"""Checkpointing: sharded-aware npz save/restore with step metadata.

Saves the full training state — group-stacked params, per-group AdamW
moments, and the Pier outer state (anchor + momentum + sync count), which is
what makes a Pier run resumable mid-interval (the paper's Megatron
integration has the same requirement).

Arrays are gathered to host (``jax.device_get`` handles cross-shard
assembly), stored as one ``.npz`` per pytree with a JSON manifest of tree
structure, dtypes and the config fingerprint. Restore re-shards via
``jax.device_put`` with the current sharding tree, so a checkpoint written on
one mesh can be read on another (e.g. 8-group run restored onto 4 groups is
rejected by shape check — group count is part of the state shape, which is
the correct semantic for per-group optimizer state).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import time
import warnings
import zipfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _path_key(path) -> str:
    """npz dict key for one pytree path (dict keys / attr names / indices)."""
    return _SEP.join(
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", ""))))
        for p in path)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_key(path)
        if key in flat:
            # e.g. a custom node whose key entries carry none of
            # key/name/idx: every leaf stringifies to "" and the npz dict
            # would silently keep only the last one
            raise ValueError(
                f"duplicate checkpoint key {key!r} (pytree path {path!r}): "
                f"the node's path entries carry no key/name/idx, so leaves "
                f"would silently overwrite each other in the npz archive")
        flat[key] = leaf
    return flat


class CheckpointManager:
    """Crash-safe: every array archive is written to a ``.tmp`` name and
    atomically renamed into place, the manifest is written *last* (its
    presence marks the checkpoint complete), and the step directory swap
    itself goes through a temp dir. A crash at any point leaves either
    the old complete checkpoint or a partial one that
    :meth:`all_steps`/:meth:`latest_step`/:meth:`restore` skip (with a
    warning) rather than raise mid-run — so GC and auto-resume always
    operate on the newest checkpoint that actually survives a load.
    """

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._warned = set()  # steps already warned about, once each
        self._verified = set()  # steps that passed the completeness check
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, trees: Dict[str, Any],
             metadata: Optional[Dict] = None) -> str:
        """trees: name -> pytree (e.g. {"state": ..., "outer": ...})."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        tmp = path + ".tmp"
        if os.path.exists(tmp):  # stale debris from a crashed save
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(),
                    "metadata": metadata or {}, "trees": {}}
        for name, tree in trees.items():
            flat = _flatten(tree)
            arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
            dest = os.path.join(tmp, f"{name}.npz")
            # temp file + atomic rename: a crash mid-write never leaves a
            # truncated archive under the final name (the temp name must
            # keep the .npz suffix — np.savez appends one otherwise)
            np.savez(dest + ".tmp.npz", **arrays)
            os.replace(dest + ".tmp.npz", dest)
            manifest["trees"][name] = sorted(arrays.keys())
        # manifest last: it is the completeness marker
        mdest = os.path.join(tmp, "manifest.json")
        with open(mdest + ".tmp", "w") as f:
            json.dump(manifest, f, indent=2)
        os.replace(mdest + ".tmp", mdest)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()
        return path

    def _gc(self):
        steps = self.all_steps()  # complete checkpoints only
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _step_error(self, step: int) -> Optional[str]:
        """Why ``step``'s checkpoint is unusable (None = complete).

        Checks the manifest parses and every archive it names passes a
        full CRC sweep with all expected arrays present — the same
        failures a crashed/partial save (or disk corruption) produces.
        """
        path = os.path.join(self.directory, f"step_{step:08d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            return f"manifest unreadable ({e})"
        for name, keys in manifest.get("trees", {}).items():
            p = os.path.join(path, f"{name}.npz")
            try:
                with zipfile.ZipFile(p) as z:
                    if z.testzip() is not None:
                        return f"{name}.npz fails CRC (truncated write?)"
                    have = {n[:-4] if n.endswith(".npy") else n
                            for n in z.namelist()}
            except (OSError, zipfile.BadZipFile) as e:
                return f"{name}.npz unreadable ({e})"
            missing = [k for k in keys if k not in have]
            if missing:
                return f"{name}.npz missing arrays {missing[:3]}"
        return None

    def _usable(self, step: int) -> bool:
        # complete checkpoints are immutable — verify each step once
        if step in self._verified:
            return True
        err = self._step_error(step)
        if err is None:
            self._verified.add(step)
            return True
        if step not in self._warned:
            self._warned.add(step)
            warnings.warn(
                f"skipping corrupt checkpoint step_{step:08d}: {err}",
                stacklevel=3)
        return False

    def all_steps(self):
        """Sorted steps with *complete* checkpoints; corrupt/truncated
        ones are skipped with a warning (once per step)."""
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and self._usable(int(m.group(1))):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None
                ) -> Tuple[Dict[str, Any], Dict]:
        """templates: name -> pytree of like-structured arrays/ShapeDtype.

        Returns (trees, metadata). Arrays are placed with ``shardings[name]``
        when given (a sharding pytree matching the template).
        """
        if step not in self._verified:
            err = self._step_error(step)
            if err is not None:
                raise ValueError(
                    f"checkpoint step_{step:08d} is incomplete/corrupt "
                    f"({err}); pick a step from all_steps() — "
                    f"latest_step() already skips unusable checkpoints")
            self._verified.add(step)
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for name, template in templates.items():
            flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
            shard_tree = shardings.get(name) if shardings else None
            shard_leaves = (jax.tree_util.tree_leaves(shard_tree)
                            if shard_tree is not None else [None] * len(flat_t))
            leaves = []
            with np.load(os.path.join(path, f"{name}.npz")) as data:
                for (p, leaf), sh in zip(flat_t, shard_leaves):
                    key = _path_key(p)
                    arr = data[key]
                    if tuple(arr.shape) != tuple(leaf.shape):
                        raise ValueError(
                            f"checkpoint/{name}/{key}: shape {arr.shape} != "
                            f"expected {leaf.shape} (group layout mismatch?)")
                    # the template dtype is authoritative: an array saved
                    # under one opt_state_dtype must not silently change
                    # the resumed run's numerics
                    tdtype = np.dtype(leaf.dtype)
                    if arr.dtype != tdtype:
                        arr = arr.astype(tdtype)
                    leaves.append(jax.device_put(arr, sh) if sh is not None
                                  else jax.device_put(arr))
            out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
        return out, manifest["metadata"]
