"""jax version compatibility shims (0.4.x ↔ ≥0.5).

The runtime targets the modern sharding API (``jax.shard_map`` with
``axis_names``, ``jax.sharding.AxisType``, ``jax.lax.pvary``,
``jax.set_mesh``); CI and some dev boxes carry jax 0.4.x where those live
under different names (or do not exist and are semantically no-ops, like
``pvary`` — the varying-mesh-axes checker it feeds was introduced later).

Everything version-dependent funnels through here so the rest of the tree
imports one spelling. Each symbol degrades to the closest 0.4.x equivalent:

- :func:`shard_map` — ``jax.shard_map(..., axis_names=manual)`` on new jax;
  ``jax.experimental.shard_map.shard_map(..., auto=<complement>)`` (partial
  manual) with ``check_rep=False`` on 0.4.x.
- :func:`pvary` — identity on 0.4.x (no VMA checker to satisfy).
- :func:`mesh_context` — ``jax.set_mesh`` on new jax; the ``Mesh`` object
  itself (a context manager) on 0.4.x.
- :func:`make_mesh` / :func:`mesh_from_devices` — drop the ``axis_types``
  kwarg where it does not exist (0.4.x meshes are implicitly all-auto,
  which is exactly what the Pier code requests).
"""

from __future__ import annotations

import contextlib
from typing import Callable, Sequence, Set, Tuple

import jax
from jax.sharding import Mesh

# jax < 0.5 defaults jax_threefry_partitionable=False, under which random
# bits generated into a sharded output differ from the same call eagerly /
# replicated. Modern jax defaults True (sharding-invariant), and the code
# here assumes it: e.g. the sim-vs-distributed equivalence relies on the
# sharded init_state producing the same params as the eager init.
try:
    if not jax.config.jax_threefry_partitionable:
        jax.config.update("jax_threefry_partitionable", True)
except AttributeError:
    pass  # flag removed (always-on) in newer jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
HAS_PVARY = hasattr(jax.lax, "pvary")
HAS_SET_MESH = hasattr(jax, "set_mesh")

AXIS_TYPE_AUTO = jax.sharding.AxisType.Auto if HAS_AXIS_TYPE else None


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with all-auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AXIS_TYPE_AUTO,) * len(shape))
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_from_devices(devices, axes: Sequence[str]) -> Mesh:
    """``Mesh(devices, axes)`` with all-auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return Mesh(devices, tuple(axes),
                    axis_types=(AXIS_TYPE_AUTO,) * len(axes))
    return Mesh(devices, tuple(axes))


def shard_map(
    f: Callable,
    *,
    mesh: Mesh,
    in_specs,
    out_specs,
    axis_names: Set[str],
):
    """Partial-manual shard_map: ``axis_names`` manual, the rest auto."""
    if HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(axis_names))
    from jax.experimental.shard_map import shard_map as _sm

    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def pvary(x, axis_names: Tuple[str, ...]):
    """Mark ``x`` varying over manual axes (identity pre-VMA-checker jax)."""
    if not axis_names:
        return x
    if HAS_PVARY:
        return jax.lax.pvary(x, tuple(axis_names))
    return x


def mesh_context(mesh: Mesh):
    """Context manager putting ``mesh`` in scope for sharding constraints."""
    if HAS_SET_MESH:
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh  # 0.4.x: Mesh is itself a context manager
    return contextlib.nullcontext()
