"""Serving launcher: the continuous-batching engine behind a CLI.

``python -m repro.launch.serve --arch qwen3-1.7b --reduced --tokens 32``

Paged-supported architectures (gqa-family KV caches) decode through the
``repro.serve`` engine — paged KV pool, Pallas decode attention,
continuous batching; MLA / SSM / encoder-decoder configs take the dense
``build_serve_steps`` path inside the same :func:`repro.serve.generate`
helper. ``--ckpt-dir`` hot-swaps params from the newest complete trainer
checkpoint between decode steps (``serve/handoff.py``).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig
from repro.configs import get_config, get_reduced_config
from repro.launch import mesh as M
from repro.models import registry as R
from repro.serve import (CheckpointPoller, EngineConfig, PagedCacheConfig,
                         ServeEngine, generate, paged_supported)


def main(argv=None):
    ap = argparse.ArgumentParser(description="Pier serving launcher")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--mesh", default="")
    # store_true + default=True left this flag dead (it could never be
    # turned off); sampling is the actual toggle now
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy decode")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV-pool block size (paged path)")
    ap.add_argument("--int8-kv", action="store_true",
                    help="int8-quantized KV blocks (paged path)")
    ap.add_argument("--ckpt-dir", default="",
                    help="hot-swap params from new complete checkpoints here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mc = (get_reduced_config(args.arch) if args.reduced
          else get_config(args.arch))
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
    else:
        shape = (jax.device_count(), 1)
    mesh = M.small_mesh(shape, ("data", "model"))
    pc = ParallelConfig(data_axis_size=shape[0], model_axis_size=shape[-1],
                        data_outer=1)

    # independent keys: reusing one key for params AND the prompt made the
    # "random" prompt a function of the weights' randomness
    key_params, key_prompt = jax.random.split(jax.random.PRNGKey(args.seed))
    params = jax.jit(lambda k: R.init_params(k, mc))(key_params)
    prompts = np.asarray(jax.random.randint(
        key_prompt, (args.batch, args.prompt_len), 0, mc.vocab_size))
    frames = None
    if mc.is_encoder_decoder:
        frames = jax.random.normal(
            key_prompt, (args.batch, mc.encoder_seq_len, mc.d_model),
            jnp.float32)

    ok, why = paged_supported(mc)
    pcfg = None
    if ok and frames is None:
        bs = args.block_size
        padded = -(-args.prompt_len // bs) * bs
        need = -(-(padded + args.tokens) // bs)  # blocks per sequence
        pcfg = PagedCacheConfig(num_blocks=need * args.batch + 1,
                                block_size=bs, quantized=args.int8_kv)

    t0 = time.time()
    if args.ckpt_dir and pcfg is not None:
        # explicit engine loop so the handoff hook runs between steps
        from repro.parallel.steps import build_paged_serve_steps
        bundle = build_paged_serve_steps(mc, pc, mesh, pcfg=pcfg)
        engine = ServeEngine(params, mc, bundle, pcfg, EngineConfig(
            max_slots=args.batch, max_new_tokens=args.tokens,
            greedy=not args.sample, temperature=args.temperature,
            seed=args.seed, max_blocks_per_seq=need))
        for b in range(args.batch):
            engine.submit(prompts[b], args.tokens)
        poller = CheckpointPoller(args.ckpt_dir, params)
        results = engine.run(on_step=poller.on_step)
        out = np.stack([np.asarray(r.tokens[: args.tokens], np.int32)
                        for r in results])
        info = {"path": "paged", "engine": engine}
        if poller.swapped_steps:
            print(f"hot-swapped params at checkpoint steps "
                  f"{poller.swapped_steps}")
    else:
        out, info = generate(
            params, mc, pc, mesh, prompts, args.tokens,
            greedy=not args.sample, temperature=args.temperature,
            seed=args.seed, frames=frames, pcfg=pcfg)
    dt = time.time() - t0

    print(f"arch={mc.name} path={info['path']} "
          f"tokens/s={out.size / max(dt, 1e-9):.1f} ({dt:.2f}s total)")
    if info["path"] == "paged":
        eng = info["engine"]
        print(f"engine: {eng.stats['decode_steps']} decode steps, "
              f"{eng.stats['prefills']} prefills, peak pool "
              f"{eng.stats['peak_blocks']}/{pcfg.num_blocks - 1} blocks")
    else:
        print(f"dense path ({why or 'frames given'})")
    print("generated[0,:16]:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
