"""Serving launcher: batched prefill + decode against the sharded KV cache.

``python -m repro.launch.serve --arch qwen3-1.7b --reduced --tokens 32``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig
from repro.configs import get_config, get_reduced_config
from repro.launch import mesh as M
from repro.models import registry as R
from repro.parallel.steps import build_serve_steps


def main(argv=None):
    ap = argparse.ArgumentParser(description="Pier serving launcher")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mc = (get_reduced_config(args.arch) if args.reduced
          else get_config(args.arch))
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
    else:
        shape = (jax.device_count(), 1)
    mesh = M.small_mesh(shape, ("data", "model"))
    pc = ParallelConfig(data_axis_size=shape[0], model_axis_size=shape[-1],
                        data_outer=1)
    max_len = args.prompt_len + args.tokens
    bundle = build_serve_steps(mc, pc, mesh, batch=args.batch, max_len=max_len)

    key = jax.random.PRNGKey(args.seed)
    params = jax.jit(
        lambda k: R.init_params(k, mc),
        out_shardings=bundle.param_shardings)(key)
    prompt = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, mc.vocab_size)
    batch_in = {"tokens": prompt}
    if mc.is_encoder_decoder:
        batch_in["frames"] = jax.random.normal(
            key, (args.batch, mc.encoder_seq_len, mc.d_model), jnp.float32)

    t0 = time.time()
    logits, state = bundle.prefill_step(params, batch_in)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [next_tok]
    t1 = time.time()
    for _ in range(args.tokens - 1):
        logits, state = bundle.serve_step(params, state, next_tok)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t2 = time.time()
    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    print(f"arch={mc.name} prefill={t1-t0:.3f}s "
          f"decode={(t2-t1)/max(args.tokens-1,1)*1e3:.1f} ms/tok")
    print("generated[0,:16]:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
