"""Production mesh construction and the Pier group refinement.

``make_production_mesh`` builds the spec-mandated meshes:

    single pod : (16, 16)      axes (data, model)   — 256 chips (v5e pod)
    multi-pod  : (2, 16, 16)   axes (pod, data, model) — 512 chips

``refine_mesh`` splits the data axis into (data_outer, data_inner) for Pier's
group structure **without touching device order**, so shardings remain
device-consistent: a Pier group = one (pod, data_outer) index =
``data_inner × model`` chips, a contiguous mesh slice with full intra-group
ICI bandwidth. All functions (not module constants) — importing this module
never touches jax device state.
"""

from __future__ import annotations

from typing import Dict, Tuple

from jax.sharding import Mesh

from repro import compat

# jax < 0.5 has no jax.sharding.AxisType; all-auto is the implicit default
# there, which is what every mesh in this module asks for.
AUTO = compat.AXIS_TYPE_AUTO


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def refine_mesh(mesh: Mesh, data_outer: int) -> Mesh:
    """(pod?, data, model) -> (pod?, data_outer, data_inner, model)."""
    names = mesh.axis_names
    devs = mesh.devices
    if "pod" in names:
        pod, data, model = devs.shape
        assert data % data_outer == 0, (data, data_outer)
        new = devs.reshape(pod, data_outer, data // data_outer, model)
        axes = ("pod", "data_outer", "data_inner", "model")
    else:
        data, model = devs.shape
        assert data % data_outer == 0, (data, data_outer)
        new = devs.reshape(data_outer, data // data_outer, model)
        axes = ("data_outer", "data_inner", "model")
    return compat.mesh_from_devices(new, axes)


def make_pier_mesh(
    *,
    multi_pod: bool = False,
    data_outer: int = 4,
) -> Mesh:
    return refine_mesh(make_production_mesh(multi_pod=multi_pod), data_outer)


def small_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh over host devices (tests / CPU runs)."""
    return compat.make_mesh(shape, axes)


def manual_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The axes Pier relaxes: everything outer to the group."""
    return tuple(a for a in ("pod", "data_outer") if a in mesh.axis_names)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data_outer", "data_inner", "data")
                 if a in mesh.axis_names)


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
