"""Production mesh construction and the Pier group refinement.

``make_production_mesh`` builds the spec-mandated meshes:

    single pod : (16, 16)      axes (data, model)   — 256 chips (v5e pod)
    multi-pod  : (2, 16, 16)   axes (pod, data, model) — 512 chips

``refine_mesh`` splits the data axis into (data_outer, data_inner) for Pier's
group structure **without touching device order**, so shardings remain
device-consistent: a Pier group = one (pod, data_outer) index =
``data_inner × model`` chips, a contiguous mesh slice with full intra-group
ICI bandwidth. All functions (not module constants) — importing this module
never touches jax device state.

Also home to the backend-aware *environment presets*
(:func:`apply_env_preset`): the async-collective / latency-hiding XLA
flags, tcmalloc hints, and host-device-count settings each kernel backend
wants, applied by the launcher **before** jax initializes its backends.
Presets only ever *append* — a flag name the user already set is left
untouched (:func:`_merge_xla_flags`), and double-apply is a no-op.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

from jax.sharding import Mesh

from repro import compat

# jax < 0.5 has no jax.sharding.AxisType; all-auto is the implicit default
# there, which is what every mesh in this module asks for.
AUTO = compat.AXIS_TYPE_AUTO


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def refine_mesh(mesh: Mesh, data_outer: int) -> Mesh:
    """(pod?, data, model) -> (pod?, data_outer, data_inner, model)."""
    names = mesh.axis_names
    devs = mesh.devices
    if "pod" in names:
        pod, data, model = devs.shape
        assert data % data_outer == 0, (data, data_outer)
        new = devs.reshape(pod, data_outer, data // data_outer, model)
        axes = ("pod", "data_outer", "data_inner", "model")
    else:
        data, model = devs.shape
        assert data % data_outer == 0, (data, data_outer)
        new = devs.reshape(data_outer, data // data_outer, model)
        axes = ("data_outer", "data_inner", "model")
    return compat.mesh_from_devices(new, axes)


def make_pier_mesh(
    *,
    multi_pod: bool = False,
    data_outer: int = 4,
) -> Mesh:
    return refine_mesh(make_production_mesh(multi_pod=multi_pod), data_outer)


def small_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh over host devices (tests / CPU runs)."""
    return compat.make_mesh(shape, axes)


def manual_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The axes Pier relaxes: everything outer to the group."""
    return tuple(a for a in ("pod", "data_outer") if a in mesh.axis_names)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data_outer", "data_inner", "data")
                 if a in mesh.axis_names)


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ---------------------------------------------------------------------------
# backend-aware environment presets (applied before jax initializes)
# ---------------------------------------------------------------------------

# Async-collective / latency-hiding flags for the gpu-triton lane: make
# XLA:GPU overlap the outer collectives with inner compute (the whole
# point of sync_delay) and route softmax/gemm through Triton. Names only
# matter for conflict detection — a user's explicit value always wins.
GPU_XLA_FLAGS: Tuple[str, ...] = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

# tcmalloc: LD_PRELOAD cannot take effect inside an already-running
# process, so the preset only *reports* a discovered library path for a
# wrapper script to export; the large-alloc report threshold is a plain
# env var (silences the per-arena warnings at multi-GiB host staging).
TCMALLOC_PRELOAD_PATHS: Tuple[str, ...] = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc.so.4",
)
TCMALLOC_REPORT_THRESHOLD = "10737418240"  # 10 GiB


def _merge_xla_flags(
        existing: str,
        additions: Sequence[str]) -> Tuple[str, List[str], List[str]]:
    """Append ``additions`` to an XLA_FLAGS string without clobbering.

    Returns ``(merged, appended, skipped)``. A flag whose *name* (the
    part before ``=``) already appears in ``existing`` is skipped — the
    user's value wins even when it conflicts with the preset, and
    re-applying the same preset is a no-op (idempotent). Wholesale
    ``os.environ["XLA_FLAGS"] = ...`` assignment (the pattern common in
    GPU launch scripts) silently drops whatever was already set — e.g.
    CI's ``--xla_force_host_platform_device_count`` — which is exactly
    the bug this helper exists to prevent.
    """
    tokens = existing.split()
    have = {t.split("=", 1)[0] for t in tokens}
    appended: List[str] = []
    skipped: List[str] = []
    for flag in additions:
        name = flag.split("=", 1)[0]
        if name in have:
            skipped.append(flag)
            continue
        tokens.append(flag)
        have.add(name)
        appended.append(flag)
    return " ".join(tokens), appended, skipped


def apply_env_preset(backend: str, *, env=None,
                     host_device_count: Optional[int] = None) -> Dict:
    """Apply one kernel backend's environment preset, append-only.

    Must run before jax initializes its backends (XLA_FLAGS is read once
    at backend init); the launcher calls it at the top of ``main()`` when
    an explicit ``--kernel-backend`` is given. ``env`` defaults to
    ``os.environ`` (pass a dict in tests). ``host_device_count`` adds
    ``--xla_force_host_platform_device_count`` for the host-platform
    lanes (interpret / jnp-ref) so multi-device meshes work on CPU.

    Returns a report dict: ``xla_flags_appended`` / ``xla_flags_skipped``
    (conflicts left to the user's value), ``env_set``, and
    ``ld_preload_hint`` (a discovered tcmalloc path, never exported here
    — preloading must happen in the wrapper script). Never touches jax
    device state.
    """
    known = ("tpu-mosaic", "gpu-triton", "interpret", "jnp-ref")
    if backend not in known:
        raise ValueError(
            f"unknown kernel backend {backend!r} (choices: {', '.join(known)})")
    if env is None:
        env = os.environ
    additions: List[str] = []
    if backend == "gpu-triton":
        additions += list(GPU_XLA_FLAGS)
    if host_device_count is not None and backend in ("interpret", "jnp-ref"):
        additions.append(
            f"--xla_force_host_platform_device_count={int(host_device_count)}")
    merged, appended, skipped = _merge_xla_flags(
        env.get("XLA_FLAGS", ""), additions)
    if appended:
        env["XLA_FLAGS"] = merged
    env_set: Dict[str, str] = {}
    if (backend in ("gpu-triton", "tpu-mosaic")
            and "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in env):
        env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = (
            TCMALLOC_REPORT_THRESHOLD)
        env_set["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = (
            TCMALLOC_REPORT_THRESHOLD)
    ld_preload_hint = None
    if backend in ("gpu-triton", "tpu-mosaic") and "LD_PRELOAD" not in env:
        for path in TCMALLOC_PRELOAD_PATHS:
            if os.path.exists(path):
                ld_preload_hint = path
                break
    return {
        "backend": backend,
        "xla_flags_appended": appended,
        "xla_flags_skipped": skipped,
        "env_set": env_set,
        "ld_preload_hint": ld_preload_hint,
    }
