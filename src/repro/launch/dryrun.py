import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 host devices back the 2x16x16 production mesh.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract the roofline terms.

Per combo this produces:

- **fit compile** (full depth, scan_layers + remat + microbatching): proves
  the sharding is coherent on the single-pod (16,16) AND multi-pod (2,16,16)
  meshes; ``memory_analysis()`` gives honest bytes/device.
- **cost compiles** (unrolled, depth L1 = prefix+C and L2 = prefix+2C):
  XLA's ``cost_analysis()`` undercounts ``lax.scan`` bodies (counted once),
  so FLOPs / HBM bytes / per-collective bytes are measured exactly at two
  small depths and extrapolated linearly in depth — exact for layer-stacked
  models (every layer past the prefix contributes identical HLO).
- **train shapes additionally** lower ``outer_step`` (the 1/H global sync)
  and ``warmup_step`` (per-step global AdamW baseline) so the roofline can
  price Pier against the paper's baseline.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all          # orchestrates subprocesses
"""

import argparse
import json
import re
import subprocess
import sys
import time
from collections import defaultdict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (
    InputShape, INPUT_SHAPES, ModelConfig, ParallelConfig, TrainConfig)
from repro.configs import assigned_architectures, get_config
from repro.launch import mesh as M
from repro.models import registry as R
from repro.models import transformer as T
from repro.parallel.steps import build_serve_steps, build_train_steps

DEFAULT_OUT = "experiments/dryrun"

# Architectures where long_500k is skipped (full-context attention without a
# sliding-window variant) — see DESIGN.md §Arch-applicability.
LONG_SKIP = {
    "deepseek-v2-236b": "MLA latent attention is full-context; MLA+SWA is "
                        "not a published configuration",
    "kimi-k2-1t-a32b": "full-context GQA MoE; no sub-quadratic variant in "
                       "the model family",
    "whisper-large-v3": "encoder-decoder; 500k-token decoder context is not "
                        "meaningful for the architecture",
}
# Dense archs that run long_500k via the sliding-window variant:
SWA_WINDOW = 4096


def resolve_model(arch: str, shape: InputShape) -> Optional[ModelConfig]:
    mc = get_config(arch)
    if shape.name == "long_500k":
        if arch in LONG_SKIP:
            return None
        if not mc.sub_quadratic:
            mc = mc.replace(sliding_window=SWA_WINDOW,
                            name=mc.name + "+swa4096")
    return mc


def auto_microbatches(shape: InputShape, pc: ParallelConfig) -> int:
    if shape.kind != "train":
        return 1
    per_group = shape.global_batch // pc.num_groups
    return max(1, per_group // 8)


def _specs_of(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def make_train_batch_specs(mc, shape, bundle):
    batch = {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
    }
    if mc.is_encoder_decoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (shape.global_batch, mc.encoder_seq_len, mc.d_model), jnp.float32)
    shardings = bundle.batch_sharding(batch)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        batch, shardings)


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_CONVERT_RE = re.compile(
    r"wrapped_convert(?:_computation)?[^\(]*\(param_0[^:]*: "
    r"(?:bf16|f16)\[([\d,]*)\]\) -> f32\[([\d,]*)\]")


def cpu_convert_artifact_bytes(hlo_text: str) -> int:
    """Bytes of whole-tensor bf16->f32 converts hoisted out of loops.

    XLA:CPU legalizes bf16 dots by upcasting operands to f32; the per-layer
    converts are then hoisted out of the ``lax.scan`` while-loop as
    loop-invariant whole-stack f32 copies that stay live for the entire
    loop. A TPU backend consumes bf16 on the MXU directly, so these buffers
    do not exist on the target hardware. We quantify them so the memory
    report can show measured and corrected bytes side by side.
    """
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        dims = m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * 4
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind OUTPUT bytes (per device) summed over the module.

    ``-start``/``-done`` pairs are counted once (the start op carries the
    shape; done lines reference the same buffer).
    """
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return dict(out)


# ---------------------------------------------------------------------------
# one combo
# ---------------------------------------------------------------------------


def _mesh_for(mesh_kind: str, data_outer: int):
    return M.make_pier_mesh(multi_pod=(mesh_kind == "multi"),
                            data_outer=data_outer)


def _compile_record(compiled) -> Dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    return {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "cpu_convert_artifact_bytes": cpu_convert_artifact_bytes(txt),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": collective_bytes(txt),
    }


def lower_train(mc, tc, pc, mesh, shape, *, steps=("inner",)):
    bundle = build_train_steps(mc, tc, pc, mesh)
    state_shapes = jax.eval_shape(bundle.init_state, jax.random.PRNGKey(0))
    state_specs = _specs_of(state_shapes, bundle.state_shardings)
    batch_specs = make_train_batch_specs(mc, shape, bundle)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)
    out = {}
    if "inner" in steps:
        out["inner"] = bundle.inner_step.lower(
            state_specs, batch_specs, step_spec).compile()
    if "warmup" in steps:
        out["warmup"] = bundle.warmup_step.lower(
            state_specs, batch_specs, step_spec).compile()
    if "outer" in steps:
        outer_shapes = jax.eval_shape(bundle.init_outer, state_shapes)
        outer_specs = _specs_of(outer_shapes, bundle.outer_shardings)
        mu = jax.ShapeDtypeStruct((), jnp.float32)
        out["outer"] = bundle.outer_step.lower(
            state_specs, outer_specs, mu, mu).compile()
    return out


def lower_serve(mc, pc, mesh, shape, *, prefill: bool):
    batch = shape.global_batch
    bundle = build_serve_steps(mc, pc, mesh, batch=batch,
                               max_len=shape.seq_len)
    pshapes = jax.eval_shape(
        lambda k: R.init_params(k, mc, scan_layers=pc.scan_layers),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    # Serving uses the bf16 model copy (paper: BF16 model / FP32 optimizer;
    # the fp32 master lives with the trainer, not the server).
    pshapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape,
            jnp.dtype(mc.dtype) if l.dtype == jnp.float32 else l.dtype),
        pshapes)
    param_specs = _specs_of(pshapes, bundle.param_shardings)
    if prefill:
        b = {"tokens": jax.ShapeDtypeStruct((batch, shape.seq_len), jnp.int32)}
        if mc.is_encoder_decoder:
            b["frames"] = jax.ShapeDtypeStruct(
                (batch, mc.encoder_seq_len, mc.d_model), jnp.float32)
        return {"prefill": bundle.prefill_step.lower(param_specs, b).compile()}
    state_shapes = jax.eval_shape(
        lambda: R.init_decode_state(mc, batch, shape.seq_len,
                                    scan_layers=pc.scan_layers))
    state_specs = _specs_of(state_shapes, bundle.state_shardings)
    toks = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return {"decode": bundle.serve_step.lower(
        param_specs, state_specs, toks).compile()}


def cost_depths(mc: ModelConfig) -> Tuple[int, int, int]:
    """(L1, L2, C) unrolled depths for the linear-in-depth extrapolation."""
    prefix, C, n, suffix = T.layer_segments(mc)
    return prefix + C, prefix + 2 * C, C


def run_combo(arch: str, shape_name: str, mesh_kind: str, data_outer: int,
              *, do_cost: bool = True, outer_sharded: bool = False) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    mc = resolve_model(arch, shape)
    record: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "data_outer": data_outer, "time": time.time(),
    }
    if mc is None:
        record["skipped"] = LONG_SKIP[arch]
        return record
    mesh = _mesh_for(mesh_kind, data_outer)
    sizes = M.axis_sizes(mesh)
    pc = ParallelConfig(
        data_axis_size=sizes.get("data_outer", 1) * sizes.get("data_inner", 1),
        model_axis_size=sizes["model"],
        num_pods=sizes.get("pod", 1),
        data_outer=sizes.get("data_outer", 1),
        scan_layers=True,
        remat="full" if shape.kind == "train" else "none",
    )
    pc = pc.replace(num_microbatches=auto_microbatches(shape, pc))
    tc = TrainConfig(global_batch_size=shape.global_batch,
                     seq_len=shape.seq_len)
    if outer_sharded:
        # sharded quantized outer exchange (DESIGN.md §10): each device
        # compresses/exchanges only its Δθ shard over data_inner×model
        from repro.config import OuterCommConfig
        tc = tc.replace(outer_comm=OuterCommConfig(
            compression="quantize", sharded=True))
        record["outer_sharded"] = True
    record["config"] = {
        "num_groups": pc.num_groups, "num_microbatches": pc.num_microbatches,
        "params": R.count_params(mc), "active_params": R.count_params(mc, True),
        "model_name": mc.name,
    }

    # ---- fit compile (full depth) ----
    t0 = time.time()
    if shape.kind == "train":
        steps = ("inner", "warmup", "outer") if mesh_kind == "single" \
            else ("inner",)
        compiled = lower_train(mc, tc, pc, mesh, shape, steps=steps)
    elif shape.kind == "prefill":
        compiled = lower_serve(mc, pc, mesh, shape, prefill=True)
    else:
        compiled = lower_serve(mc, pc, mesh, shape, prefill=False)
    record["fit"] = {k: _compile_record(v) for k, v in compiled.items()}
    record["fit_compile_seconds"] = time.time() - t0
    del compiled

    # ---- cost compiles (small unrolled depths, single-pod only) ----
    # chunk_policy("never") + mlstm_chunk=0 force the loop-free quadratic
    # forms so cost_analysis() counts every FLOP exactly (lax.scan bodies
    # are otherwise counted once); memory honesty comes from the fit
    # compile above, which uses the production (chunked/scanned) paths.
    if do_cost and mesh_kind == "single":
        from repro.models.attention import chunk_policy

        L1, L2, C = cost_depths(mc)
        cost = {}
        # MoE train grads at nm=1 trip the same XLA partitioner CHECK (the
        # microbatch scan sidesteps it); use nm=2 and scale the in-loop
        # terms back up. The scan body holds exactly 1/nm of the step's
        # model work, so flops/bytes scale by nm; grad all-reduce /
        # reduce-scatter run once per step (outside the loop) either way.
        nm_cost = 2 if (mc.is_moe and shape.kind == "train") else 1
        with chunk_policy("never"):
            for L in (L1, L2):
                mcl = mc.replace(num_layers=L, mlstm_chunk=0)
                pcl = pc.replace(scan_layers=False, num_microbatches=nm_cost,
                                 remat="none")
                if shape.kind == "train":
                    cl = lower_train(mcl, tc, pcl, mesh, shape,
                                     steps=("inner",))
                    cost[L] = _compile_record(cl["inner"])
                    if nm_cost > 1:
                        r = cost[L]
                        r["flops"] *= nm_cost
                        r["bytes_accessed"] *= nm_cost
                        r["collective_bytes"] = {
                            k: v * nm_cost if k in ("all-gather", "all-to-all")
                            else v
                            for k, v in r["collective_bytes"].items()}
                        r["cost_nm_scaled"] = nm_cost
                elif shape.kind == "prefill":
                    cl = lower_serve(mcl, pcl, mesh, shape, prefill=True)
                    cost[L] = _compile_record(cl["prefill"])
                else:
                    cl = lower_serve(mcl, pcl, mesh, shape, prefill=False)
                    cost[L] = _compile_record(cl["decode"])
                del cl
        record["cost_depths"] = {"L1": L1, "L2": L2, "cycle": C,
                                 "full_depth": mc.num_layers}
        record["cost"] = {str(k): v for k, v in cost.items()}
        record["extrapolated"] = extrapolate_cost(
            cost[L1], cost[L2], L1, L2, mc.num_layers)
    return record


def extrapolate_cost(r1: Dict, r2: Dict, L1: int, L2: int, L: int) -> Dict:
    """Linear-in-depth extrapolation of flops / bytes / collectives."""
    def lin(a, b):
        per_layer = (b - a) / (L2 - L1)
        return a + per_layer * (L - L1)

    out = {
        "flops": lin(r1["flops"], r2["flops"]),
        "bytes_accessed": lin(r1["bytes_accessed"], r2["bytes_accessed"]),
    }
    kinds = set(r1["collective_bytes"]) | set(r2["collective_bytes"])
    out["collective_bytes"] = {
        k: max(0.0, lin(r1["collective_bytes"].get(k, 0),
                        r2["collective_bytes"].get(k, 0)))
        for k in kinds
    }
    return out


# ---------------------------------------------------------------------------
# orchestration
# ---------------------------------------------------------------------------


def all_combos():
    for arch in assigned_architectures():
        for shape in INPUT_SHAPES:
            yield arch, shape


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="", choices=[""] + list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--data-outer", type=int, default=4)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--all", action="store_true",
                    help="run every combo in subprocesses")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--outer-sharded", action="store_true",
                    help="lower the train steps with the sharded quantized "
                         "outer exchange (DESIGN.md §10)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = []
        for arch, shape in all_combos():
            for mesh_kind in (["single", "multi"] if args.mesh == "both"
                              else [args.mesh]):
                tag = f"{arch}__{shape}__{mesh_kind}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag} (exists)", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", mesh_kind, "--out", args.out,
                       "--data-outer", str(args.data_outer)]
                if args.no_cost:
                    cmd.append("--no-cost")
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                ok = r.returncode == 0
                print(f"[{'ok' if ok else 'FAIL'}] {tag} "
                      f"({time.time()-t0:.0f}s)", flush=True)
                if not ok:
                    failures.append(tag)
                    with open(os.path.join(args.out, tag + ".err"), "w") as f:
                        f.write(r.stdout[-5000:] + "\n--- stderr ---\n"
                                + r.stderr[-10000:])
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape required"
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh_kind in meshes:
        record = run_combo(args.arch, args.shape, mesh_kind, args.data_outer,
                           do_cost=not args.no_cost,
                           outer_sharded=args.outer_sharded)
        tag = f"{args.arch}__{args.shape}__{mesh_kind}"
        path = os.path.join(args.out, tag + ".json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        if "skipped" in record:
            print(f"{tag}: SKIPPED ({record['skipped']})")
        else:
            fit = record["fit"]
            key = next(iter(fit))
            mem = (fit[key]["argument_bytes_per_device"]
                   + fit[key]["temp_bytes_per_device"]) / 2**30
            print(f"{tag}: ok mem/dev={mem:.1f}GiB "
                  f"compile={record['fit_compile_seconds']:.0f}s")


if __name__ == "__main__":
    main()
