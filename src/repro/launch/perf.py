import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede all other imports (jax locks device count on first init).

"""§Perf hillclimb driver: lower/compile named VARIANTS of a (arch × shape)
pair and report the roofline-term deltas vs the paper-faithful baseline.

    python -m repro.launch.perf --pair granite-8b:train_4k --variant baseline
    python -m repro.launch.perf --pair granite-8b:train_4k --list

Each variant is {parallel-config overrides, model overrides, serve-sharding
overrides}; records land in experiments/perf/<pair>__<variant>.json.
"""

import argparse
import json
import time
from typing import Dict


from repro.config import INPUT_SHAPES, ParallelConfig, TrainConfig
from repro.launch import mesh as M
from repro.launch.dryrun import (
    _compile_record, _mesh_for, auto_microbatches, cost_depths,
    extrapolate_cost, lower_serve, lower_train, resolve_model)
from repro.models.attention import chunk_policy

# ---------------------------------------------------------------------------
# variant definitions (the §Perf candidate changes)
# ---------------------------------------------------------------------------

VARIANTS: Dict[str, Dict[str, Dict]] = {
    # paper-faithful baseline: fp32 master+moments, remat=full, FSDP-in-group
    "baseline": {},
    # activation-checkpoint policy: no remat (more memory, -25% flops)
    "no_remat": {"pc": {"remat": "none"}},
    # selective remat: save matmul outputs, recompute the cheap chains
    "selective_remat": {"pc": {"remat": "selective"}},
    "selective_opt_bf16": {"pc": {"remat": "selective"},
                           "tc": {"opt_state_dtype": "bfloat16"}},
    # fewer microbatches: fewer FSDP re-gathers per step (all-gather /nm)
    "nm2": {"pc_nm": 2},
    "nm1": {"pc_nm": 1},
    # beyond-paper: bf16 optimizer state (halves AdamW m/v bytes)
    "opt_bf16": {"tc": {"opt_state_dtype": "bfloat16"}},
    # bf16 master params (paper's 'BF16 model' reading): 4->2 bytes/param
    "master_bf16": {"mc": {"param_dtype": "bfloat16"}},
    # both memory levers together
    "bf16_all": {"tc": {"opt_state_dtype": "bfloat16"},
                 "mc": {"param_dtype": "bfloat16"}},
    # group structure: 2 groups instead of 4 (more in-group sharding)
    "groups2": {"data_outer": 2},
    "groups8": {"data_outer": 8},
    # inference: expert-parallel over BOTH data_inner and model axes
    # (kills the per-layer FSDP all-gather of expert stacks)
    "ep2d": {"ep2d": True},
    # inference: no FSDP (pure TP serving; weights replicated over data)
    "serve_no_fsdp": {"pc": {"fsdp": False}},
    # sliding-window length ablation for long-context decode
    "swa_1k": {"mc": {"sliding_window": 1024}},
    # the memory-fit combo for the 100B+ MoEs: 2 groups + bf16 everywhere
    "fit_combo": {"data_outer": 2,
                  "tc": {"opt_state_dtype": "bfloat16"},
                  "mc": {"param_dtype": "bfloat16"}},
    # fit_combo + relaxed remat (is there flops headroom once memory fits?)
    "fit_combo_norematt": {"data_outer": 2,
                           "tc": {"opt_state_dtype": "bfloat16"},
                           "mc": {"param_dtype": "bfloat16"},
                           "pc": {"remat": "none"}},
}


def run_variant(arch: str, shape_name: str, variant: str,
                data_outer: int = 4) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    spec = VARIANTS[variant]
    mc = resolve_model(arch, shape)
    assert mc is not None, "pair is skipped for this shape"
    if "mc" in spec:
        mc = mc.replace(**spec["mc"])
    mesh = _mesh_for("single", spec.get("data_outer", data_outer))
    sizes = M.axis_sizes(mesh)
    pc = ParallelConfig(
        data_axis_size=sizes["data_outer"] * sizes["data_inner"],
        model_axis_size=sizes["model"],
        data_outer=sizes["data_outer"],
        scan_layers=True,
        remat="full" if shape.kind == "train" else "none")
    pc = pc.replace(num_microbatches=auto_microbatches(shape, pc))
    if "pc" in spec:
        pc = pc.replace(**spec["pc"])
    if "pc_nm" in spec:
        pc = pc.replace(num_microbatches=spec["pc_nm"])
    tc = TrainConfig(global_batch_size=shape.global_batch,
                     seq_len=shape.seq_len,
                     **spec.get("tc", {}))

    if spec.get("ep2d"):
        # widen the expert-parallel axis to (data_inner, model)
        import repro.parallel.sharding as S
        orig = S._physical

        def patched(logical, *, fsdp, experts):
            if logical == S.EXP and experts:
                return ("data_inner", "model")
            return orig(logical, fsdp=fsdp, experts=experts)

        S._physical = patched
        import repro.parallel.axes as AX
        orig_rules = AX.pier_rules

        def patched_rules(**kw):
            r = orig_rules(**kw)
            rules = dict(r.rules)
            if rules.get("experts"):
                rules["experts"] = ("data_inner", "model")
            return AX.LogicalAxisRules(rules=rules,
                                       axis_sizes=r.axis_sizes)

        AX.pier_rules = patched_rules

    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "time": time.time(),
           "pc": {"num_microbatches": pc.num_microbatches,
                  "remat": pc.remat, "fsdp": pc.fsdp,
                  "data_outer": sizes["data_outer"]},
           "tc": {"opt_state_dtype": tc.opt_state_dtype},
           "mc": {"param_dtype": mc.param_dtype,
                  "sliding_window": mc.sliding_window}}

    t0 = time.time()
    if shape.kind == "train":
        out = lower_train(mc, tc, pc, mesh, shape,
                          steps=("inner", "outer"))
        rec["fit"] = {k: _compile_record(v) for k, v in out.items()}
    elif shape.kind == "prefill":
        out = lower_serve(mc, pc, mesh, shape, prefill=True)
        rec["fit"] = {"prefill": _compile_record(out["prefill"])}
    else:
        out = lower_serve(mc, pc, mesh, shape, prefill=False)
        rec["fit"] = {"decode": _compile_record(out["decode"])}
    del out
    rec["compile_seconds"] = time.time() - t0

    # cost extrapolation (exact flops/collectives), same method as dryrun
    L1, L2, C = cost_depths(mc)
    nm_cost = 2 if (mc.is_moe and shape.kind == "train") else 1
    cost = {}
    with chunk_policy("never"):
        for L in (L1, L2):
            mcl = mc.replace(num_layers=L, mlstm_chunk=0)
            pcl = pc.replace(scan_layers=False, num_microbatches=nm_cost,
                             remat="none")
            if shape.kind == "train":
                cl = lower_train(mcl, tc, pcl, mesh, shape, steps=("inner",))
                cost[L] = _compile_record(cl["inner"])
                if nm_cost > 1:
                    r = cost[L]
                    r["flops"] *= nm_cost
                    r["bytes_accessed"] *= nm_cost
                    r["collective_bytes"] = {
                        k: v * nm_cost if k in ("all-gather", "all-to-all")
                        else v for k, v in r["collective_bytes"].items()}
            elif shape.kind == "prefill":
                cl = lower_serve(mcl, pcl, mesh, shape, prefill=True)
                cost[L] = _compile_record(cl["prefill"])
            else:
                cl = lower_serve(mcl, pcl, mesh, shape, prefill=False)
                cost[L] = _compile_record(cl["decode"])
            del cl
    rec["extrapolated"] = extrapolate_cost(
        cost[L1], cost[L2], L1, L2, mc.num_layers)
    return rec


def summarize(rec: Dict) -> str:
    key = next(iter(rec["fit"]))
    f = rec["fit"][key]
    mem = (f["argument_bytes_per_device"] + f["temp_bytes_per_device"]
           + f["output_bytes_per_device"]) / 2**30
    corr = mem - f.get("cpu_convert_artifact_bytes", 0) / 2**30
    e = rec["extrapolated"]
    coll = sum(e["collective_bytes"].values())
    return (f"{rec['variant']:14s} mem={mem:7.1f}GiB (corr {corr:7.1f}) "
            f"flops/dev={e['flops']:.3g} hbm={e['bytes_accessed']:.3g} "
            f"coll={coll/2**30:.1f}GiB")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=False, default="")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args(argv)
    if args.list:
        print("\n".join(VARIANTS))
        return
    arch, shape = args.pair.split(":")
    rec = run_variant(arch, shape, args.variant)
    os.makedirs(args.out, exist_ok=True)
    tag = f"{arch}__{shape}__{args.variant}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    print(summarize(rec))


if __name__ == "__main__":
    main()
