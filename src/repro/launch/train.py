"""Training launcher: ``python -m repro.launch.train --arch gpt2-small ...``

Runs the full Pier loop on whatever devices are available (CPU host devices
for validation, a real TPU slice in production — the code path is identical).
The host loop consults :class:`PierSchedule` each step: warmup (global
AdamW) -> momentum accumulation every r steps -> switch to group-local inner
steps -> outer Nesterov sync every r steps, with optional host offload of the
outer state between syncs (§V). With ``sync_delay > 0`` every boundary —
warmup accumulate and outer sync alike — is split into an async dispatch
(overlapping the next inner steps) and a delayed apply flowing through one
in-flight window; a sync controller can re-resolve the delay and switch the
sync strategy mid-run — see DESIGN.md §5/§9.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import (MembershipConfig, ModelConfig, OuterCommConfig,
                          ParallelConfig, TrainConfig)
from repro.configs import get_config, get_reduced_config
from repro.core import offload
from repro.core.pier import PierSchedule
from repro.data.pipeline import synthetic_pipeline
from repro.kernels import backend as kbackend
from repro.launch import mesh as M
from repro.parallel.steps import build_train_steps
from repro.sync import (ChurnSchedule, MembershipController,
                        ModelDelayController, resolve_strategy)


def resolve_auto_sync_delay(tc: TrainConfig, mc: ModelConfig,
                            pc: ParallelConfig, *, chip: str = "") -> int:
    """Resolve ``sync_delay="auto"`` to d* from the overlap step-time model.

    d* is the smallest delay that fully hides the outer collective given
    the mesh and a ``chip`` hint (benchmarks/overlap.py). Warns and falls
    back to 0 (eager) whenever the model has no estimate: no/unknown chip
    hint, or the benchmarks package not importable from this deployment.
    The Trainer itself goes further and *measures* t_comm/t_inner on-line
    (repro/sync/delay.py); this analytic resolution is the fallback and
    the standalone entry point.
    """
    if tc.sync_delay != "auto":
        return tc.sync_delay
    return ModelDelayController(tc, mc, pc, chip=chip).initial_delay()


class Trainer:
    """Host-side training loop weaving inner/outer steps per the schedule.

    Every outer boundary — warmup accumulate and outer sync alike — flows
    through the same single in-flight dispatch/apply window (DESIGN.md
    §9). A :class:`~repro.sync.SyncController` (injected, or built from
    the strategy hook when ``sync_delay="auto"``) is consulted after
    every outer dispatch; its decisions re-resolve the overlap delay
    and/or *switch the sync strategy* mid-run — a switch flushes the
    window and swaps to a per-strategy cached :class:`StepBundle` (the
    re-jit boundary), retargeting the error-feedback residual when the
    residual requirement changes.
    """

    def __init__(self, mc: ModelConfig, tc: TrainConfig, pc: ParallelConfig,
                 mesh, *, checkpoint_dir: Optional[str] = None,
                 chip_hint: str = "", sync_controller=None,
                 adaptive_sync: bool = False, remeasure_every: int = 0,
                 membership=None):
        self.strategy = resolve_strategy(tc)
        # elastic membership (DESIGN.md §11): an injected
        # MembershipController (scripted churn), or one built from
        # tc.membership (full membership through the elastic graphs —
        # bit-identical to the fixed path at all-ones weights). Either
        # way tc.membership gates the elastic step variants in the bundle.
        if membership is not None:
            if membership.num_groups != pc.num_groups:
                raise ValueError(
                    f"membership controller tracks {membership.num_groups} "
                    f"groups but the mesh has {pc.num_groups}")
            if tc.membership is None:
                tc = tc.replace(membership=membership.cfg)
        elif tc.membership is not None:
            membership = MembershipController(
                pc.num_groups, cfg=tc.membership)
        self.membership = membership
        # sync_delay="auto": the strategy injects a SyncController —
        # measured t_comm/t_inner once enough sync windows are observed,
        # the analytic --chip model (or eager) until then; with
        # adaptive_sync the controller may also walk the strategy ladder.
        self.sync_controller = sync_controller
        if self.sync_controller is None and tc.sync_delay == "auto":
            self.sync_controller = self.strategy.make_sync_controller(
                tc, mc, pc, chip=chip_hint, adaptive=adaptive_sync,
                remeasure_every=remeasure_every)
        if tc.sync_delay == "auto":
            dec = self.sync_controller.initial_decision()
            if dec.strategy is not None and dec.strategy != self.strategy:
                self.strategy = dec.strategy
            tc = tc.replace(sync_delay=dec.clamped_delay(tc.sync_interval))
        self.mc, self.tc, self.pc = mc, tc, pc
        self.mesh = mesh
        self.sched = PierSchedule(tc)
        # jitted step bundles are cached per strategy: a controller that
        # switches back to an earlier rung re-uses the compiled steps
        self._bundles = {}
        self.bundle = self._bundle_for(self.strategy)
        self.state = self.bundle.init_state(jax.random.PRNGKey(tc.seed))
        self.outer = self.bundle.init_outer(self.state)
        self.step = 0
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self._outer_on_host = False
        self.history = []
        # the (single) in-flight window, uniform over ops (DESIGN.md §9):
        # (apply_at, "outer", DispatchState | [ChunkDispatch]) or
        # (apply_at, "accumulate", pending OuterState).
        # sync_delay < sync_interval bounds the queue depth at one.
        self._inflight = None
        # the EventMembership record bound to an in-flight *outer*
        # dispatch (None when no membership / accumulate): consumed by
        # its apply for the live mask and the post-apply bootstraps
        self._inflight_member = None
        if tc.offload_outer_state:
            self.outer = offload.to_host(self.outer)
            self._outer_on_host = True

    @property
    def delay_controller(self):
        """Back-compat view: the scalar-delay half of the sync controller
        (None when no controller is installed)."""
        c = self.sync_controller
        return c.delay_controller if c is not None else None

    def _bundle_for(self, strategy):
        b = self._bundles.get(strategy)
        if b is None:
            b = build_train_steps(self.mc, self.tc, self.pc, self.mesh,
                                  strategy=strategy)
            self._bundles[strategy] = b
        return b

    # ------------------------------------------------------------------
    def _outer_to_device(self):
        if self._outer_on_host:
            self.outer = offload.to_device(self.outer)
            self._outer_on_host = False

    def _outer_to_host(self):
        if self.tc.offload_outer_state and not self._outer_on_host:
            self.outer = offload.to_host(self.outer)
            self._outer_on_host = True

    def train_step(self, batch) -> dict:
        """One scheduled step (inner or warmup + its outer events).

        With ``sync_delay == 0`` the dispatch+apply pair that fires at a
        sync boundary is fused into the classic eager ``outer_step`` /
        ``accumulate_step`` — the pre-delay code paths, bit for bit. With
        ``sync_delay > 0`` dispatch enqueues the event's computation
        without blocking the host (jax dispatch is async — no
        ``block_until_ready`` anywhere on this path), so it overlaps the
        next ``sync_delay`` inner steps; apply then installs the result —
        the target with the stale-delta correction for outer events, the
        pending outer state for warmup accumulates.
        """
        sched, tc = self.sched, self.tc
        step = self.step
        phase = sched.phase(step)
        step_arr = jnp.asarray(step, jnp.int32)
        t0 = time.perf_counter()
        if phase == "warmup":
            self.state, metrics = self.bundle.warmup_step(
                self.state, batch, step_arr)
        else:
            self.state, metrics = self.bundle.inner_step(
                self.state, batch, step_arr)
        ctrl = self.sync_controller
        if ctrl is not None and ctrl.wants_measurement:
            # materializing the metrics blocks on the inner step — the
            # wall time is the measured t_inner fed to the controller.
            # Outside the measurement windows the conversion stays at
            # return, off the dispatch-enqueue critical path.
            metrics = {k: float(v) for k, v in metrics.items()}
            ctrl.observe_step(time.perf_counter() - t0)
        events = sched.events(step)
        chunked = self.bundle.chunk_dispatch_steps is not None
        # while the controller still wants t_comm samples the sync must go
        # through dispatch/apply (bit-identical at d=0); once measurement
        # is done a resolved d*=0 takes the fused eager step
        measuring = ctrl is not None and ctrl.wants_measurement
        fused_outer = any(ev.kind == "dispatch" and ev.op == "outer"
                          and ev.apply_step == step for ev in events)
        if fused_outer and not chunked and not measuring:
            # a delay re-resolution to 0 can leave the last measured
            # window's dispatch in flight — install it before the eager step
            self._apply_inflight()
            self._outer_to_device()
            if self.membership is not None:
                rec = self.membership.at(sched.outer_index(step))
                self.state, self.outer = self.bundle.elastic_outer_step(
                    self.state, self.outer,
                    jnp.float32(sched.mu_at(step)),
                    jnp.float32(sched.outer_lr_at(step)),
                    jnp.asarray(rec.weights, jnp.float32),
                    jnp.asarray(rec.apply_live))
                self._bootstrap_groups(rec.bootstrap_after_apply)
            else:
                self.state, self.outer = self.bundle.outer_step(
                    self.state, self.outer,
                    jnp.float32(sched.mu_at(step)),
                    jnp.float32(sched.outer_lr_at(step)))
            self._outer_to_host()
            self._consult_controller()
        else:
            for ev in events:
                if ev.kind == "apply":
                    # the stored apply_step is authoritative: a delay
                    # decision adopted mid-window rebuilds the schedule,
                    # whose re-timed apply event must not cut the
                    # already-dispatched window short
                    if (self._inflight is not None
                            and self._inflight[0] <= step):
                        self._apply_inflight()
                    continue
                # a delay re-resolution may have shrunk the window to
                # nothing — never strand (or double-book) an in-flight
                # dispatch
                self._apply_inflight()
                if ev.op == "accumulate":
                    self._dispatch_accumulate(ev)
                else:
                    dispatch = self._dispatch(step)
                    self._inflight = (ev.apply_step, "outer", dispatch)
                    self._consult_controller()
            # a delay decision can shrink a window below its dispatched
            # length — never let a due apply slip past its step
            if self._inflight is not None and self._inflight[0] <= step:
                self._apply_inflight()
        self.step += 1
        return {k: float(v) for k, v in metrics.items()}

    def _dispatch_accumulate(self, ev):
        """Warmup accumulate as a dispatch/apply pair (DESIGN.md §9).

        Eager (``apply_step == sync_step``): the donating
        ``accumulate_step`` — the pre-delay path, bit for bit. Delayed:
        the non-donating dispatch computes the pending outer state from
        the dispatch-time params; the pre-dispatch state stays live until
        the apply installs the result (whose stale-delta correction is
        identically zero — ``core.outer.warmup_apply``).

        While a measured controller still wants t_comm samples, the
        warmup accumulate windows are wall-clocked too: the accumulate's
        global reduce moves the full-precision Δθ tree, so for an fp32
        strategy its timing is directly representative, and for a
        compressed wire the controller rescales the sample by the modeled
        payload-width ratio (``warmup=True`` →
        :attr:`~repro.sync.delay.MeasuredDelayController.warmup_scale`) —
        either way d* resolves *before* the first post-warmup sync
        instead of burning the first real windows on measurement.
        """
        mu = jnp.float32(self.sched.mu_at(ev.sync_step))
        ctrl = self.sync_controller
        measure = ctrl is not None and ctrl.wants_measurement
        t0 = time.perf_counter() if measure else 0.0
        self._outer_to_device()
        if ev.apply_step <= ev.sync_step:
            self.outer = self.bundle.accumulate_step(
                self.state, self.outer, mu)
            if measure:
                jax.block_until_ready(self.outer.momentum)
            self._outer_to_host()
        else:
            pending = self.bundle.accumulate_dispatch_step(
                self.state, self.outer, mu)
            if measure:
                # overlap is sacrificed for the measured windows only —
                # the same policy the outer dispatch measurement applies
                jax.block_until_ready(pending.momentum)
            self._inflight = (ev.apply_step, "accumulate", pending)
            # the old outer state stays current for the window but is
            # never read again before the apply replaces it wholesale —
            # offload (when configured) can evict it right away instead
            # of holding 2x the outer state on device for d steps
            self._outer_to_host()
        if measure:
            ctrl.observe_window(t_comm=time.perf_counter() - t0,
                                warmup=True)
            # adopt a freshly resolved d* right away (delay only — no
            # tick: strategy decisions stay keyed on *outer* windows, so
            # scripted replays are unaffected by warmup sampling)
            self._adopt_delay(ctrl.current_decision())

    def _dispatch(self, step: int):
        """Launch the outer collective for the sync boundary at ``step``.

        With a chunked strategy plan the Δθ leaf spans dispatch as
        separate XLA computations enqueued back to back (none blocks the
        host), so chunk k's cross-domain reduce overlaps chunk k+1's
        quantization; each chunk carries its own ChunkDispatch, so the
        per-chunk applies later install early chunks while late chunks'
        collectives are still in flight.

        While the controller is measuring, the host blocks on the
        dispatched targets to wall-clock t_comm (overlap is sacrificed for
        those windows only); the decision round itself runs afterwards in
        ``_consult_controller``.
        """
        sched = self.sched
        mu = jnp.float32(sched.mu_at(step))
        olr = jnp.float32(sched.outer_lr_at(step))
        ctrl = self.sync_controller
        measure = ctrl is not None and ctrl.wants_measurement
        t0 = time.perf_counter() if measure else 0.0
        self._outer_to_device()
        if self.bundle.chunk_dispatch_steps is not None:
            chunks, chunk_leaves = [], []
            for chunk_step in self.bundle.chunk_dispatch_steps:
                chunk, leaves = chunk_step(self.state, self.outer, mu, olr)
                chunks.append(chunk)
                chunk_leaves.append(leaves)
            self.outer = self.bundle.stitch_outer(self.outer, chunk_leaves)
            dispatch = chunks  # a list marks the per-chunk in-flight shape
        elif self.membership is not None:
            rec = self.membership.at(sched.outer_index(step))
            dispatch, self.outer = self.bundle.elastic_dispatch_step(
                self.state, self.outer, mu, olr,
                jnp.asarray(rec.weights, jnp.float32))
            self._inflight_member = rec
        else:
            dispatch, self.outer = self.bundle.dispatch_step(
                self.state, self.outer, mu, olr)
        self._outer_to_host()
        if measure:
            jax.block_until_ready(
                [c.targets for c in dispatch] if isinstance(dispatch, list)
                else dispatch.target)
            ctrl.observe_window(t_comm=time.perf_counter() - t0)
        return dispatch

    def _consult_controller(self):
        """One decision round after an outer sync window.

        Ticks the window (feeding ``remeasure_every`` counters), then
        adopts the decision: a strategy switch first (it flushes the
        window just dispatched through the *old* bundle before swapping),
        then the clamped delay for the following windows.
        """
        ctrl = self.sync_controller
        if ctrl is None:
            return
        ctrl.tick_window()
        dec = ctrl.current_decision()
        if dec.strategy is not None and dec.strategy != self.strategy:
            self._switch_strategy(dec.strategy)
        self._adopt_delay(dec)

    def _adopt_delay(self, dec):
        """Adopt a decision's clamped delay (rebuilding the schedule)."""
        d = dec.clamped_delay(self.tc.sync_interval)
        if d != self.tc.sync_delay:
            print(f"sync_delay re-resolved: {self.tc.sync_delay} -> {d} "
                  f"({type(self.sync_controller).__name__} decision)",
                  flush=True)
            self.tc = self.tc.replace(sync_delay=d)
            self.sched = PierSchedule(self.tc)

    def _switch_strategy(self, strategy):
        """Adopt a new outer-sync strategy mid-run (DESIGN.md §9).

        The in-flight window is flushed through the old bundle (its
        payload was produced by the old strategy's jitted steps), the
        per-strategy cached bundle is swapped in (the re-jit boundary),
        and the error-feedback residual is retargeted: materialized at
        zero when the new plan needs one the state lacks, dropped when it
        does not. Momentum/anchor/num_syncs carry over untouched.
        """
        self.flush()
        print(f"outer-sync strategy switch: {self.strategy.name} -> "
              f"{strategy.name}", flush=True)
        self.strategy = strategy
        self.bundle = self._bundle_for(strategy)
        self._outer_to_device()
        need = self.bundle.plan.needs_residual
        if need and self.outer.residual is None:
            self.outer = self.outer._replace(
                residual=self.bundle.init_residual(self.state))
        elif not need and self.outer.residual is not None:
            self.outer = self.outer._replace(residual=None)
        # the rs-ag wire path's second residual retargets the same way
        # (init_residual's zero tree has the right stacked shardings)
        need2 = getattr(self.bundle.plan, "needs_residual2", False)
        if need2 and getattr(self.outer, "residual2", None) is None:
            self.outer = self.outer._replace(
                residual2=self.bundle.init_residual(self.state))
        elif not need2 and getattr(self.outer, "residual2", None) is not None:
            self.outer = self.outer._replace(residual2=None)
        self._outer_to_host()

    def _apply_inflight(self):
        # The schedule emits apply events purely by step count; if flush()
        # already drained the window (checkpoint mid-flight, segmented
        # run()), the event is a no-op rather than a double apply.
        if self._inflight is None:
            return
        _, op, payload = self._inflight
        rec, self._inflight_member = self._inflight_member, None
        if op == "accumulate":
            # install the pending outer state (core.outer.warmup_apply —
            # the warmup stale-delta correction is identically zero)
            self.outer = payload
            self._outer_on_host = False
            self._outer_to_host()
        elif isinstance(payload, list):  # per-chunk apply, span order
            for chunk, apply_step in zip(payload,
                                         self.bundle.chunk_apply_steps):
                self.state = apply_step(self.state, chunk)
        elif rec is not None:
            # elastic apply (DESIGN.md §11): only live groups install the
            # target; then the groups rejoining at the next event
            # bootstrap off the freshly installed anchor (or checkpoint)
            self._inflight = None
            self.state = self.bundle.elastic_apply_step(
                self.state, payload, jnp.asarray(rec.apply_live))
            self._bootstrap_groups(rec.bootstrap_after_apply)
            return
        else:
            self.state = self.bundle.apply_step(self.state, payload)
        self._inflight = None

    def _bootstrap_groups(self, groups):
        """Rejoin bootstrap (DESIGN.md §11), right after an event's apply.

        Each named group's replica is reset to the donor params — the
        freshly installed anchor (exact: the applied target *is* the new
        anchor), or the latest complete checkpoint's anchor when
        ``rejoin_bootstrap="checkpoint"`` — with fresh inner-opt state and
        a zeroed error-feedback residual, so it trains the next window
        coherently and re-enters the mask at the next dispatch boundary.
        """
        if not groups:
            return
        self._outer_to_device()
        donor = self._bootstrap_donor()
        for g in groups:
            self.state, self.outer = self.bundle.bootstrap_group(
                self.state, self.outer, jnp.asarray(g, jnp.int32), donor)
        self._outer_to_host()

    def _bootstrap_donor(self):
        cfg = self.tc.membership
        if (cfg is not None and cfg.rejoin_bootstrap == "checkpoint"
                and self.ckpt is not None):
            latest = self.ckpt.latest_step()
            if latest is not None:
                trees, _ = self.ckpt.restore(latest, {"outer": self.outer})
                return trees["outer"].anchor
        return self.outer.anchor

    def flush(self):
        """Drain an in-flight dispatch (end of run / before checkpoint)."""
        if self._inflight is not None:
            self._apply_inflight()

    def run(self, steps: int, pipeline, *, log_every: int = 10,
            ckpt_every: int = 0):
        t0 = time.time()
        for _ in range(steps):
            batch = next(pipeline)
            metrics = self.train_step(batch)
            self.history.append(metrics)
            if log_every and self.step % log_every == 0:
                dt = (time.time() - t0) / max(self.step, 1)
                print(f"step {self.step:6d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.3f} "
                      f"({dt*1e3:.0f} ms/step avg)", flush=True)
            if ckpt_every and self.ckpt and self.step % ckpt_every == 0:
                self.save()
        self.flush()
        return self.history

    def save(self):
        self.flush()  # a checkpoint must not strand an in-flight dispatch
        self._outer_to_device()
        self.ckpt.save(self.step, {"state": self.state, "outer": self.outer},
                       metadata={"step": self.step,
                                 "optimizer": self.tc.optimizer})
        self._outer_to_host()

    def restore(self, step: Optional[int] = None):
        step = step if step is not None else self.ckpt.latest_step()
        self._outer_to_device()
        trees, meta = self.ckpt.restore(
            step, {"state": self.state, "outer": self.outer},
            shardings={
                "state": jax.tree.map(lambda x: x.sharding, self.state),
                "outer": jax.tree.map(lambda x: x.sharding, self.outer),
            })
        self.state, self.outer = trees["state"], trees["outer"]
        self.step = meta["step"]
        self._inflight = None  # checkpoints are saved flushed
        self._outer_to_host()


def main(argv=None):
    ap = argparse.ArgumentParser(description="Pier training launcher")
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke-scale config")
    ap.add_argument("--optimizer", default="pier",
                    choices=["pier", "diloco", "adamw"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--total-steps", type=int, default=0,
                    help="schedule horizon (defaults to --steps)")
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--sync-interval", type=int, default=10)
    ap.add_argument("--sync-delay", default="0",
                    help="overlap the outer all-reduce with this many "
                         "inner steps (0 = eager; 'auto' = resolve d* from "
                         "the overlap step-time model, needs --chip)")
    ap.add_argument("--chip", default="",
                    help="chip hint for --sync-delay auto "
                         "(e.g. tpu-v5e, a100-perlmutter, gh200-vista)")
    ap.add_argument("--adaptive-sync", action="store_true",
                    help="with --sync-delay auto: let the controller also "
                         "switch the sync strategy down its ladder when "
                         "the measured t_comm stays exposed at the max "
                         "legal delay (DESIGN.md §9)")
    ap.add_argument("--remeasure-every", type=int, default=0,
                    help="re-sample t_comm/t_inner every N sync windows "
                         "after the initial measurement (0 = measure once)")
    ap.add_argument("--outer-compression", default="none",
                    choices=["none", "quantize", "int8-wire", "rs-ag"],
                    help="compress the cross-pod Δθ payload (int8-wire: "
                         "ring-exchange the actual packed q+scales; "
                         "rs-ag: quantized reduce-scatter + all-gather, "
                         "~2/E of the per-device wire bytes)")
    ap.add_argument("--outer-comm-bits", type=int, default=8,
                    choices=[4, 8])
    ap.add_argument("--hierarchical-reduce", action="store_true",
                    help="two-stage outer reduce: fp32 intra-pod, "
                         "compressed cross-pod")
    ap.add_argument("--comm-chunks", type=int, default=1,
                    help="dispatch the Δθ tree as this many separate "
                         "XLA computations")
    ap.add_argument("--sharded-outer", action="store_true",
                    help="exchange only each device's Δθ shard along the "
                         "auto (TP/FSDP) axes, with the outer state "
                         "sharded alongside (DESIGN.md §10)")
    ap.add_argument("--churn-script", default="",
                    help="scripted elastic membership (DESIGN.md §11), "
                         "e.g. 'drop:1@3,rejoin:1@6,straggle:0@4+2' — "
                         "entries keyed on the post-warmup outer event "
                         "ordinal; empty = full membership")
    ap.add_argument("--max-staleness", type=int, default=1,
                    help="straggler tolerance: a group more than this "
                         "many missed outer events behind is evicted "
                         "from the apply cohort until it bootstraps back")
    ap.add_argument("--min-live", type=int, default=1,
                    help="fail fast if the churn script ever leaves "
                         "fewer contributing groups than this")
    ap.add_argument("--rejoin-bootstrap", default="anchor",
                    choices=["anchor", "checkpoint"],
                    help="donor for a rejoining group's params: the "
                         "freshly installed anchor, or the latest "
                         "complete checkpoint (needs --checkpoint-dir)")
    ap.add_argument("--groups", type=int, default=2,
                    help="Pier groups (data_outer)")
    ap.add_argument("--mesh", default="",
                    help="mesh shape e.g. 2,2,2 = data_outer,data_inner,model"
                         " (default: all devices as 1D data_inner)")
    ap.add_argument("--lr", type=float, default=4e-4)
    ap.add_argument("--offload", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-backend", default="",
                    choices=["", "auto", "tpu-mosaic", "gpu-triton",
                             "interpret", "jnp-ref"],
                    help="force the kernel lowering lane "
                         "(kernels/backend.py registry; default: "
                         "REPRO_KERNEL_BACKEND env var or platform "
                         "auto-detect) and apply its environment preset "
                         "before first device use")
    args = ap.parse_args(argv)
    if ((args.adaptive_sync or args.remeasure_every)
            and args.sync_delay != "auto"):
        ap.error("--adaptive-sync/--remeasure-every need --sync-delay auto "
                 "(the measured controller they configure only runs there)")

    if args.kernel_backend and args.kernel_backend != "auto":
        # env preset before the jax.device_count() below triggers backend
        # init (XLA_FLAGS is read exactly once, at init) — append-only,
        # so CI's pre-set --xla_force_host_platform_device_count survives
        preset = M.apply_env_preset(args.kernel_backend)
        if preset["xla_flags_appended"]:
            print("env preset "
                  f"({args.kernel_backend}): appended XLA_FLAGS "
                  + " ".join(preset["xla_flags_appended"]))
        if preset["ld_preload_hint"]:
            print(f"env preset ({args.kernel_backend}): tcmalloc available "
                  f"at {preset['ld_preload_hint']} — export LD_PRELOAD in "
                  f"the wrapper script to use it")
    kbackend.set_kernel_backend(args.kernel_backend or None)

    mc = (get_reduced_config(args.arch) if args.reduced
          else get_config(args.arch))
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
    else:
        n = jax.device_count()
        shape = (args.groups, max(n // args.groups, 1), 1)
    mesh = M.small_mesh(shape, ("data_outer", "data_inner", "model"))
    pc = ParallelConfig(
        data_axis_size=shape[0] * shape[1], model_axis_size=shape[2],
        data_outer=shape[0])
    sync_delay = (args.sync_delay if args.sync_delay == "auto"
                  else int(args.sync_delay))
    tc = TrainConfig(
        optimizer=args.optimizer,
        total_steps=args.total_steps or args.steps,
        global_batch_size=args.global_batch,
        seq_len=args.seq_len,
        sync_interval=args.sync_interval,
        sync_delay=sync_delay,
        inner_lr=args.lr, inner_min_lr=args.lr / 10,
        offload_outer_state=args.offload,
        seed=args.seed,
        lazy_start=args.optimizer != "diloco",
        outer_comm=OuterCommConfig(
            compression=args.outer_compression,
            bits=args.outer_comm_bits,
            hierarchical=args.hierarchical_reduce,
            chunks=args.comm_chunks,
            sharded=args.sharded_outer),
    )
    membership = None
    if args.churn_script:
        mcfg = MembershipConfig(max_staleness=args.max_staleness,
                                min_live=args.min_live,
                                rejoin_bootstrap=args.rejoin_bootstrap)
        tc = tc.replace(membership=mcfg)
        membership = MembershipController(
            pc.num_groups, cfg=mcfg,
            schedule=ChurnSchedule.parse(args.churn_script))
    strategy = resolve_strategy(tc)
    print(f"arch={mc.name} optimizer={tc.optimizer} mesh={shape} "
          f"groups={pc.num_groups} devices={jax.device_count()} "
          f"outer_sync={strategy.name} "
          f"kernel_backend={kbackend.resolve_backend().name} "
          f"transport={strategy.transport_name(mesh)}"
          + (f" churn={args.churn_script}" if args.churn_script else ""))
    trainer = Trainer(mc, tc, pc, mesh,
                      checkpoint_dir=args.checkpoint_dir or None,
                      chip_hint=args.chip,
                      adaptive_sync=args.adaptive_sync,
                      remeasure_every=args.remeasure_every,
                      membership=membership)
    if tc.sync_delay == "auto":
        print(f"sync_delay=auto resolved to d*={trainer.tc.sync_delay} "
              f"(chip={args.chip or 'none'}; re-resolves from measured "
              f"sync windows)")
    pipeline = synthetic_pipeline(mesh, M.data_axes(mesh), mc, trainer.tc)
    try:
        trainer.run(args.steps, pipeline, log_every=args.log_every,
                    ckpt_every=args.ckpt_every)
    finally:
        pipeline.close()
    print(json.dumps({"final_loss": trainer.history[-1]["loss"],
                      "steps": trainer.step}))


if __name__ == "__main__":
    main()
