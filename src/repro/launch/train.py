"""Training launcher: ``python -m repro.launch.train --arch gpt2-small ...``

Runs the full Pier loop on whatever devices are available (CPU host devices
for validation, a real TPU slice in production — the code path is identical).
The host loop consults :class:`PierSchedule` each step: warmup (global
AdamW) -> momentum accumulation every r steps -> switch to group-local inner
steps -> outer Nesterov sync every r steps, with optional host offload of the
outer state between syncs (§V). With ``sync_delay > 0`` the sync is split
into an async dispatch (global Δθ all-reduce overlapping the next inner
steps) and a delayed apply — see DESIGN.md §5.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import (ModelConfig, OuterCommConfig, ParallelConfig,
                          TrainConfig)
from repro.configs import get_config, get_reduced_config
from repro.core import offload
from repro.core.pier import PierSchedule
from repro.data.pipeline import synthetic_pipeline
from repro.launch import mesh as M
from repro.parallel.steps import build_train_steps
from repro.sync import ModelDelayController, resolve_strategy


def resolve_auto_sync_delay(tc: TrainConfig, mc: ModelConfig,
                            pc: ParallelConfig, *, chip: str = "") -> int:
    """Resolve ``sync_delay="auto"`` to d* from the overlap step-time model.

    d* is the smallest delay that fully hides the outer collective given
    the mesh and a ``chip`` hint (benchmarks/overlap.py). Warns and falls
    back to 0 (eager) whenever the model has no estimate: no/unknown chip
    hint, or the benchmarks package not importable from this deployment.
    The Trainer itself goes further and *measures* t_comm/t_inner on-line
    (repro/sync/delay.py); this analytic resolution is the fallback and
    the standalone entry point.
    """
    if tc.sync_delay != "auto":
        return tc.sync_delay
    return ModelDelayController(tc, mc, pc, chip=chip).initial_delay()


class Trainer:
    """Host-side training loop weaving inner/outer steps per the schedule."""

    def __init__(self, mc: ModelConfig, tc: TrainConfig, pc: ParallelConfig,
                 mesh, *, checkpoint_dir: Optional[str] = None,
                 chip_hint: str = ""):
        self.strategy = resolve_strategy(tc)
        # sync_delay="auto": the strategy injects a DelayController —
        # measured t_comm/t_inner once enough sync windows are observed,
        # the analytic --chip model (or eager) until then.
        self.delay_controller = None
        if tc.sync_delay == "auto":
            self.delay_controller = self.strategy.make_delay_controller(
                tc, mc, pc, chip=chip_hint)
            tc = tc.replace(sync_delay=self.delay_controller.initial_delay())
        self.mc, self.tc, self.pc = mc, tc, pc
        self.mesh = mesh
        self.sched = PierSchedule(tc)
        self.bundle = build_train_steps(mc, tc, pc, mesh,
                                        strategy=self.strategy)
        self.state = self.bundle.init_state(jax.random.PRNGKey(tc.seed))
        self.outer = self.bundle.init_outer(self.state)
        self.step = 0
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self._outer_on_host = False
        self.history = []
        # the (single) in-flight delayed dispatch: (apply_at, DispatchState).
        # sync_delay < sync_interval bounds the queue depth at one.
        self._inflight = None
        if tc.offload_outer_state:
            self.outer = offload.to_host(self.outer)
            self._outer_on_host = True

    # ------------------------------------------------------------------
    def _outer_to_device(self):
        if self._outer_on_host:
            self.outer = offload.to_device(self.outer)
            self._outer_on_host = False

    def _outer_to_host(self):
        if self.tc.offload_outer_state and not self._outer_on_host:
            self.outer = offload.to_host(self.outer)
            self._outer_on_host = True

    def train_step(self, batch) -> dict:
        """One scheduled step (inner or warmup + its outer events).

        With ``sync_delay == 0`` the dispatch+apply pair that fires at a
        sync boundary is fused into the classic eager ``outer_step`` — the
        pre-delay code path, bit for bit. With ``sync_delay > 0`` dispatch
        enqueues the global all-reduce without blocking the host (jax
        dispatch is async — no ``block_until_ready`` anywhere on this path),
        so it overlaps the next ``sync_delay`` inner steps; apply then
        installs the target with the stale-delta correction.
        """
        sched, tc = self.sched, self.tc
        step = self.step
        phase = sched.phase(step)
        step_arr = jnp.asarray(step, jnp.int32)
        t0 = time.perf_counter()
        if phase == "warmup":
            self.state, metrics = self.bundle.warmup_step(
                self.state, batch, step_arr)
        else:
            self.state, metrics = self.bundle.inner_step(
                self.state, batch, step_arr)
        if (self.delay_controller is not None
                and self.delay_controller.wants_measurement):
            # materializing the metrics blocks on the inner step — the
            # wall time is the measured t_inner fed to the controller.
            # Outside the measurement windows the conversion stays at
            # return, off the dispatch-enqueue critical path.
            metrics = {k: float(v) for k, v in metrics.items()}
            self.delay_controller.observe_step(time.perf_counter() - t0)
        events = sched.events(step)
        fused = (len(events) == 2 and events[0].kind == "dispatch"
                 and events[1].kind == "apply")
        chunked = self.bundle.chunk_dispatch_steps is not None
        # while the delay controller still wants t_comm samples the sync
        # must go through dispatch/apply (bit-identical at d=0); once
        # measurement is done a resolved d*=0 takes the fused eager step
        measuring = (self.delay_controller is not None
                     and self.delay_controller.wants_measurement)
        if fused and not chunked and not measuring:
            # a delay re-resolution to 0 can leave the last measured
            # window's dispatch in flight — install it before the eager step
            self._apply_inflight()
            self._outer_to_device()
            self.state, self.outer = self.bundle.outer_step(
                self.state, self.outer,
                jnp.float32(sched.mu_at(step)),
                jnp.float32(sched.outer_lr_at(step)))
            self._outer_to_host()
        else:
            for ev in events:
                if ev.kind == "accumulate":
                    self._outer_to_device()
                    self.outer = self.bundle.accumulate_step(
                        self.state, self.outer,
                        jnp.float32(sched.mu_at(step)))
                    self._outer_to_host()
                elif ev.kind == "dispatch":
                    # a delay re-resolution may have shrunk the window to
                    # nothing — never strand (or double-book) an in-flight
                    # dispatch
                    self._apply_inflight()
                    dispatch = self._dispatch(step)
                    apply_at = self.sched.apply_step_for(step)
                    self._inflight = (apply_at, dispatch)
                    if apply_at <= step:
                        self._apply_inflight()
                else:  # apply
                    self._apply_inflight()
        self.step += 1
        return {k: float(v) for k, v in metrics.items()}

    def _dispatch(self, step: int):
        """Launch the outer collective for the sync boundary at ``step``.

        With a chunked strategy plan the Δθ leaf spans dispatch as
        separate XLA computations enqueued back to back (none blocks the
        host), so chunk k's cross-domain reduce overlaps chunk k+1's
        quantization; each chunk carries its own ChunkDispatch, so the
        per-chunk applies later install early chunks while late chunks'
        collectives are still in flight.

        While the delay controller is measuring, the host blocks on the
        dispatched targets to wall-clock t_comm (overlap is sacrificed for
        those windows only) and d* is re-resolved from the EMAs.
        """
        sched = self.sched
        mu = jnp.float32(sched.mu_at(step))
        olr = jnp.float32(sched.outer_lr_at(step))
        ctrl = self.delay_controller
        measure = ctrl is not None and ctrl.wants_measurement
        t0 = time.perf_counter() if measure else 0.0
        self._outer_to_device()
        if self.bundle.chunk_dispatch_steps is not None:
            chunks, chunk_leaves = [], []
            for chunk_step in self.bundle.chunk_dispatch_steps:
                chunk, leaves = chunk_step(self.state, self.outer, mu, olr)
                chunks.append(chunk)
                chunk_leaves.append(leaves)
            self.outer = self.bundle.stitch_outer(self.outer, chunk_leaves)
            dispatch = chunks  # a list marks the per-chunk in-flight shape
        else:
            dispatch, self.outer = self.bundle.dispatch_step(
                self.state, self.outer, mu, olr)
        self._outer_to_host()
        if measure:
            jax.block_until_ready(
                [c.targets for c in dispatch] if isinstance(dispatch, list)
                else dispatch.target)
            ctrl.observe_window(t_comm=time.perf_counter() - t0)
            self._re_resolve_delay()
        return dispatch

    def _re_resolve_delay(self):
        """Adopt the controller's current d* for the following windows."""
        d = self.delay_controller.current_delay()
        if d != self.tc.sync_delay:
            print(f"sync_delay re-resolved: {self.tc.sync_delay} -> {d} "
                  f"(measured t_comm/t_inner)", flush=True)
            self.tc = self.tc.replace(sync_delay=d)
            self.sched = PierSchedule(self.tc)

    def _apply_inflight(self):
        # The schedule emits apply events purely by step count; if flush()
        # already drained the window (checkpoint mid-flight, segmented
        # run()), the event is a no-op rather than a double apply.
        if self._inflight is None:
            return
        _, dispatch = self._inflight
        if isinstance(dispatch, list):  # per-chunk apply, span order
            for chunk, apply_step in zip(dispatch,
                                         self.bundle.chunk_apply_steps):
                self.state = apply_step(self.state, chunk)
        else:
            self.state = self.bundle.apply_step(self.state, dispatch)
        self._inflight = None

    def flush(self):
        """Drain an in-flight dispatch (end of run / before checkpoint)."""
        if self._inflight is not None:
            self._apply_inflight()

    def run(self, steps: int, pipeline, *, log_every: int = 10,
            ckpt_every: int = 0):
        t0 = time.time()
        for _ in range(steps):
            batch = next(pipeline)
            metrics = self.train_step(batch)
            self.history.append(metrics)
            if log_every and self.step % log_every == 0:
                dt = (time.time() - t0) / max(self.step, 1)
                print(f"step {self.step:6d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.3f} "
                      f"({dt*1e3:.0f} ms/step avg)", flush=True)
            if ckpt_every and self.ckpt and self.step % ckpt_every == 0:
                self.save()
        self.flush()
        return self.history

    def save(self):
        self.flush()  # a checkpoint must not strand an in-flight dispatch
        self._outer_to_device()
        self.ckpt.save(self.step, {"state": self.state, "outer": self.outer},
                       metadata={"step": self.step,
                                 "optimizer": self.tc.optimizer})
        self._outer_to_host()

    def restore(self, step: Optional[int] = None):
        step = step if step is not None else self.ckpt.latest_step()
        self._outer_to_device()
        trees, meta = self.ckpt.restore(
            step, {"state": self.state, "outer": self.outer},
            shardings={
                "state": jax.tree.map(lambda x: x.sharding, self.state),
                "outer": jax.tree.map(lambda x: x.sharding, self.outer),
            })
        self.state, self.outer = trees["state"], trees["outer"]
        self.step = meta["step"]
        self._inflight = None  # checkpoints are saved flushed
        self._outer_to_host()


def main(argv=None):
    ap = argparse.ArgumentParser(description="Pier training launcher")
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke-scale config")
    ap.add_argument("--optimizer", default="pier",
                    choices=["pier", "diloco", "adamw"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--total-steps", type=int, default=0,
                    help="schedule horizon (defaults to --steps)")
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--sync-interval", type=int, default=10)
    ap.add_argument("--sync-delay", default="0",
                    help="overlap the outer all-reduce with this many "
                         "inner steps (0 = eager; 'auto' = resolve d* from "
                         "the overlap step-time model, needs --chip)")
    ap.add_argument("--chip", default="",
                    help="chip hint for --sync-delay auto "
                         "(e.g. tpu-v5e, a100-perlmutter, gh200-vista)")
    ap.add_argument("--outer-compression", default="none",
                    choices=["none", "quantize", "int8-wire"],
                    help="compress the cross-pod Δθ payload (int8-wire: "
                         "ring-exchange the actual packed q+scales)")
    ap.add_argument("--outer-comm-bits", type=int, default=8,
                    choices=[4, 8])
    ap.add_argument("--hierarchical-reduce", action="store_true",
                    help="two-stage outer reduce: fp32 intra-pod, "
                         "compressed cross-pod")
    ap.add_argument("--comm-chunks", type=int, default=1,
                    help="dispatch the Δθ tree as this many separate "
                         "XLA computations")
    ap.add_argument("--groups", type=int, default=2,
                    help="Pier groups (data_outer)")
    ap.add_argument("--mesh", default="",
                    help="mesh shape e.g. 2,2,2 = data_outer,data_inner,model"
                         " (default: all devices as 1D data_inner)")
    ap.add_argument("--lr", type=float, default=4e-4)
    ap.add_argument("--offload", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mc = (get_reduced_config(args.arch) if args.reduced
          else get_config(args.arch))
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
    else:
        n = jax.device_count()
        shape = (args.groups, max(n // args.groups, 1), 1)
    mesh = M.small_mesh(shape, ("data_outer", "data_inner", "model"))
    pc = ParallelConfig(
        data_axis_size=shape[0] * shape[1], model_axis_size=shape[2],
        data_outer=shape[0])
    sync_delay = (args.sync_delay if args.sync_delay == "auto"
                  else int(args.sync_delay))
    tc = TrainConfig(
        optimizer=args.optimizer,
        total_steps=args.total_steps or args.steps,
        global_batch_size=args.global_batch,
        seq_len=args.seq_len,
        sync_interval=args.sync_interval,
        sync_delay=sync_delay,
        inner_lr=args.lr, inner_min_lr=args.lr / 10,
        offload_outer_state=args.offload,
        seed=args.seed,
        lazy_start=args.optimizer != "diloco",
        outer_comm=OuterCommConfig(
            compression=args.outer_compression,
            bits=args.outer_comm_bits,
            hierarchical=args.hierarchical_reduce,
            chunks=args.comm_chunks),
    )
    print(f"arch={mc.name} optimizer={tc.optimizer} mesh={shape} "
          f"groups={pc.num_groups} devices={jax.device_count()} "
          f"outer_sync={resolve_strategy(tc).name}")
    trainer = Trainer(mc, tc, pc, mesh,
                      checkpoint_dir=args.checkpoint_dir or None,
                      chip_hint=args.chip)
    if tc.sync_delay == "auto":
        print(f"sync_delay=auto resolved to d*={trainer.tc.sync_delay} "
              f"(chip={args.chip or 'none'}; re-resolves from measured "
              f"sync windows)")
    pipeline = synthetic_pipeline(mesh, M.data_axes(mesh), mc, trainer.tc)
    try:
        trainer.run(args.steps, pipeline, log_every=args.log_every,
                    ckpt_every=args.ckpt_every)
    finally:
        pipeline.close()
    print(json.dumps({"final_loss": trainer.history[-1]["loss"],
                      "steps": trainer.step}))


if __name__ == "__main__":
    main()
