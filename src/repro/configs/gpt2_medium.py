"""GPT-2 medium (345M) — the paper's own evaluation model (Table I / §VI).

24L d_model=1024 16H d_ff=4096 vocab=50304, LayerNorm + GELU + learned
positions (GPT-2 family).
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="gpt2-medium",
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=50_304,
        attention_kind="gqa",
        positional="learned",
        max_position_embeddings=4096,
        norm="layernorm",
        activation="gelu",
        tie_embeddings=True,
        source="Pier paper Table I / GPT-2",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="gpt2-medium-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        max_position_embeddings=1024,
    )
