"""RecurrentGemma 9B (Griffin hybrid: RG-LRU + local attention, 2:1)
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, local window 2048.
Griffin pattern: two recurrent (RG-LRU) blocks followed by one local-attention
block. Sub-quadratic: runs long_500k natively (O(1) LRU state + windowed KV).
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256_000,
        attention_kind="gqa",
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048,
        lru_width=4096,
        conv1d_width=4,
        norm="rmsnorm",
        activation="gelu",  # GeGLU in Griffin; gated handled in layers
        logit_softcap=30.0,
        source="arXiv:2402.19427",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="recurrentgemma-9b-reduced",
        num_layers=3,  # one full rglru/rglru/local_attn cycle
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        d_ff=512,
        vocab_size=512,
        local_window=64,
        lru_width=256,
    )
