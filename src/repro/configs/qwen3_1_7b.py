"""Qwen3 1.7B (dense, GQA + qk-norm) [hf:Qwen/Qwen3-8B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, head_dim=128.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151_936,
        attention_kind="gqa",
        use_qk_norm=True,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="qwen3-1.7b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
