"""IBM Granite 8B code model (dense, llama-arch) [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=49_152,
        attention_kind="gqa",
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=10_000_000.0,
        source="arXiv:2405.04324",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="granite-8b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
    )
