"""Chameleon 34B (early-fusion VLM) [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. Early fusion: image
content enters as VQ-VAE code tokens sharing the text vocabulary, so the
backbone is a standard decoder; the VQ tokenizer frontend is stubbed per the
assignment (``input_specs`` provides interleaved token ids). Chameleon uses
qk-norm for training stability.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=65_536,
        attention_kind="gqa",
        use_qk_norm=True,
        norm="rmsnorm",
        activation="swiglu",
        source="arXiv:2405.09818",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="chameleon-34b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
    )
