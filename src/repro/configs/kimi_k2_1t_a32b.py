"""Kimi K2 — trillion-parameter MoE, 32B active [arXiv:2501.kimi2 per
assignment table].

61L d_model=7168 64H (GQA kv=8) moe_d_ff=2048 vocab=163840,
384 routed experts top-8 + 1 shared expert, first layer dense.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=18432,  # dense MLP width for the leading dense layer
        vocab_size=163_840,
        attention_kind="gqa",
        num_experts=384,
        num_experts_per_tok=8,
        num_shared_experts=1,
        moe_d_ff=2048,
        first_dense_layers=1,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=50_000.0,
        source="arXiv:2501.kimi2 (assignment table)",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="kimi-k2-1t-a32b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=1,
        moe_d_ff=128,
        first_dense_layers=1,
    )
