"""DeepSeek-V2 236B (MoE, MLA) [arXiv:2405.04434].

60L d_model=5120 128H d_ff(moe)=1536 vocab=102400; MLA kv_lora_rank=512,
2 shared + 160 routed experts, top-6. The first layer uses a dense MLP
(d_ff=12288) per the model card.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,  # MLA: all heads share the latent kv cache
        d_ff=12288,  # dense MLP for the leading dense layer
        vocab_size=102_400,
        attention_kind="mla",
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_rope_head_dim=64,
        qk_nope_head_dim=128,
        v_head_dim=128,
        num_experts=160,
        num_experts_per_tok=6,
        num_shared_experts=2,
        moe_d_ff=1536,
        first_dense_layers=1,
        norm="rmsnorm",
        activation="swiglu",
        source="arXiv:2405.04434",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="deepseek-v2-236b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=512,
        kv_lora_rank=64,
        q_lora_rank=96,
        qk_rope_head_dim=16,
        qk_nope_head_dim=32,
        v_head_dim=32,
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=1,
        moe_d_ff=128,
        first_dense_layers=1,
    )
