"""GPT-2 XL (1.5B) — the paper's own evaluation model (Table I / §VI).

48L d_model=1600 25H d_ff=6400 vocab=50304, LayerNorm + GELU + learned
positions (GPT-2 family).
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="gpt2-xl",
        family="dense",
        num_layers=48,
        d_model=1600,
        num_heads=25,
        num_kv_heads=25,
        d_ff=6400,
        vocab_size=50_304,
        attention_kind="gqa",
        positional="learned",
        max_position_embeddings=4096,
        norm="layernorm",
        activation="gelu",
        tie_embeddings=True,
        source="Pier paper Table I / GPT-2",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="gpt2-xl-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        max_position_embeddings=1024,
    )
