"""GPT-2 small (125M) — the paper's own evaluation model (Table I).

12L d_model=768 12H d_ff=3072 vocab=50304 (padded to a multiple of 128, as
Megatron-LM does), LayerNorm + GELU + learned positions.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="gpt2-small",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=50_304,
        attention_kind="gqa",
        positional="learned",
        max_position_embeddings=4096,
        norm="layernorm",
        activation="gelu",
        tie_embeddings=True,
        source="Pier paper Table I / GPT-2",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="gpt2-small-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        max_position_embeddings=1024,
    )
