"""Whisper large-v3 (audio encoder-decoder) [arXiv:2212.04356].

32L (enc) + 32L (dec) d_model=1280 20H (MHA) d_ff=5120 vocab=51866.
The mel-spectrogram + conv feature extractor frontend is STUBBED per the
assignment: ``input_specs`` provides precomputed frame embeddings of shape
(batch, encoder_seq_len, d_model). Pre-LN transformer with learned positions
and GELU, per the original architecture.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,  # decoder layers
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51_866,
        attention_kind="gqa",
        positional="learned",
        max_position_embeddings=448 * 128,  # extended for the assigned shapes
        is_encoder_decoder=True,
        encoder_layers=32,
        encoder_seq_len=1500,
        norm="layernorm",
        activation="gelu",
        source="arXiv:2212.04356",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="whisper-large-v3-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        encoder_layers=2,
        encoder_seq_len=64,
        max_position_embeddings=4096,
    )
