"""xLSTM 1.3B (SSM-family: sLSTM + mLSTM blocks) [arXiv:2405.04517].

48L d_model=2048 4H vocab=50304, attention-free. We use the paper's 7:1
mLSTM:sLSTM block ratio. Sub-quadratic: runs long_500k natively (O(1)
matrix-memory decode state).
"""

from repro.config import ModelConfig

# 7 mLSTM blocks then 1 sLSTM block, cycled over the 48 layers.
_PATTERN = ("mlstm",) * 7 + ("slstm",)


def model_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,  # mLSTM/sLSTM blocks carry their own up/down projections
        vocab_size=50_304,
        attention_kind="none",
        positional="none",
        block_pattern=_PATTERN,
        mlstm_chunk=64,
        norm="rmsnorm",
        activation="swiglu",
        source="arXiv:2405.04517",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="xlstm-1.3b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        vocab_size=512,
        block_pattern=("mlstm", "slstm"),
        mlstm_chunk=16,
    )
