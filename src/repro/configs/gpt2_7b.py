"""GPT-2 7B (paper §VI-B3, DP+TP) — the paper's own evaluation model (Table I / §VI).

32L d_model=4096 32H d_ff=16384 vocab=50304, LayerNorm + GELU + learned
positions (GPT-2 family).
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="gpt2-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=16384,
        vocab_size=50_304,
        attention_kind="gqa",
        positional="learned",
        max_position_embeddings=4096,
        norm="layernorm",
        activation="gelu",
        tie_embeddings=True,
        source="Pier paper Table I / GPT-2",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="gpt2-7b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        max_position_embeddings=1024,
    )
