"""MiniCPM 2B (dense, llama-like, WSD schedule) [arXiv:2404.06395].

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753, tied embeddings.
The WSD (warmup-stable-decay) *inner* LR schedule is available as
``TrainConfig.lr_schedule="wsd"``.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        num_layers=40,
        d_model=2304,
        num_heads=36,
        num_kv_heads=36,
        d_ff=5760,
        vocab_size=122_753,
        attention_kind="gqa",
        tie_embeddings=True,
        norm="rmsnorm",
        activation="swiglu",
        source="arXiv:2404.06395",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="minicpm-2b-reduced",
        num_layers=2,
        d_model=288,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
    )
