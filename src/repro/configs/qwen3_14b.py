"""Qwen3 14B (dense, GQA + qk-norm) [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, head_dim=128,
per-head RMS qk-norm.
"""

from repro.config import ModelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151_936,
        attention_kind="gqa",
        use_qk_norm=True,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        activation="swiglu",
        source="hf:Qwen/Qwen3-8B",
    )


def reduced_config() -> ModelConfig:
    return model_config().replace(
        name="qwen3-14b-reduced",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
    )
