"""Architecture configuration registry.

Every assigned architecture (and the paper's own GPT-2 family) registers a
full-scale :class:`~repro.config.ModelConfig` plus a ``reduced`` smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) that runs a real step on CPU.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

# Assigned pool (10) + paper's own models.
ARCH_MODULES = [
    "deepseek_v2_236b",
    "granite_8b",
    "minicpm_2b",
    "qwen3_14b",
    "qwen3_1_7b",
    "xlstm_1_3b",
    "chameleon_34b",
    "recurrentgemma_9b",
    "whisper_large_v3",
    "kimi_k2_1t_a32b",
    # paper's evaluation models (GPT-2 family, Table I)
    "gpt2_small",
    "gpt2_medium",
    "gpt2_xl",
    "gpt2_7b",
]

# canonical display names (as in the assignment table)
_DISPLAY = {
    "qwen3_1_7b": "qwen3-1.7b",
    "xlstm_1_3b": "xlstm-1.3b",
}
_CANONICAL = {_DISPLAY.get(m, m.replace("_", "-")): m for m in ARCH_MODULES}
# accept a few alternate spellings
_ALIASES = {
    "qwen3-1-7b": "qwen3_1_7b",
    "xlstm-1-3b": "xlstm_1_3b",
}


def _module_for(name: str):
    key = name.replace("_", "-").lower()
    mod = _ALIASES.get(key) or _CANONICAL.get(key)
    if mod is None:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(_CANONICAL)}"
        )
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    """Full-scale config for ``--arch <name>``."""
    return _module_for(name).model_config()


def get_reduced_config(name: str) -> ModelConfig:
    """Reduced same-family smoke variant (2 layers, d_model<=512, <=4 experts)."""
    return _module_for(name).reduced_config()


def list_architectures() -> List[str]:
    return sorted(_CANONICAL)


def assigned_architectures() -> List[str]:
    """The ten assigned-pool architectures (excludes the GPT-2 family)."""
    return [_DISPLAY.get(m, m.replace("_", "-"))
            for m in ARCH_MODULES if not m.startswith("gpt2")]


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in list_architectures()}
