from repro.data.synthetic import MarkovLM, make_train_batch  # noqa: F401
from repro.data.pipeline import DataPipeline  # noqa: F401
