"""Deterministic synthetic language-model data.

Pretraining-convergence benchmarks need data with *learnable structure* so
that optimizer differences show up in the loss curve (pure-random tokens have
a constant-entropy floor reached immediately). We use a sparse first-order
Markov chain over the vocabulary: each token has ``branching`` possible
successors with Dirichlet-distributed probabilities. The achievable loss
floor is the chain's conditional entropy; how fast an optimizer approaches it
mirrors the paper's validation-loss comparisons (Figs. 1, 3, 4).

Everything is seeded and pure-jnp, so batches are reproducible across
processes — group ``g`` always sees stream ``seed + g``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


class MarkovLM:
    def __init__(self, vocab_size: int, *, seed: int = 0, branching: int = 8,
                 concentration: float = 0.5):
        self.vocab_size = vocab_size
        self.branching = min(branching, vocab_size)
        rng = np.random.default_rng(seed)
        succ = np.stack([
            rng.choice(vocab_size, size=self.branching, replace=False)
            for _ in range(vocab_size)
        ])  # (V, B) successor ids
        probs = rng.dirichlet(
            np.full(self.branching, concentration), size=vocab_size)
        self._succ = jnp.asarray(succ, jnp.int32)
        self._probs = jnp.asarray(probs, jnp.float32)
        self._logp = jnp.log(self._probs)

    @property
    def entropy(self) -> float:
        """Conditional entropy in nats = the achievable loss floor."""
        h = -np.sum(np.asarray(self._probs) * np.log(np.asarray(self._probs)),
                    axis=-1)
        return float(np.mean(h))

    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def sample(self, key, batch: int, seq_len: int) -> jax.Array:
        """(batch, seq_len + 1) token walk."""
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch,), 0, self.vocab_size)

        def step(tok, k):
            idx = jax.random.categorical(k, self._logp[tok], axis=-1)
            nxt = jnp.take_along_axis(
                self._succ[tok], idx[:, None], axis=1)[:, 0]
            return nxt, nxt

        keys = jax.random.split(k1, seq_len)
        _, walk = jax.lax.scan(step, first, keys)
        return jnp.concatenate([first[:, None], walk.T], axis=1)


def make_train_batch(lm: MarkovLM, key, batch: int, seq_len: int):
    """{"tokens": (B, S), "labels": (B, S)} next-token pairs."""
    toks = lm.sample(key, batch, seq_len)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
