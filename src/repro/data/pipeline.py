"""Sharded input pipeline for the mesh trainer.

Produces global device arrays laid out over the mesh's data axes with
background prefetch. Each Pier group consumes a *disjoint* slice of the
stream (the group's data-parallel shard), matching the paper's Megatron
data loader semantics.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, TrainConfig
from repro.data.synthetic import MarkovLM


class DataPipeline:
    """Iterator of sharded training batches.

    Args:
      mesh: the (refined) device mesh.
      batch_axes: mesh axis name(s) sharding dim 0 of every array.
      make_batch: fn(step) -> dict of host numpy arrays (global shape).
      prefetch: number of batches to stage ahead.
    """

    def __init__(
        self,
        mesh: Mesh,
        batch_axes,
        make_batch: Callable[[int], Dict[str, np.ndarray]],
        *,
        prefetch: int = 2,
    ):
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.make_batch = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._step = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _shard(self, batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            full = P(self.batch_axes, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, full))
        return out

    def _producer(self):
        step = 0
        while not self._stop.is_set():
            batch = self.make_batch(step)
            try:
                self._q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        batch = self._q.get()
        self._step += 1
        return self._shard(batch)

    def close(self):
        self._stop.set()


def synthetic_pipeline(
    mesh: Mesh,
    batch_axes,
    mc: ModelConfig,
    tc: TrainConfig,
    *,
    seq_len: Optional[int] = None,
    global_batch: Optional[int] = None,
) -> DataPipeline:
    """Markov-LM pipeline producing {"tokens", "labels"} batches."""
    lm = MarkovLM(min(mc.vocab_size, 2048), seed=tc.seed)
    S = seq_len or tc.seq_len
    B = global_batch or tc.global_batch_size

    def make(step: int) -> Dict[str, np.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(tc.seed), step)
        toks = np.asarray(lm.sample(key, B, S))
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    return DataPipeline(mesh, batch_axes, make)
