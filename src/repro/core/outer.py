"""The Pier outer optimizer (Algorithms 1 & 2 of the paper).

The outer "gradient" is the averaged model delta ``Δθ = θ_t − θ_{t−r}``
(already globally all-reduced by the caller). Three formulations:

- ``nesterov_torch`` (the paper's choice, §V): PyTorch's approximated
  Nesterov —  ``M ← μM + Δθ;  θ ← θ_anchor + lr·(μM + Δθ)``  (Alg. 2 l.20-21).
- ``nesterov_classic``: Nesterov's original look-ahead form, which in the
  delta-space reduces to using the *pre-update* momentum for the correction:
  ``θ ← θ_anchor + lr·(μ·M_old + (1+μ−μ)·Δθ)`` with ``M ← μM + Δθ`` — the
  paper implements both and reports the torch variant converges better.
- ``sgd``: plain momentum SGD, ``θ ← θ_anchor + lr·M``.

Note the **sign convention**: Δθ points in the *improvement* direction
(it is the result of inner optimization), so the outer step *adds* it —
equivalently the outer gradient is −Δθ fed to a standard minimizer.

**Delayed (overlapped) sync** splits the eager update into two halves so the
global all-reduce can run concurrently with subsequent inner steps:

- :func:`outer_reduce` — consume the globally averaged Δθ: advance the
  momentum and produce the synchronized *target* ``θ_anchor + lr·step``.
  This is everything that depends on the collective's result.
- :func:`outer_apply` — install the target ``sync_delay`` steps later with
  the stale-delta correction ``θ ← target + (θ_t − θ_dispatch)``: inner
  progress made while the collective was in flight is preserved on top of
  the synchronized model (it is *also* measured by the next Δθ, which is
  taken against the target-anchor — transient local retention, counted
  globally exactly once).

:func:`outer_update` composes the two with zero drift — the eager path.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class OuterState(NamedTuple):
    momentum: Any  # M pytree (fp32 by default)
    anchor: Any  # θ_{t-r}: model snapshot at the last sync
    num_syncs: jax.Array  # () int32 — how many outer steps have been taken
    # Error-feedback residual of the compressed outer collective (DESIGN.md
    # §6): what blockwise quantization dropped from each group's payload,
    # re-injected into the next Δθ so the error telescopes instead of
    # biasing the Nesterov momentum. ``None`` (an empty pytree node) when
    # ``outer_compression == "none"`` — the state is then structurally
    # identical to the pre-compression layout. When present: fp32 leaves of
    # param shape with a leading ``num_groups`` axis (group-local, unlike
    # the replicated momentum/anchor).
    residual: Any = None
    # Second error-feedback residual for the reduce-scatter + all-gather
    # wire path (DESIGN.md §14): what re-quantizing the *reduced shard*
    # before the gather leg dropped. Same layout as ``residual`` (fp32,
    # leading ``num_groups`` axis), but each group's leaf is nonzero only
    # on its own 1/E payload shard — the slot the reduce-scatter delivered
    # to it. ``None`` unless the strategy's plan sets ``needs_residual2``.
    residual2: Any = None


def outer_init(params, tc: TrainConfig, *, num_groups: int = 1,
               needs_residual: Optional[bool] = None,
               needs_residual2: bool = False) -> OuterState:
    """``needs_residual`` defaults from the config's own strategy; pass it
    explicitly when an injected strategy overrides the config (the runner
    keys its specs off the strategy plan, and the state must match)."""
    dt = jnp.dtype(tc.opt_state_dtype)
    if needs_residual is None:
        needs_residual = tc.outer_comm.compression != "none"
    zeros_g = lambda p: jnp.zeros((num_groups, *p.shape), jnp.float32)  # noqa: E731
    residual = jax.tree.map(zeros_g, params) if needs_residual else None
    residual2 = jax.tree.map(zeros_g, params) if needs_residual2 else None
    return OuterState(
        momentum=jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        anchor=jax.tree.map(lambda p: p.astype(dt), params),
        num_syncs=jnp.zeros((), jnp.int32),
        residual=residual,
        residual2=residual2,
    )


def warmup_reduce(state: OuterState, params, mu) -> OuterState:
    """Algorithm 1, lines 5-6: Δθ = θ_t − θ_{t−r};  M ← μM + Δθ.

    The *dispatch half* of the warmup accumulate event (DESIGN.md §9),
    analogous to :func:`outer_reduce`: everything that depends on the
    dispatch-time model — the delta against the anchor, the momentum
    advance, and the anchor moving to θ_t — computed from ``params`` as
    snapshotted at the sync boundary. The momentum is accumulated but NOT
    applied; the returned state is *pending* until :func:`warmup_apply`
    installs it ``sync_delay`` steps later (same call, eagerly, on the
    d = 0 path).
    """
    sdt = jax.tree.leaves(state.momentum)[0].dtype

    def acc(m, p, a):
        delta = p.astype(jnp.float32) - a.astype(jnp.float32)
        return (mu * m.astype(jnp.float32) + delta).astype(sdt)

    new_m = jax.tree.map(acc, state.momentum, params, state.anchor)
    new_anchor = jax.tree.map(lambda p, a: p.astype(a.dtype), params, state.anchor)
    return OuterState(momentum=new_m, anchor=new_anchor,
                      num_syncs=state.num_syncs + 1,
                      residual=state.residual,
                      residual2=state.residual2)


def warmup_apply(pending: OuterState) -> OuterState:
    """Install a dispatched warmup accumulation — the *apply half*.

    The warmup stale-delta correction is **identically zero**, by the
    following argument (the analogue of :func:`outer_apply`'s drift term):
    the accumulate touches only the outer state, never the params, and
    nothing reads the outer state inside the in-flight window — the next
    boundary (accumulate or first post-warmup dispatch) is ``sync_interval``
    steps after this one, and every window closes in ``sync_delay <
    sync_interval`` steps. The anchor deliberately snapshots the
    *dispatch-time* θ_t (not the apply-time θ_{t+d}): inner progress made
    while the event was in flight stays ahead of the anchor and is measured
    by the *next* Δθ — counted exactly once, exactly as the eager schedule
    counts it. (Advancing the anchor at apply time instead would silently
    drop those ``d`` steps of progress from the next delta.) Hence a
    warmup-overlapped run is bit-identical to eager warmup, not merely
    within tolerance — asserted by tests/test_event_engine.py.
    """
    return pending


def warmup_accumulate(state: OuterState, params, mu) -> OuterState:
    """Eager fused warmup accumulate (sync_delay = 0): reduce then apply
    with an empty in-flight window — the historical single-event API,
    bit-identical to :func:`warmup_reduce` composed with
    :func:`warmup_apply` at any delay."""
    return warmup_apply(warmup_reduce(state, params, mu))


def quant_fns(*, bits: int, block: int, use_pallas: bool = False):
    """(quantize, dequantize) callables for the outer payload — the one
    place the pallas-vs-reference quantizer choice is made (shared by
    :func:`compress_delta` and the ``Int8Wire`` wire strategy, so the
    backend selection cannot drift between them)."""
    if use_pallas:
        from repro.kernels import ops as kops
        return (lambda x: kops.quantize_blockwise(x, bits=bits, block=block),
                lambda q, s: kops.dequantize_blockwise(q, s, block=block))
    from repro.kernels.ref import (dequantize_blockwise_ref,
                                   quantize_blockwise_ref)
    return (lambda x: quantize_blockwise_ref(x, bits=bits, block=block),
            lambda q, s: dequantize_blockwise_ref(q, s, block=block))


def compress_delta(delta, residual, tc: TrainConfig = None, *,
                   bits: int = None, block: int = None,
                   use_pallas: bool = False):
    """Blockwise-quantize one group's Δθ payload with error feedback.

    Per leaf (fp32):  c = Δθ + residual;  (q, s) = Q(c);  payload = DQ(q, s);
    residual' = c − payload.  The *payload* (the dequantized value — the
    numeric simulation of what int8+scales put on the wire) is what the
    caller exchanges over the slow domain; ``payload + residual' == c``
    exactly per round, so the error telescopes across syncs instead of
    accumulating in the momentum.

    ``residual=None`` means a zero residual (first sync / stateless use).
    ``bits``/``block`` default from ``tc`` (the legacy call shape); the
    Quantized strategy passes them explicitly.
    Returns (payload_tree_f32, new_residual_tree_f32).
    """
    if bits is None:
        bits = tc.outer_comm.bits
    if block is None:
        block = tc.outer_comm.block
    quant, dequant = quant_fns(bits=bits, block=block, use_pallas=use_pallas)

    def leaf(d, r):
        c = d.astype(jnp.float32)
        if r is not None:
            c = c + r.astype(jnp.float32)
        flat = c.reshape(-1)
        q, s = quant(flat)
        payload = dequant(q, s)[: flat.shape[0]].reshape(c.shape)
        return payload, c - payload

    flat_d, treedef = jax.tree_util.tree_flatten(delta)
    flat_r = (treedef.flatten_up_to(residual) if residual is not None
              else [None] * len(flat_d))
    out = [leaf(d, r) for d, r in zip(flat_d, flat_r)]
    unf = jax.tree_util.tree_unflatten
    return (unf(treedef, [p for p, _ in out]),
            unf(treedef, [r for _, r in out]))


_UNSET = object()


def outer_reduce(
    state: OuterState,
    delta_avg,  # globally averaged Δθ pytree (fp32)
    tc: TrainConfig,
    *,
    mu,  # momentum coefficient (schedule of Alg. 2)
    lr,  # outer LR (schedule of §V)
    use_pallas: bool = False,
    residual=_UNSET,  # new error-feedback residual to store (default: keep)
    residual2=_UNSET,  # new gather-leg residual to store (default: keep)
):
    """Algorithm 2, lines 19-21. Returns (target_params_f32, new_state).

    The target comes back in fp32; :func:`outer_apply` (or the caller, on
    the eager path) casts to the param dtype and re-broadcasts. The new
    state's anchor IS the target, so the next Δθ measures progress from the
    synchronized model. With ``use_pallas`` the fused update kernel is used
    (single HBM pass over θ/M/Δθ — see kernels/pier_update.py).
    """
    new_residual = state.residual if residual is _UNSET else residual
    new_residual2 = state.residual2 if residual2 is _UNSET else residual2

    flat, treedef = jax.tree_util.tree_flatten(state.momentum)
    a_flat = treedef.flatten_up_to(state.anchor)
    d_flat = treedef.flatten_up_to(delta_avg)
    p_new, m_new, anchor_new = outer_reduce_leaves(
        flat, a_flat, d_flat, tc, mu=mu, lr=lr, use_pallas=use_pallas)
    unf = jax.tree_util.tree_unflatten
    new_params = unf(treedef, p_new)
    new_state = OuterState(
        momentum=unf(treedef, m_new),
        anchor=unf(treedef, anchor_new),
        num_syncs=state.num_syncs + 1,
        residual=new_residual,
        residual2=new_residual2,
    )
    return new_params, new_state


def outer_reduce_leaves(m_leaves, a_leaves, d_leaves, tc: TrainConfig, *,
                        mu, lr, use_pallas: bool = False):
    """Algorithm 2 lines 19-21 on an explicit leaf span.

    The per-leaf math of :func:`outer_reduce`, factored out so the chunked
    strategy can run it per contiguous Δθ span (each chunk's own XLA
    computation) with numerics shared — bitwise — with the fused path.
    Returns ``(target_leaves_f32, new_momentum_leaves, new_anchor_leaves)``.
    """
    if not m_leaves:
        return [], [], []
    sdt = jnp.dtype(m_leaves[0].dtype)
    if use_pallas:
        from repro.kernels import ops as kops

        p_new, m_new = [], []
        for m, a, d in zip(m_leaves, a_leaves, d_leaves):
            p, mm = kops.pier_update_leaf(a, m, d, tc, mu=mu, lr=lr)
            p_new.append(p)
            m_new.append(mm)
        return p_new, m_new, [p.astype(sdt) for p in p_new]

    form = tc.outer_optimizer

    def upd(m, a, d):
        mf = m.astype(jnp.float32)
        af = a.astype(jnp.float32)
        df = d.astype(jnp.float32)
        m_new = mu * mf + df
        if form == "nesterov_torch":
            step = mu * m_new + df
        elif form == "nesterov_classic":
            step = mu * mf + df
        elif form == "sgd":
            step = m_new
        else:
            raise ValueError(f"unknown outer optimizer {form!r}")
        p_new = af + lr * step
        return p_new, m_new.astype(sdt)

    p_new, m_new = [], []
    for m, a, d in zip(m_leaves, a_leaves, d_leaves):
        p, mm = upd(m, a, d)
        p_new.append(p)
        m_new.append(mm)
    return p_new, m_new, [p.astype(sdt) for p in p_new]


def outer_apply(target_f32, dispatch_params, current_params):
    """Install a dispatched target with the stale-delta correction.

    ``θ ← target + (θ_t − θ_dispatch)`` per leaf, in fp32, cast back to the
    current param dtype. When ``current_params is dispatch_params`` (the
    eager path) the correction is exactly zero and the result is bit-equal
    to the target: IEEE-754 guarantees ``x − x == +0.0`` and ``t + 0.0 == t``
    for finite ``t``.
    """

    def apply(t, pd, pt):
        drift = pt.astype(jnp.float32) - pd.astype(jnp.float32)
        return (t + drift).astype(pt.dtype)

    return jax.tree.map(apply, target_f32, dispatch_params, current_params)


def outer_update(
    state: OuterState,
    delta_avg,
    tc: TrainConfig,
    *,
    mu,
    lr,
    use_pallas: bool = False,
    residual=_UNSET,
    residual2=_UNSET,
):
    """Eager fused update (sync_delay=0): reduce with zero in-flight drift.

    Returns (new_params_f32, new_state) — the historical single-event API;
    kept because the simulator, distributed steps, and tests compose it
    directly on the d=0 path.
    """
    return outer_reduce(state, delta_avg, tc, mu=mu, lr=lr,
                        use_pallas=use_pallas, residual=residual,
                        residual2=residual2)
