from repro.core.outer import (  # noqa: F401
    OuterState,
    outer_init,
    outer_update,
    warmup_accumulate,
)
from repro.core.pier import PierSchedule  # noqa: F401
