from repro.core.outer import (  # noqa: F401
    OuterState,
    outer_init,
    outer_update,
    warmup_accumulate,
    warmup_apply,
    warmup_reduce,
)
from repro.core.pier import OuterEvent, PierSchedule  # noqa: F401
