"""Pier schedule logic: phase selection, outer events, momentum decay, LR.

The host training loop consults :class:`PierSchedule` each step to decide
which jitted step function to run (warmup / inner) and which *outer events*
fire after it — this mirrors the paper's Megatron integration where the outer
sync is woven into the main training loop at interval boundaries (§V).

The unified outer-event engine (DESIGN.md §9): **every** outer event —
warmup momentum accumulation and post-warmup outer sync alike — is a
dispatch/apply pair carrying its own ``apply_step``:

- ``dispatch`` — launch the event's computation at the sync boundary
  ``sync_step``. For ``op == "outer"`` this is the global Δθ all-reduce +
  Nesterov math (Alg. 2); for ``op == "accumulate"`` the momentum-warmup
  accumulation (Alg. 1) reading the dispatch-time params. With
  ``sync_delay > 0`` the computation overlaps the following inner steps.
- ``apply`` — install the dispatched result ``sync_delay`` steps later
  (same step when 0): the synchronized target with the stale-delta
  correction for ``op == "outer"``, the pending outer state for
  ``op == "accumulate"`` (whose correction is identically zero — see
  ``core/outer.py:warmup_apply``).

``sync_delay = 0`` degenerates to dispatch+apply on the same step, which the
runners fuse into the classic eager events — bit-identical to the
pre-delay code path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal, Tuple

from repro.config import TrainConfig

Phase = Literal["warmup", "inner"]

OuterOp = Literal["accumulate", "outer"]


@dataclass(frozen=True)
class OuterEvent:
    """One outer-engine event fired after the inner update of a step.

    ``sync_step`` is the boundary the event belongs to (where its dispatch
    fires); ``apply_step`` is the step whose inner update its apply
    follows — ``sync_step + delay`` for both halves of the pair, so either
    half alone identifies the full window.
    """

    kind: Literal["dispatch", "apply"]
    op: OuterOp
    sync_step: int
    apply_step: int


@dataclass(frozen=True)
class PierSchedule:
    tc: TrainConfig

    # ---------------------------------------------------------- phase logic
    def phase(self, step: int) -> Phase:
        """Which inner step runs at ``step`` (0-based)."""
        if self.tc.optimizer == "adamw":
            return "warmup"  # AdamW baseline = global sync every step
        if self.tc.optimizer == "diloco" and not self.tc.lazy_start:
            return "inner"
        return "warmup" if step < self.warmup_steps else "inner"

    @property
    def warmup_steps(self) -> int:
        if self.tc.optimizer == "adamw":
            return self.tc.total_steps
        if self.tc.optimizer == "diloco" and not self.tc.lazy_start:
            return 0
        return self.tc.warmup_steps

    def is_sync_step(self, step: int) -> bool:
        """True if an outer event fires AFTER the inner update at ``step``.

        During warmup the event is momentum accumulation (Alg. 1 line 4,
        Pier only); after warmup it is the outer optimizer step (Alg. 2).
        """
        if self.tc.optimizer == "adamw":
            return False
        if (step + 1) % self.tc.sync_interval != 0:
            return False
        if step < self.warmup_steps:
            # momentum warmup accumulation — Pier only (DiLoCo lazy-starts
            # without accumulating)
            return self.tc.momentum_warmup
        return True

    def sync_kind(self, step: int) -> str:
        """Legacy spelling of :meth:`op_at` (kept for callers/tests)."""
        return self.op_at(step)

    # ------------------------------------------------------- event model
    def op_at(self, step: int) -> OuterOp:
        """Which outer op the boundary at ``step`` performs."""
        return "accumulate" if step < self.warmup_steps else "outer"

    def is_dispatch_step(self, step: int) -> bool:
        """True if a post-warmup outer dispatch fires after ``step``."""
        return self.is_sync_step(step) and self.sync_kind(step) == "outer"

    def delay_for(self, sync_step: int) -> int:
        """Per-event delay of the boundary at ``sync_step``.

        Today uniform (``tc.sync_delay`` for accumulate and outer events
        alike — the same ``< sync_interval`` bound closes every window
        before the next boundary, including across the warmup→inner
        transition); kept as a seam so a controller/schedule can
        differentiate per-op delays without touching the event stream.
        """
        return self.tc.sync_delay

    def apply_step_for(self, dispatch_step: int) -> int:
        """The step whose inner update the ``dispatch_step`` apply follows."""
        return dispatch_step + self.delay_for(dispatch_step)

    def events(self, step: int) -> Tuple[OuterEvent, ...]:
        """Outer events fired after the inner update at ``step``, in order.

        At most two events fire per step, and only with ``sync_delay == 0``
        can they share a boundary (dispatch immediately followed by its own
        apply — the fused eager path). ``sync_delay < sync_interval``
        guarantees an apply always precedes the next dispatch — for
        accumulate and outer events alike, including across the
        warmup→inner transition (boundaries are ``sync_interval`` apart in
        every phase) — so the in-flight window never holds more than one
        outstanding dispatch.
        """
        evs = []
        # apply lands first: it belongs to an older dispatch (d > 0), or to
        # the dispatch emitted this very step (d == 0, handled below).
        for s0 in range(max(step - self.tc.sync_interval + 1, 0), step):
            if (self.is_sync_step(s0)
                    and self.apply_step_for(s0) == step):
                evs.append(OuterEvent("apply", self.op_at(s0), s0, step))
        if self.is_sync_step(step):
            op = self.op_at(step)
            a = self.apply_step_for(step)
            evs.append(OuterEvent("dispatch", op, step, a))
            if a == step:
                evs.append(OuterEvent("apply", op, step, step))
        return tuple(evs)

    # ------------------------------------------------------------ schedules
    def mu_at(self, step: int) -> float:
        """Momentum-decay schedule (Alg. 2 lines 12-18). DiLoCo: fixed 0.9."""
        if self.tc.optimizer == "diloco":
            return self.tc.outer_momentum
        return self.tc.mu_at(step)

    def outer_lr_at(self, step: int) -> float:
        """Outer LR schedule (§V). DiLoCo: fixed (paper recommends 0.7)."""
        if self.tc.optimizer == "diloco":
            return self.tc.fixed_outer_lr
        return self.tc.outer_lr_at(step)

    def outer_index(self, dispatch_step: int) -> int:
        """0-based ordinal of the post-warmup outer dispatch at ``step``.

        The elastic-membership churn schedule (DESIGN.md §11) keys its
        drop/rejoin/straggle entries on this ordinal — "outer event k"
        means the k-th post-warmup ``outer`` dispatch boundary, counting
        from 0 — so scripts stay meaningful across delay/interval
        changes. Raises on a step that is not an outer dispatch boundary.
        """
        if not (self.is_sync_step(dispatch_step)
                and self.op_at(dispatch_step) == "outer"):
            raise ValueError(
                f"step {dispatch_step} is not a post-warmup outer "
                f"dispatch boundary")
        w = self.warmup_steps
        return (dispatch_step - w) // self.tc.sync_interval

    # -------------------------------------------------------------- helpers
    def num_outer_steps(self) -> int:
        post = self.tc.total_steps - self.warmup_steps
        return post // self.tc.sync_interval

    def global_comm_fraction(self) -> float:
        """Fraction of steps that require global (cross-group) communication.

        This is the quantity Pier optimizes: AdamW = 1.0; Pier/DiLoCo = 1/r
        after warmup (plus the warmup phase itself).
        """
        if self.tc.optimizer == "adamw":
            return 1.0
        w = self.warmup_steps / max(self.tc.total_steps, 1)
        return w + (1 - w) / self.tc.sync_interval
