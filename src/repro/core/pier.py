"""Pier schedule logic: phase selection, outer events, momentum decay, LR.

The host training loop consults :class:`PierSchedule` each step to decide
which jitted step function to run (warmup / inner) and which *outer events*
fire after it — this mirrors the paper's Megatron integration where the outer
sync is woven into the main training loop at interval boundaries (§V).

Outer events (the delayed-sync event model, see DESIGN.md):

- ``accumulate`` — momentum-warmup accumulation (Alg. 1), warmup phase only.
- ``dispatch``   — launch the global Δθ all-reduce + Nesterov math for the
  sync boundary at ``sync_step``. With ``sync_delay > 0`` the collective
  overlaps the following inner steps.
- ``apply``      — install the synchronized target computed by the dispatch
  from ``sync_step`` (fires ``sync_delay`` steps later; same step when 0).

``sync_delay = 0`` degenerates to dispatch+apply on the same step, which the
runners fuse into the classic eager outer step — bit-identical to the
pre-delay code path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal, Tuple

from repro.config import TrainConfig

Phase = Literal["warmup", "inner"]


@dataclass(frozen=True)
class OuterEvent:
    """One outer-optimizer event fired after the inner update of a step."""

    kind: Literal["accumulate", "dispatch", "apply"]
    sync_step: int  # the sync boundary (dispatch step) this event belongs to


@dataclass(frozen=True)
class PierSchedule:
    tc: TrainConfig

    # ---------------------------------------------------------- phase logic
    def phase(self, step: int) -> Phase:
        """Which inner step runs at ``step`` (0-based)."""
        if self.tc.optimizer == "adamw":
            return "warmup"  # AdamW baseline = global sync every step
        if self.tc.optimizer == "diloco" and not self.tc.lazy_start:
            return "inner"
        return "warmup" if step < self.warmup_steps else "inner"

    @property
    def warmup_steps(self) -> int:
        if self.tc.optimizer == "adamw":
            return self.tc.total_steps
        if self.tc.optimizer == "diloco" and not self.tc.lazy_start:
            return 0
        return self.tc.warmup_steps

    def is_sync_step(self, step: int) -> bool:
        """True if an outer event fires AFTER the inner update at ``step``.

        During warmup the event is momentum accumulation (Alg. 1 line 4,
        Pier only); after warmup it is the outer optimizer step (Alg. 2).
        """
        if self.tc.optimizer == "adamw":
            return False
        if (step + 1) % self.tc.sync_interval != 0:
            return False
        if step < self.warmup_steps:
            # momentum warmup accumulation — Pier only (DiLoCo lazy-starts
            # without accumulating)
            return self.tc.momentum_warmup
        return True

    def sync_kind(self, step: int) -> str:
        return "accumulate" if step < self.warmup_steps else "outer"

    # ------------------------------------------------------- event model
    def is_dispatch_step(self, step: int) -> bool:
        """True if a post-warmup outer dispatch fires after ``step``."""
        return self.is_sync_step(step) and self.sync_kind(step) == "outer"

    def apply_step_for(self, dispatch_step: int) -> int:
        """The step whose inner update the ``dispatch_step`` apply follows."""
        return dispatch_step + self.tc.sync_delay

    def events(self, step: int) -> Tuple[OuterEvent, ...]:
        """Outer events fired after the inner update at ``step``, in order.

        At most two events fire per step, and only with ``sync_delay == 0``
        can they coincide (dispatch immediately followed by its own apply —
        the fused eager path). ``sync_delay < sync_interval`` guarantees an
        apply always precedes the next dispatch, so the in-flight window
        never holds more than one outstanding Δθ.
        """
        evs = []
        d = self.tc.sync_delay
        # apply lands first: it belongs to an older dispatch (d > 0), or to
        # the dispatch emitted this very step (d == 0, handled below).
        if d > 0 and step - d >= 0 and self.is_dispatch_step(step - d):
            evs.append(OuterEvent("apply", step - d))
        if self.is_sync_step(step):
            if self.sync_kind(step) == "accumulate":
                evs.append(OuterEvent("accumulate", step))
            else:
                evs.append(OuterEvent("dispatch", step))
                if d == 0:
                    evs.append(OuterEvent("apply", step))
        return tuple(evs)

    # ------------------------------------------------------------ schedules
    def mu_at(self, step: int) -> float:
        """Momentum-decay schedule (Alg. 2 lines 12-18). DiLoCo: fixed 0.9."""
        if self.tc.optimizer == "diloco":
            return self.tc.outer_momentum
        return self.tc.mu_at(step)

    def outer_lr_at(self, step: int) -> float:
        """Outer LR schedule (§V). DiLoCo: fixed (paper recommends 0.7)."""
        if self.tc.optimizer == "diloco":
            return self.tc.fixed_outer_lr
        return self.tc.outer_lr_at(step)

    # -------------------------------------------------------------- helpers
    def num_outer_steps(self) -> int:
        post = self.tc.total_steps - self.warmup_steps
        return post // self.tc.sync_interval

    def global_comm_fraction(self) -> float:
        """Fraction of steps that require global (cross-group) communication.

        This is the quantity Pier optimizes: AdamW = 1.0; Pier/DiLoCo = 1/r
        after warmup (plus the warmup phase itself).
        """
        if self.tc.optimizer == "adamw":
            return 1.0
        w = self.warmup_steps / max(self.tc.total_steps, 1)
        return w + (1 - w) / self.tc.sync_interval
