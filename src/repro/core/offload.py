"""Host-memory offload of the outer-optimizer state (paper §V).

Between outer steps the anchor model ``θ_{t−r}`` and the momentum ``M`` are
dead weight in HBM (they are touched once every ``r`` inner steps). The paper
offloads them to host memory; on TPU the equivalent is JAX memory kinds:
``device_put`` onto a sharding with ``memory_kind="pinned_host"``.

Each device offloads only its own shard (the paper's "avoid redundant data
movement" note) — this falls out for free because we offload the sharded
arrays as-is, preserving their sharding but switching the memory kind.

On backends without pinned_host support (the CPU validation backend),
offload degrades to a no-op and ``supports_offload()`` reports False; the
switch semantics (`TrainConfig.offload_outer_state`) are identical.
"""

from __future__ import annotations

import functools
from typing import Any

import jax


@functools.cache
def supports_offload() -> bool:
    try:
        dev = jax.devices()[0]
        kinds = getattr(dev, "addressable_memories", lambda: [])()
        return any(m.kind == "pinned_host" for m in kinds)
    except Exception:
        return False


def _with_memory_kind(sharding, kind: str):
    return sharding.with_memory_kind(kind)


def to_host(tree: Any) -> Any:
    """Move a pytree of arrays to pinned host memory (keeps sharding)."""
    if not supports_offload():
        return tree

    def move(x):
        if not isinstance(x, jax.Array):
            return x
        return jax.device_put(x, _with_memory_kind(x.sharding, "pinned_host"))

    return jax.tree.map(move, tree)


def to_device(tree: Any) -> Any:
    """Bring an offloaded pytree back to device HBM."""
    if not supports_offload():
        return tree

    def move(x):
        if not isinstance(x, jax.Array):
            return x
        return jax.device_put(x, _with_memory_kind(x.sharding, "device"))

    return jax.tree.map(move, tree)


def offload_bytes(tree: Any) -> int:
    """HBM bytes freed by offloading ``tree`` (for the memory report)."""
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
        if hasattr(x, "size")
    )
