"""Single-host multi-group simulation of Pier / DiLoCo / AdamW.

For the convergence experiments (paper Figs. 1, 3, 4; Tables III, IV) the
group structure is *algorithmic*, not physical: we hold one model replica per
group stacked on the leading axis and ``vmap`` the inner AdamW step over it.
This executes Algorithm 2 bit-for-bit (including the momentum warmup phase,
the μ decay schedule, and the outer Nesterov step) without needing a mesh —
groups see disjoint data streams exactly as the distributed runner shards
them.

The distributed (shard_map) path in ``repro.parallel.steps`` is semantically
identical; tests assert the two agree step-for-step on a tiny model.

The unified outer-event stream (DESIGN.md §9: every boundary — warmup
accumulate and post-warmup outer sync alike — is a dispatch/apply pair
with a per-event ``apply_step``) is executed exactly as the host loop
would: with ``sync_delay > 0`` the dispatched result is held in the
(single) in-flight window and installed at its ``apply_step`` — the
synchronized target with the stale-delta correction for outer events,
the pending outer state for warmup accumulates (whose correction is
identically zero, see ``core/outer.py:warmup_apply``) — so
delayed-schedule convergence can be measured without a mesh.

An optional :class:`~repro.sync.SyncController` is consulted after every
outer dispatch (``tick_window`` + ``current_decision``), mirroring the
Trainer: a strategy decision flushes the window and re-plans/re-jits the
dispatch (:meth:`SimulatedRun.switch_strategy`), a delay decision
rebuilds the schedule — so controller-driven runs (scripted or adaptive)
can be replayed bit-for-bit against the distributed path.

The outer collective is consumed as a pluggable strategy object
(``repro/sync/``, DESIGN.md §7), resolved from the config exactly as the
distributed runner resolves it. The numeric models match the distributed
path: ``Quantized`` blockwise-quantizes (and *dequantizes* — exactly the
value an int8+scales wire format delivers) each group's Δθ plus its
error-feedback residual before averaging; ``Int8Wire`` additionally
models the ring exchange's **per-source-scale sum semantics** exactly —
the per-group dequantized payloads accumulate in canonical source order
and scale by ``1/E``, the same sequential sum the distributed ring runs,
so the sim ↔ distributed equivalence binds bit for bit at the reduce
(DESIGN.md §8); ``Hierarchical`` with ``num_pods > 1`` first averages
the per-group deltas full-precision inside each pod (the fast domain),
so only the per-pod payloads are quantized and exchanged (the ring's
endpoints become the pods, one representative each). The ``Chunked``
combinator has no numeric effect on dispatch, but the simulator honours
its plan at *apply* time: each leaf span installs through its own
per-chunk apply (in any order — the ordering property tests permute
them), mirroring the distributed per-chunk apply pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.core.outer import (OuterState, outer_apply, outer_init,
                              warmup_apply, warmup_reduce)
from repro.core.pier import PierSchedule
from repro.sync import resolve_strategy, validate_pod_grouping
from repro.data.synthetic import MarkovLM, make_train_batch
from repro.models import registry as R
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import lr_at


@dataclass
class SimState:
    params: Any  # single replica (warmup) -- kept in sync with groups
    group_params: Optional[Any]  # (G, ...) stacked replicas, post-switch
    opt: Any  # AdamWState (single or stacked)
    outer: OuterState
    step: int = 0


class SimulatedRun:
    def __init__(self, mc: ModelConfig, tc: TrainConfig, *, num_groups: int,
                 seed: int = 0, num_pods: int = 1, strategy=None,
                 sync_controller=None, membership=None,
                 checkpoint_manager=None):
        if tc.optimizer != "adamw":
            assert num_groups >= 1
        validate_pod_grouping(num_groups, num_pods)
        assert isinstance(tc.sync_delay, int), (
            "sync_delay='auto' must be resolved before simulation "
            "(see launch/train.py)")
        self.mc, self.tc = mc, tc
        self.G = num_groups
        self.P = max(num_pods, 1)
        self.strategy = (strategy if strategy is not None
                         else resolve_strategy(tc))
        self.sync_controller = sync_controller
        # elastic membership (DESIGN.md §11): a MembershipController whose
        # per-event records drive the weighted dispatch, the masked apply
        # and the rejoin bootstrap; checkpoint_manager is the optional
        # donor source for rejoin_bootstrap="checkpoint"
        self.membership = membership
        self.ckpt = checkpoint_manager
        if membership is not None and membership.num_groups != num_groups:
            raise ValueError(
                f"membership controller tracks {membership.num_groups} "
                f"groups but the run has {num_groups}")
        self.sched = PierSchedule(tc)
        self.lm = MarkovLM(mc.vocab_size, seed=1234)
        key = jax.random.PRNGKey(seed)
        params = R.init_params(key, mc)
        # the host-side dispatch plan: leaf spans for per-chunk apply;
        # also decides whether the state carries an EF residual (an
        # injected strategy may override the config's own resolution)
        self.plan = self.strategy.plan(params, tc)
        if membership is not None and self.plan.num_chunks > 1:
            raise NotImplementedError(
                "elastic membership does not compose with chunked "
                "dispatch yet (per-chunk weighted applies are a recorded "
                "follow-up) — drop chunking or membership")
        self.state = SimState(
            params=params,
            group_params=None,
            opt=adamw_init(params, tc),
            outer=outer_init(params, tc, num_groups=num_groups,
                             needs_residual=self.plan.needs_residual,
                             needs_residual2=getattr(
                                 self.plan, "needs_residual2", False)),
        )
        self._val_batch = make_train_batch(
            self.lm, jax.random.PRNGKey(99991), 16, tc.seq_len)

        # ---- jitted steps ----
        def sgd_step(params, opt, batch, step):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: R.loss_fn(p, mc, batch), has_aux=True)(params)
            grads, gnorm = clip_by_global_norm(grads, tc.clip_grad)
            lr = lr_at(tc, step)
            new_params, new_opt = adamw_update(grads, opt, params, tc, lr)
            return new_params, new_opt, loss

        self._warmup_step = jax.jit(sgd_step)
        self._inner_step = jax.jit(
            jax.vmap(sgd_step, in_axes=(0, 0, 0, None)))
        self._val_loss = jax.jit(
            lambda p: R.loss_fn(p, mc, self._val_batch)[0])

        def do_accumulate(outer, params, mu):
            """The dispatch half of a warmup accumulate (Alg. 1): reads
            the dispatch-time params; the result is pending until its
            apply installs it (``warmup_apply`` — correction is zero)."""
            return warmup_reduce(outer, params, mu)

        self._accumulate = jax.jit(do_accumulate)
        self._build_dispatch()

        def do_apply(target_f32, dispatch_group, current_group):
            """Install the target on every group with the drift correction.

            target is unstacked; the (G, ...) snapshot/current leaves
            broadcast against it, so each group keeps its own in-flight
            progress. Eager (d=0) calls this with dispatch == current:
            the correction is exactly zero.
            """
            return outer_apply(target_f32, dispatch_group, current_group)

        self._apply = jax.jit(do_apply)

        def do_apply_masked(target_f32, dispatch_group, current_group, live):
            """Elastic apply (DESIGN.md §11): install the target only on
            the live groups; an absent/evicted group keeps its stale
            params until its rejoin bootstrap."""
            new = outer_apply(target_f32, dispatch_group, current_group)

            def mask(n, o):
                lg = live.reshape((live.shape[0],) + (1,) * (n.ndim - 1))
                return jnp.where(lg, n, o)

            return jax.tree.map(mask, new, current_group)

        self._apply_masked = jax.jit(do_apply_masked)
        # the (single) in-flight window, uniform over ops (DESIGN.md §9):
        # (apply_at_step, "outer", target, snapshot) or
        # (apply_at_step, "accumulate", pending_outer, None)
        self._inflight = None
        # the EventMembership record bound to an in-flight *outer*
        # dispatch (None when full membership / accumulate): consumed by
        # its apply for the live mask and the post-apply bootstraps
        self._inflight_member = None

    # ------------------------------------------------------------------
    def _build_dispatch(self):
        """(Re-)jit the outer dispatch off the current strategy's plan.

        Called at construction and again on every
        :meth:`switch_strategy` — the re-jit boundary is the strategy
        object itself (its plan keys the span structure; its
        ``sim_dispatch`` the reduce numerics).
        """
        strategy, tc, P = self.strategy, self.tc, self.P

        def do_dispatch(group_params, outer, mu, lr, weights):
            """Global Δθ mean + Nesterov math -> (target_f32, new outer).

            Delegates to the resolved strategy: FlatFP32 is the seed path,
            bit for bit; Quantized/Hierarchical mirror the distributed
            two-stage reduce (per-group Δθ -> optional full-precision
            intra-pod mean -> optional quantize+dequantize with error
            feedback -> global mean of the payloads). ``weights`` is the
            (G,) elastic participation vector (None = classic 1/G mean,
            bit for bit); with weights the reduce normalizes by 1/Σw —
            identical at all-ones by construction.
            """
            return strategy.sim_dispatch(group_params, outer, tc,
                                         mu=mu, lr=lr, num_pods=P,
                                         weights=weights)

        self._dispatch = jax.jit(do_dispatch)

    def switch_strategy(self, strategy):
        """Adopt a new outer-sync strategy mid-run (DESIGN.md §9).

        Flushes the in-flight window (a dispatched result from the old
        strategy must install through the old plan), re-plans and re-jits
        the dispatch, and retargets the error-feedback residual: zeros
        when the new strategy needs one the state lacks (first-sync
        semantics of ``compress_delta(residual=None)``), dropped when it
        does not. Momentum/anchor/num_syncs carry over untouched.
        """
        if strategy == self.strategy:
            return
        self.flush()
        self.strategy = strategy
        st = self.state
        self.plan = strategy.plan(st.params, self.tc)
        self._build_dispatch()
        outer = st.outer
        if self.plan.needs_residual and outer.residual is None:
            st.outer = outer._replace(residual=jax.tree.map(
                lambda p: jnp.zeros((self.G, *p.shape), jnp.float32),
                st.params))
        elif not self.plan.needs_residual and outer.residual is not None:
            st.outer = outer._replace(residual=None)
        # the rs-ag wire path's second residual retargets the same way
        need2 = getattr(self.plan, "needs_residual2", False)
        if need2 and st.outer.residual2 is None:
            st.outer = st.outer._replace(residual2=jax.tree.map(
                lambda p: jnp.zeros((self.G, *p.shape), jnp.float32),
                st.params))
        elif not need2 and st.outer.residual2 is not None:
            st.outer = st.outer._replace(residual2=None)

    def _consult_controller(self):
        """One controller round after an outer dispatch (mirrors the
        Trainer): tick the window, then adopt the decision — strategy
        first (flushes the window just dispatched), then the clamped
        delay for the following windows."""
        ctrl = self.sync_controller
        if ctrl is None:
            return
        ctrl.tick_window()
        dec = ctrl.current_decision()
        if dec.strategy is not None and dec.strategy != self.strategy:
            self.switch_strategy(dec.strategy)
        d = dec.clamped_delay(self.tc.sync_interval)
        if d != self.tc.sync_delay:
            self.tc = self.tc.replace(sync_delay=d)
            self.sched = PierSchedule(self.tc)

    # ------------------------------------------------------------------
    def _global_batch(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.tc.seed), step)
        return make_train_batch(
            self.lm, key, self.tc.global_batch_size, self.tc.seq_len)

    def _group_batches(self, step: int):
        """(G, b, S) disjoint slices of the same global batch."""
        b = self._global_batch(step)
        G = self.G
        per = self.tc.global_batch_size // G
        return jax.tree.map(
            lambda x: x[: G * per].reshape(G, per, *x.shape[1:]), b)

    def _switch_to_groups(self):
        st = self.state
        stack = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.G, *x.shape)), t)
        st.group_params = stack(st.params)
        st.opt = stack(st.opt)

    # ------------------------------------------------------------------
    def run(self, num_steps: int, *, eval_every: int = 0) -> Dict[str, List]:
        """Run ``num_steps`` and return the loss history."""
        hist = {"step": [], "train_loss": [], "val_loss": [], "val_step": []}
        tc, st = self.tc, self.state
        for _ in range(num_steps):
            # re-read per step: a controller decision may rebuild the
            # schedule (delay) mid-run
            sched = self.sched
            step = st.step
            phase = sched.phase(step)
            if phase == "warmup":
                batch = self._global_batch(step)
                st.params, st.opt, loss = self._warmup_step(
                    st.params, st.opt, batch, jnp.asarray(step))
                if (not sched.is_sync_step(step)
                        and (step + 1) % tc.sync_interval == 0):
                    # DiLoCo lazy start: advance the anchor without
                    # accumulating momentum
                    st.outer = OuterState(
                        momentum=st.outer.momentum,
                        anchor=jax.tree.map(
                            lambda p, a: p.astype(a.dtype),
                            st.params, st.outer.anchor),
                        num_syncs=st.outer.num_syncs,
                        residual=st.outer.residual,
                        residual2=st.outer.residual2)
            else:
                if st.group_params is None:
                    self._switch_to_groups()
                batches = self._group_batches(step)
                st.group_params, st.opt, losses = self._inner_step(
                    st.group_params, st.opt, batches, jnp.asarray(step))
                loss = jnp.mean(losses)
            for ev in sched.events(step):
                if ev.kind == "apply":
                    # the stored apply_step is authoritative: a delay
                    # decision adopted mid-window must not cut the
                    # already-dispatched window short via the rebuilt
                    # schedule's re-timed apply event
                    if (self._inflight is not None
                            and self._inflight[0] <= step):
                        self._apply_inflight()
                    continue
                # dispatch (either op): the window is free by the schedule
                # invariant; drain defensively anyway
                self._apply_inflight()
                mu = jnp.float32(sched.mu_at(step))
                if ev.op == "accumulate":
                    pending = self._accumulate(st.outer, st.params, mu)
                    self._inflight = (ev.apply_step, "accumulate",
                                      pending, None)
                else:
                    olr = jnp.float32(sched.outer_lr_at(step))
                    rec, w = None, None
                    if self.membership is not None:
                        rec = self.membership.at(sched.outer_index(step))
                        w = jnp.asarray(rec.weights, jnp.float32)
                    target, st.outer = self._dispatch(
                        st.group_params, st.outer, mu, olr, w)
                    self._inflight = (ev.apply_step, "outer", target,
                                      st.group_params)
                    self._inflight_member = rec
                    self._consult_controller()
            # a delay decision can shrink a window below its dispatched
            # length — never let a due apply slip past its step
            if self._inflight is not None and self._inflight[0] <= step:
                self._apply_inflight()
            hist["step"].append(step)
            hist["train_loss"].append(float(loss))
            if eval_every and (step + 1) % eval_every == 0:
                p = (jax.tree.map(lambda g: g[0], st.group_params)
                     if st.group_params is not None else st.params)
                hist["val_loss"].append(float(self._val_loss(p)))
                hist["val_step"].append(step)
            st.step += 1
        return hist

    def _apply_inflight(self, order=None):
        # No-op when flush() already drained the window — the schedule's
        # apply event is step-based and does not know about early drains.
        #
        # Accumulate events install their pending outer state (the
        # warmup stale-delta correction is identically zero — see
        # core/outer.py:warmup_apply). Outer events install the target
        # into the params: with a chunked plan each leaf span installs
        # through its own per-chunk apply — in ``order`` (span indices;
        # default span order), modeling the distributed per-chunk
        # pipeline where early chunks land while late chunks are still in
        # flight. Spans are disjoint and the correction is per-leaf, so
        # every order is bit-identical (asserted by the ordering property
        # tests).
        if self._inflight is None:
            return
        st = self.state
        _, op, target, snapshot = self._inflight
        rec, self._inflight_member = self._inflight_member, None
        if op == "accumulate":
            st.outer = warmup_apply(target)
            self._inflight = None
            return
        spans = self.plan.spans
        if rec is not None:
            # elastic apply: only live groups install the target; then
            # the groups rejoining at the next event bootstrap off the
            # freshly installed anchor (or the latest checkpoint)
            live = jnp.asarray(rec.apply_live)
            st.group_params = self._apply_masked(
                target, snapshot, st.group_params, live)
            i0 = rec.apply_live.index(True)
            st.params = jax.tree.map(lambda g: g[i0], st.group_params)
            self._inflight = None
            for g in rec.bootstrap_after_apply:
                self._bootstrap_group(g)
            return
        if len(spans) == 1:
            st.group_params = self._apply(target, snapshot, st.group_params)
        else:
            t_flat, treedef = jax.tree_util.tree_flatten(target)
            s_flat = treedef.flatten_up_to(snapshot)
            c_flat = treedef.flatten_up_to(st.group_params)
            for ci in (order if order is not None else range(len(spans))):
                lo, hi = spans[ci]
                new = self._apply(tuple(t_flat[lo:hi]),
                                  tuple(s_flat[lo:hi]),
                                  tuple(c_flat[lo:hi]))
                c_flat[lo:hi] = list(new)
            st.group_params = jax.tree_util.tree_unflatten(treedef, c_flat)
        st.params = jax.tree.map(lambda g: g[0], st.group_params)
        self._inflight = None

    def _bootstrap_group(self, g: int):
        """Rejoin bootstrap (DESIGN.md §11).

        Runs right after an event's apply: group ``g``'s replica is reset
        to the donor params — the freshly installed anchor (exact: the
        applied target *is* the new anchor, ``outer_reduce`` sets
        ``anchor_new = target``), or the latest complete checkpoint when
        ``rejoin_bootstrap="checkpoint"`` and a manager is attached — with
        fresh inner-opt state and a zeroed error-feedback residual, so it
        trains the next window coherently and re-enters the mask at the
        next dispatch boundary.
        """
        st = self.state
        donor = None
        if (self.membership is not None
                and self.membership.cfg.rejoin_bootstrap == "checkpoint"
                and self.ckpt is not None):
            latest = self.ckpt.latest_step()
            if latest is not None:
                trees, _ = self.ckpt.restore(latest, {"params": st.params})
                donor = trees["params"]
        if donor is None:
            donor = st.outer.anchor
        st.group_params = jax.tree.map(
            lambda gp, d: gp.at[g].set(d.astype(gp.dtype)),
            st.group_params, donor)
        fresh = adamw_init(
            jax.tree.map(lambda gp: gp[g], st.group_params), self.tc)
        st.opt = jax.tree.map(
            lambda og, f: og.at[g].set(f.astype(og.dtype)), st.opt, fresh)
        if st.outer.residual is not None:
            st.outer = st.outer._replace(residual=jax.tree.map(
                lambda r: r.at[g].set(jnp.zeros_like(r[g])),
                st.outer.residual))
        if st.outer.residual2 is not None:
            st.outer = st.outer._replace(residual2=jax.tree.map(
                lambda r: r.at[g].set(jnp.zeros_like(r[g])),
                st.outer.residual2))

    def flush(self):
        """Apply an in-flight dispatch early (end-of-run drain)."""
        if self._inflight is not None:
            self._apply_inflight()

    def eval_params(self):
        st = self.state
        if st.group_params is not None:
            return jax.tree.map(
                lambda g: jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype),
                st.group_params)
        return st.params
