"""Single-host multi-group simulation of Pier / DiLoCo / AdamW.

For the convergence experiments (paper Figs. 1, 3, 4; Tables III, IV) the
group structure is *algorithmic*, not physical: we hold one model replica per
group stacked on the leading axis and ``vmap`` the inner AdamW step over it.
This executes Algorithm 2 bit-for-bit (including the momentum warmup phase,
the μ decay schedule, and the outer Nesterov step) without needing a mesh —
groups see disjoint data streams exactly as the distributed runner shards
them.

The distributed (shard_map) path in ``repro.parallel.steps`` is semantically
identical; tests assert the two agree step-for-step on a tiny model.

The outer-event stream (accumulate / dispatch / apply, see DESIGN.md §5)
is executed exactly as the host loop would: with ``sync_delay > 0`` the
dispatched target is held in flight and installed ``d`` steps later with
the stale-delta correction, so delayed-schedule convergence can be
measured without a mesh.

The compressed hierarchical collective (DESIGN.md §6) is modeled
numerically: with ``outer_compression="quantize"`` each group's Δθ (plus
its error-feedback residual) is blockwise-quantized and *dequantized*
before averaging — exactly the value an int8+scales wire format delivers —
and with ``hierarchical_reduce=True`` and ``num_pods > 1`` the per-group
deltas are first averaged full-precision inside each pod (the fast
domain), so only the per-pod payloads are quantized and exchanged. The
``comm_chunks`` knob is a pure host-dispatch optimization with no numeric
effect, so the simulator ignores it.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.core.outer import (OuterState, compress_delta, outer_apply,
                              outer_init, outer_reduce, warmup_accumulate)


def _compress_rows(delta, residual, tc):
    """Vmapped error-feedback quantization over the leading group/pod axis.

    delta/residual: trees of (G, ...) fp32. Returns (payload, new_residual)
    with the same shapes — row g is exactly compress_delta on group g.
    """
    return jax.vmap(lambda d, r: compress_delta(d, r, tc))(delta, residual)
from repro.core.pier import PierSchedule
from repro.data.synthetic import MarkovLM, make_train_batch
from repro.models import registry as R
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import lr_at


@dataclass
class SimState:
    params: Any  # single replica (warmup) -- kept in sync with groups
    group_params: Optional[Any]  # (G, ...) stacked replicas, post-switch
    opt: Any  # AdamWState (single or stacked)
    outer: OuterState
    step: int = 0


class SimulatedRun:
    def __init__(self, mc: ModelConfig, tc: TrainConfig, *, num_groups: int,
                 seed: int = 0, num_pods: int = 1):
        if tc.optimizer != "adamw":
            assert num_groups >= 1
        assert num_groups % max(num_pods, 1) == 0, (num_groups, num_pods)
        assert isinstance(tc.sync_delay, int), (
            "sync_delay='auto' must be resolved before simulation "
            "(see launch/train.py)")
        self.mc, self.tc = mc, tc
        self.G = num_groups
        self.P = max(num_pods, 1)
        self.sched = PierSchedule(tc)
        self.lm = MarkovLM(mc.vocab_size, seed=1234)
        key = jax.random.PRNGKey(seed)
        params = R.init_params(key, mc)
        self.state = SimState(
            params=params,
            group_params=None,
            opt=adamw_init(params, tc),
            outer=outer_init(params, tc, num_groups=num_groups),
        )
        self._val_batch = make_train_batch(
            self.lm, jax.random.PRNGKey(99991), 16, tc.seq_len)

        # ---- jitted steps ----
        def sgd_step(params, opt, batch, step):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: R.loss_fn(p, mc, batch), has_aux=True)(params)
            grads, gnorm = clip_by_global_norm(grads, tc.clip_grad)
            lr = lr_at(tc, step)
            new_params, new_opt = adamw_update(grads, opt, params, tc, lr)
            return new_params, new_opt, loss

        self._warmup_step = jax.jit(sgd_step)
        self._inner_step = jax.jit(
            jax.vmap(sgd_step, in_axes=(0, 0, 0, None)))
        self._val_loss = jax.jit(
            lambda p: R.loss_fn(p, mc, self._val_batch)[0])

        def do_accumulate(outer, params, mu):
            return warmup_accumulate(outer, params, mu)

        self._accumulate = jax.jit(do_accumulate)

        compress = tc.outer_compression != "none"
        G, P = self.G, self.P

        def do_dispatch(group_params, outer, mu, lr):
            """Global Δθ mean + Nesterov math -> (target_f32, new outer).

            The knobs-off branch is the seed path, bit for bit. The
            compressed/hierarchical branch mirrors the distributed
            two-stage reduce: per-group Δθ -> (optional) full-precision
            intra-pod mean -> (optional) quantize+dequantize with error
            feedback -> global mean of the payloads.
            """
            if not compress and not tc.hierarchical_reduce:
                mean_params = jax.tree.map(
                    lambda p: jnp.mean(p.astype(jnp.float32), axis=0),
                    group_params)
                delta = jax.tree.map(
                    lambda m, a: m - a.astype(jnp.float32),
                    mean_params, outer.anchor)
                return outer_reduce(outer, delta, tc, mu=mu, lr=lr)

            delta = jax.tree.map(
                lambda p, a: p.astype(jnp.float32)
                - a.astype(jnp.float32)[None],
                group_params, outer.anchor)  # (G, ...)
            if tc.hierarchical_reduce:
                # P == 1 degenerates to quantizing the *global* mean once —
                # exactly the distributed path on a pod-less mesh, where the
                # stage-1 pmean over the fast axes is already the full reduce
                # stage 1: full-precision mean over the fast intra-pod axis,
                # broadcast back so every group in a pod holds the pod mean
                # (== its quantization input; residuals stay pod-identical)
                def pod_mean(d):
                    pm = jnp.mean(d.reshape(P, G // P, *d.shape[1:]), axis=1,
                                  keepdims=True)
                    return jnp.broadcast_to(pm, (P, G // P, *d.shape[1:])
                                            ).reshape(d.shape)
                delta = jax.tree.map(pod_mean, delta)
            new_residual = outer.residual
            if compress:
                delta, new_residual = _compress_rows(
                    delta, outer.residual, tc)
            delta_avg = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)
            return outer_reduce(outer, delta_avg, tc, mu=mu, lr=lr,
                                residual=new_residual)

        self._dispatch = jax.jit(do_dispatch)

        def do_apply(target_f32, dispatch_group, current_group):
            """Install the target on every group with the drift correction.

            target is unstacked; the (G, ...) snapshot/current leaves
            broadcast against it, so each group keeps its own in-flight
            progress. Eager (d=0) calls this with dispatch == current:
            the correction is exactly zero.
            """
            return outer_apply(target_f32, dispatch_group, current_group)

        self._apply = jax.jit(do_apply)
        # the (single) in-flight dispatch: (apply_at_step, target, snapshot)
        self._inflight = None

    # ------------------------------------------------------------------
    def _global_batch(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.tc.seed), step)
        return make_train_batch(
            self.lm, key, self.tc.global_batch_size, self.tc.seq_len)

    def _group_batches(self, step: int):
        """(G, b, S) disjoint slices of the same global batch."""
        b = self._global_batch(step)
        G = self.G
        per = self.tc.global_batch_size // G
        return jax.tree.map(
            lambda x: x[: G * per].reshape(G, per, *x.shape[1:]), b)

    def _switch_to_groups(self):
        st = self.state
        stack = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.G, *x.shape)), t)
        st.group_params = stack(st.params)
        st.opt = stack(st.opt)

    # ------------------------------------------------------------------
    def run(self, num_steps: int, *, eval_every: int = 0) -> Dict[str, List]:
        """Run ``num_steps`` and return the loss history."""
        hist = {"step": [], "train_loss": [], "val_loss": [], "val_step": []}
        sched, tc, st = self.sched, self.tc, self.state
        for _ in range(num_steps):
            step = st.step
            phase = sched.phase(step)
            if phase == "warmup":
                batch = self._global_batch(step)
                st.params, st.opt, loss = self._warmup_step(
                    st.params, st.opt, batch, jnp.asarray(step))
                if (not sched.is_sync_step(step)
                        and (step + 1) % tc.sync_interval == 0):
                    # DiLoCo lazy start: advance the anchor without
                    # accumulating momentum
                    st.outer = OuterState(
                        momentum=st.outer.momentum,
                        anchor=jax.tree.map(
                            lambda p, a: p.astype(a.dtype),
                            st.params, st.outer.anchor),
                        num_syncs=st.outer.num_syncs,
                        residual=st.outer.residual)
            else:
                if st.group_params is None:
                    self._switch_to_groups()
                batches = self._group_batches(step)
                st.group_params, st.opt, losses = self._inner_step(
                    st.group_params, st.opt, batches, jnp.asarray(step))
                loss = jnp.mean(losses)
            for ev in sched.events(step):
                if ev.kind == "accumulate":
                    st.outer = self._accumulate(
                        st.outer, st.params, jnp.float32(sched.mu_at(step)))
                elif ev.kind == "dispatch":
                    mu = jnp.float32(sched.mu_at(step))
                    olr = jnp.float32(sched.outer_lr_at(step))
                    target, st.outer = self._dispatch(
                        st.group_params, st.outer, mu, olr)
                    self._inflight = (sched.apply_step_for(step), target,
                                      st.group_params)
                else:  # apply
                    self._apply_inflight()
            hist["step"].append(step)
            hist["train_loss"].append(float(loss))
            if eval_every and (step + 1) % eval_every == 0:
                p = (jax.tree.map(lambda g: g[0], st.group_params)
                     if st.group_params is not None else st.params)
                hist["val_loss"].append(float(self._val_loss(p)))
                hist["val_step"].append(step)
            st.step += 1
        return hist

    def _apply_inflight(self):
        # No-op when flush() already drained the window — the schedule's
        # apply event is step-based and does not know about early drains.
        if self._inflight is None:
            return
        st = self.state
        _, target, snapshot = self._inflight
        st.group_params = self._apply(target, snapshot, st.group_params)
        st.params = jax.tree.map(lambda g: g[0], st.group_params)
        self._inflight = None

    def flush(self):
        """Apply an in-flight dispatch early (end-of-run drain)."""
        if self._inflight is not None:
            self._apply_inflight()

    def eval_params(self):
        st = self.state
        if st.group_params is not None:
            return jax.tree.map(
                lambda g: jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype),
                st.group_params)
        return st.params
