"""Elastic outer membership: churn scripting + liveness/staleness control.

DESIGN.md §11. Pier's outer collective hard-assumed all G groups
participate in every outer event; this module is the host-side state
machine that lets groups lag, drop out, and rejoin between outer
boundaries, feeding the weighted variable-membership reduction
(``repro.sync.base.weighted_psum_mean`` / ``weighted_stack_mean`` /
``repro.kernels.ref.dequant_sum_sources(weights=...)``):

- :class:`ChurnSchedule` — a scripted sequence of :class:`ChurnEvent`
  entries keyed on the **post-warmup outer dispatch ordinal**
  (``PierSchedule.outer_index``), with a launcher-friendly spec grammar::

      drop:G@K        group G leaves the cohort before event K
      rejoin:G@K      group G returns and participates at event K
      straggle:G@K+N  group G's deltas for events [K, K+N) arrive late
                      (discarded; see the staleness bound below)

  e.g. ``"drop:1@3,rejoin:1@6,straggle:0@4+2"``.

- :class:`MembershipController` — replays a schedule into per-event
  :class:`EventMembership` records:

  * a **dropped** group carries weight 0 and receives no outer applies
    until its scripted rejoin; returning groups always bootstrap (they
    missed applies while away), so a rejoin at event K bootstraps right
    after event K-1's apply installs the new anchor, trains the window,
    and re-enters the mask at dispatch K — "re-enters the mask at the
    next dispatch boundary".
  * a **straggler** stays in the cohort (receives applies) while its
    lateness stays within ``MembershipConfig.max_staleness`` missed
    events; the deltas it failed to deliver are *discarded* (weight 0 —
    down-weighted late delivery is a recorded follow-up). A straggler
    more than ``max_staleness`` events behind is **evicted**: removed
    from the apply cohort too, and auto-rejoins (with bootstrap) when
    its lateness window ends.
  * every event's live count is checked against ``min_live`` at
    construction time, so an over-aggressive script fails before any
    training step runs.

The controller is pure host-side bookkeeping: records are precomputed
from the script, so the simulator and the Trainer consume *identical*
decisions — the basis for the sync-boundary agreement tests. The
weights themselves are traced arguments of the jitted step functions
(no re-jit when the mask changes).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.config import MembershipConfig


@dataclass(frozen=True)
class ChurnEvent:
    """One scripted membership transition.

    ``kind``: ``"drop"`` | ``"rejoin"`` | ``"straggle"``. ``event`` is
    the post-warmup outer dispatch ordinal the transition keys on (for
    ``straggle``, the first event whose delta is late); ``late`` is the
    straggle window length in events (ignored otherwise).
    """

    kind: str
    group: int
    event: int
    late: int = 0

    def __post_init__(self):
        if self.kind not in ("drop", "rejoin", "straggle"):
            raise ValueError(f"unknown churn kind {self.kind!r}")
        if self.group < 0:
            raise ValueError(f"group must be >= 0, got {self.group}")
        if self.event < 0:
            raise ValueError(f"event must be >= 0, got {self.event}")
        if self.kind == "rejoin" and self.event < 1:
            raise ValueError(
                "rejoin must name event >= 1: the returning group "
                "bootstraps at the previous event's apply boundary")
        if self.kind == "straggle" and self.late < 1:
            raise ValueError(
                f"straggle needs a lateness >= 1 event, got {self.late}")


_SPEC_RE = re.compile(
    r"^(?P<kind>drop|rejoin|straggle):(?P<group>\d+)@(?P<event>\d+)"
    r"(?:\+(?P<late>\d+))?$")


@dataclass(frozen=True)
class ChurnSchedule:
    """An ordered collection of scripted churn events."""

    events: Tuple[ChurnEvent, ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "ChurnSchedule":
        """Parse the launcher grammar, e.g.
        ``"drop:1@3,rejoin:1@6,straggle:0@4+2"``."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            m = _SPEC_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad churn spec entry {part!r}: expected "
                    f"kind:group@event[+late] with kind in "
                    f"drop|rejoin|straggle")
            late = m.group("late")
            if late is not None and m.group("kind") != "straggle":
                raise ValueError(
                    f"bad churn spec entry {part!r}: +late is only "
                    f"meaningful for straggle")
            events.append(ChurnEvent(
                kind=m.group("kind"), group=int(m.group("group")),
                event=int(m.group("event")),
                late=int(late) if late is not None else 0))
        return cls(events=tuple(events))

    def for_group(self, g: int) -> Tuple[ChurnEvent, ...]:
        return tuple(sorted((e for e in self.events if e.group == g),
                            key=lambda e: e.event))

    def max_event(self) -> int:
        """Last event ordinal any entry touches (-1 for an empty script)."""
        last = -1
        for e in self.events:
            last = max(last, e.event + (e.late if e.kind == "straggle"
                                        else 0))
        return last


@dataclass(frozen=True)
class EventMembership:
    """The membership decision for one post-warmup outer event.

    ``weights`` feeds the weighted reduction at this event's dispatch;
    ``apply_live`` masks this event's apply (an absent/evicted group
    keeps its stale params until bootstrap); ``bootstrap_after_apply``
    names the groups to bootstrap immediately after this event's apply
    lands (params <- the freshly installed anchor, or the latest
    complete checkpoint; fresh inner-opt state; zero residual) so they
    train the next window coherently and participate at event + 1.
    """

    event: int
    weights: Tuple[float, ...]
    apply_live: Tuple[bool, ...]
    bootstrap_after_apply: Tuple[int, ...] = ()

    @property
    def full(self) -> bool:
        return all(w == 1.0 for w in self.weights) and all(self.apply_live)

    @property
    def num_live(self) -> int:
        return sum(1 for w in self.weights if w > 0)


# Per-group phase labels of the membership state machine.
_LIVE = "live"
_ABSENT = "absent"  # dropped, awaiting scripted rejoin
_STRAGGLING = "straggling"  # in cohort, deltas discarded
_EVICTED = "evicted"  # out of cohort (beyond the staleness bound)


@dataclass
class MembershipController:
    """Replays a :class:`ChurnSchedule` into per-event membership records.

    Deterministic and precomputed: the full timeline is validated (and
    ``min_live`` enforced) at construction, so the simulator and the
    Trainer — consuming the same controller — see identical weights,
    apply masks, and bootstrap points at every boundary.
    """

    num_groups: int
    cfg: MembershipConfig = field(default_factory=MembershipConfig)
    schedule: Optional[ChurnSchedule] = None

    def __post_init__(self):
        if self.num_groups < 1:
            raise ValueError(
                f"num_groups must be >= 1, got {self.num_groups}")
        sched = self.schedule or ChurnSchedule()
        for e in sched.events:
            if e.group >= self.num_groups:
                raise ValueError(
                    f"churn entry {e} names group {e.group} but only "
                    f"{self.num_groups} groups exist")
        self._records: Dict[int, EventMembership] = {}
        self._horizon = sched.max_event()
        self._validate_script(sched)
        self._replay(sched)

    # ------------------------------------------------------------ validation
    def _validate_script(self, sched: ChurnSchedule) -> None:
        for g in range(self.num_groups):
            open_drop = None
            straggle_until = -1
            for e in sched.for_group(g):
                if e.kind == "drop":
                    if open_drop is not None:
                        raise ValueError(
                            f"group {g} dropped at event {e.event} while "
                            f"already dropped at {open_drop}")
                    if e.event < straggle_until:
                        raise ValueError(
                            f"group {g} dropped at event {e.event} inside "
                            f"its straggle window (until {straggle_until})")
                    open_drop = e.event
                elif e.kind == "rejoin":
                    if open_drop is None:
                        raise ValueError(
                            f"group {g} rejoins at event {e.event} without "
                            f"a preceding drop")
                    if e.event <= open_drop:
                        raise ValueError(
                            f"group {g} rejoin at event {e.event} must come "
                            f"after its drop at {open_drop}")
                    open_drop = None
                else:  # straggle
                    if open_drop is not None:
                        raise ValueError(
                            f"group {g} straggles at event {e.event} while "
                            f"dropped at {open_drop}")
                    if e.event < straggle_until:
                        raise ValueError(
                            f"group {g} straggle at event {e.event} overlaps "
                            f"its previous straggle window")
                    straggle_until = e.event + e.late

    # ---------------------------------------------------------------- replay
    def _replay(self, sched: ChurnSchedule) -> None:
        G = self.num_groups
        phase = [_LIVE] * G
        missed = [0] * G
        straggle_end = [-1] * G  # first event after the straggle window
        drops: Dict[int, List[int]] = {}
        rejoins: Dict[int, List[int]] = {}
        straggles: Dict[int, List[ChurnEvent]] = {}
        for e in sched.events:
            if e.kind == "drop":
                drops.setdefault(e.event, []).append(e.group)
            elif e.kind == "rejoin":
                rejoins.setdefault(e.event, []).append(e.group)
            else:
                straggles.setdefault(e.event, []).append(e)

        for k in range(self._horizon + 1):
            bootstrap_next: List[int] = []
            # scripted transitions taking effect at event k
            for g in drops.get(k, ()):
                phase[g] = _ABSENT
            for g in rejoins.get(k, ()):
                phase[g] = _LIVE
                missed[g] = 0
            for e in straggles.get(k, ()):
                phase[e.group] = _STRAGGLING
                straggle_end[e.group] = k + e.late
            # straggle windows ending at k: the group re-contributes now
            for g in range(G):
                if (phase[g] in (_STRAGGLING, _EVICTED)
                        and straggle_end[g] == k):
                    phase[g] = _LIVE
                    missed[g] = 0
                    straggle_end[g] = -1
            weights = tuple(
                1.0 if phase[g] == _LIVE else 0.0 for g in range(G))
            apply_live = tuple(
                phase[g] in (_LIVE, _STRAGGLING) for g in range(G))
            # staleness accounting + eviction (after this event's mask:
            # a group becomes evictable once it has MISSED more than
            # max_staleness events)
            for g in range(G):
                if phase[g] == _LIVE:
                    missed[g] = 0
                    continue
                missed[g] += 1
                if (phase[g] == _STRAGGLING
                        and missed[g] > self.cfg.max_staleness):
                    phase[g] = _EVICTED
                if phase[g] == _ABSENT and missed[g] > self.cfg.max_staleness:
                    phase[g] = _EVICTED
            # rejoins participating at k+1 bootstrap right after event
            # k's apply: scripted rejoins, and evicted stragglers whose
            # window ends at k+1
            for g in rejoins.get(k + 1, ()):
                bootstrap_next.append(g)
            for g in range(G):
                if phase[g] == _EVICTED and straggle_end[g] == k + 1:
                    bootstrap_next.append(g)
            rec = EventMembership(
                event=k, weights=weights, apply_live=apply_live,
                bootstrap_after_apply=tuple(sorted(set(bootstrap_next))))
            if rec.num_live < self.cfg.min_live:
                raise ValueError(
                    f"churn schedule leaves only {rec.num_live} live "
                    f"groups at event {k} (< min_live="
                    f"{self.cfg.min_live}): {rec.weights}")
            self._records[k] = rec

    # ------------------------------------------------------------------ API
    def at(self, event: int) -> EventMembership:
        """Membership record for post-warmup outer event ``event``.

        Events past the scripted horizon are full membership (every
        transition has resolved; evicted-but-never-rejoined states
        cannot persist past the horizon by construction — an open drop
        without a rejoin keeps the group absent forever, which the
        horizon record reflects).
        """
        if event < 0:
            raise ValueError(f"event must be >= 0, got {event}")
        if event in self._records:
            return self._records[event]
        if self._horizon >= 0 and event > self._horizon:
            last = self._records[self._horizon]
            # steady state past the horizon: the last record's phases,
            # minus one-shot bootstrap actions
            return EventMembership(
                event=event, weights=last.weights,
                apply_live=last.apply_live, bootstrap_after_apply=())
        return EventMembership(
            event=event, weights=(1.0,) * self.num_groups,
            apply_live=(True,) * self.num_groups)

    @property
    def elastic(self) -> bool:
        """True if any event deviates from full membership."""
        return any(not r.full or r.bootstrap_after_apply
                   for r in self._records.values())
