"""Delay controllers: how ``sync_delay="auto"`` resolves d*.

d* is the smallest delay (in inner steps) that fully hides the outer
collective: ``d* = ceil(t_comm / t_inner)``. Two sources for the times:

- :class:`ModelDelayController` — the analytic step-time model of
  ``benchmarks/overlap.py`` (roofline compute + ring-all-reduce bandwidth
  terms), keyed by a ``--chip`` hint. Warn-and-fallback to eager (d*=0)
  on an unknown chip or when the benchmarks package is not deployed.
- :class:`MeasuredDelayController` — on-line measurement: EMAs of the
  wall-clock inner-step time and the dispatch-to-ready time of the first
  few sync windows, re-resolving d* once at least ``min_windows`` windows
  are measured; before that it defers to the fallback (the model). This
  replaces the analytic-model-only path: no chip hint needed, and the
  resolved delay tracks the fabric actually underneath the run.

Controllers are created through the strategy hook
:meth:`repro.sync.base.OuterSyncStrategy.make_delay_controller`, so a
custom strategy can inject its own resolution policy.
"""

from __future__ import annotations

import math
import warnings
from typing import Optional


class DelayController:
    """Protocol: decides (and possibly re-decides) the sync delay d*."""

    def initial_delay(self) -> int:
        raise NotImplementedError

    @property
    def wants_measurement(self) -> bool:
        """True while the host loop should wall-clock sync windows for
        :meth:`observe_window` (the measured controller's warmup)."""
        return False

    def observe_step(self, t_inner: float) -> None:
        """Record one inner step's wall-clock seconds."""

    def observe_window(self, *, t_comm: float,
                       t_inner: Optional[float] = None,
                       warmup: bool = False) -> None:
        """Record one measured sync window (dispatch-to-ready seconds).

        ``warmup=True`` marks a warmup accumulate window: its collective
        exchanged the fp32 Δθ, not the strategy's wire payload, so
        width-scaling controllers rescale the sample before folding it
        into their estimate."""

    def tick_window(self) -> None:
        """Note that one sync window elapsed, measured or not.

        The host loop calls this on *every* outer dispatch (unlike
        :meth:`observe_window`, which only fires while
        :attr:`wants_measurement` holds) — the hook long-running
        controllers use to schedule periodic re-measurement
        (``remeasure_every``)."""

    def current_delay(self) -> int:
        return self.initial_delay()


class FixedDelayController(DelayController):
    """A constant d*.

    ``sync_interval`` (when known) bounds the delay to the legal window
    ``[0, sync_interval − 1]``: an out-of-range fixed delay would silently
    violate the single-outstanding-dispatch invariant that
    ``PierSchedule.events`` documents (the apply must precede the next
    dispatch), so it is clamped with a warning rather than handed to the
    schedule. A negative delay without an interval to clamp against
    raises outright.
    """

    def __init__(self, delay: int, sync_interval: Optional[int] = None):
        d = int(delay)
        if sync_interval is not None:
            hi = int(sync_interval) - 1
            if d < 0 or d > hi:
                warnings.warn(
                    f"fixed sync_delay {d} outside the legal window "
                    f"[0, {hi}] (sync_interval {int(sync_interval)}); "
                    f"clamping — the in-flight dispatch must be applied "
                    f"before the next boundary", stacklevel=2)
                d = max(0, min(d, hi))
        elif d < 0:
            raise ValueError(f"sync_delay must be >= 0, got {d}")
        self._delay = d

    def initial_delay(self) -> int:
        return self._delay


class ModelDelayController(DelayController):
    """Analytic d* from the overlap step-time model (``--chip`` hint).

    Falls back to 0 (eager) with a warning whenever the model has no
    estimate: no/unknown chip hint, or the benchmarks package not
    importable from this deployment.
    """

    def __init__(self, tc, mc, pc, *, chip: str = ""):
        self.tc, self.mc, self.pc = tc, mc, pc
        self.chip = chip or ""
        self._cached: Optional[int] = None

    def initial_delay(self) -> int:
        if self._cached is not None:
            return self._cached
        self._cached = self._resolve()
        return self._cached

    def _resolve(self) -> int:
        tc, mc, pc = self.tc, self.mc, self.pc
        try:
            from benchmarks.overlap import resolve_sync_delay
        except ImportError:
            if self.chip:
                warnings.warn(
                    "sync_delay='auto': benchmarks package not importable; "
                    "falling back to eager (d*=0)", stacklevel=3)
            return 0
        comm = tc.outer_comm
        d = resolve_sync_delay(
            n_params=mc.param_count(), n_devices=pc.num_devices,
            group_size=pc.group_size, sync_interval=tc.sync_interval,
            chip=self.chip or None,
            bits=(comm.bits if comm.compression != "none" else 32),
            block=comm.block,
            hierarchical=comm.hierarchical, pods=pc.num_pods)
        if d is None:
            # resolve_sync_delay already warned for an unknown chip; an
            # empty hint is the documented "no estimate" case.
            return 0
        return max(0, min(int(d), tc.sync_interval - 1))


class MeasuredDelayController(DelayController):
    """Measured d*: EMA ``t_comm``/``t_inner`` over the first sync windows.

    The host loop times every inner step (:meth:`observe_step`) and, while
    :attr:`wants_measurement` is True, blocks on the dispatched collective
    to wall-clock it (:meth:`observe_window`) — overlap is sacrificed for
    the measurement windows only. Once ``min_windows`` windows are in,
    d* = ceil(ema_t_comm / ema_t_inner) clamped to
    ``[0, sync_interval - 1]``; before that the fallback (analytic model)
    answers.

    ``remeasure_every = k > 0`` keeps long runs honest: after the initial
    measurement completes, every ``k`` *unmeasured* sync windows
    (:meth:`tick_window`, which the host calls on every dispatch) re-opens
    a burst of ``min_windows`` measured windows, folding fresh samples
    into the EMAs — fabric contention drifts over a multi-day run, and
    without re-sampling the controller would freeze on the first
    minutes' timings forever. 0 (the default) keeps the original
    measure-once behavior.

    ``warmup_scale`` is the modeled payload-width ratio (strategy wire
    bytes/param over fp32's 4.0): warmup accumulate windows exchange the
    *fp32* Δθ whatever the strategy, so their ``t_comm`` samples
    over-estimate a compressed wire's collective by exactly that ratio.
    Samples observed with ``warmup=True`` are multiplied by it before
    entering the EMA — d* then resolves from representative-width
    samples before the first post-warmup sync, instead of deferring to
    the fallback until ``min_windows`` post-warmup windows have been
    paid for. 1.0 (fp32 strategies) keeps warmup samples exact.
    """

    def __init__(self, tc, *, fallback: Optional[DelayController] = None,
                 min_windows: int = 2, max_windows: int = 6,
                 skip_windows: int = 1, ema: float = 0.5,
                 remeasure_every: int = 0, warmup_scale: float = 1.0):
        self.tc = tc
        self.fallback = fallback or FixedDelayController(0)
        self.min_windows = int(min_windows)
        self.max_windows = int(max_windows)
        # the first window(s) wall-clock jit compilation, not the
        # collective — observed but not folded into the EMA
        self.skip_windows = int(skip_windows)
        self.ema = float(ema)
        self.remeasure_every = int(remeasure_every)
        self.warmup_scale = float(warmup_scale)
        self.windows = 0
        self.t_inner: Optional[float] = None
        self.t_comm: Optional[float] = None
        self._since_measure = 0  # unmeasured windows since the last burst
        self._burst = 0  # re-measurement windows still owed
        self._measured_this_window = False  # observe seen since last tick

    def initial_delay(self) -> int:
        return self.fallback.initial_delay()

    @property
    def wants_measurement(self) -> bool:
        return self.windows < self.max_windows or self._burst > 0

    def tick_window(self) -> None:
        measured, self._measured_this_window = (self._measured_this_window,
                                                False)
        if measured or self.wants_measurement:
            self._since_measure = 0
            return
        if self.remeasure_every > 0:
            self._since_measure += 1
            if self._since_measure >= self.remeasure_every:
                self._burst = self.min_windows
                self._since_measure = 0

    def _ema(self, old: Optional[float], new: float) -> float:
        if old is None:
            return new
        return self.ema * new + (1.0 - self.ema) * old

    def observe_step(self, t_inner: float) -> None:
        self.t_inner = self._ema(self.t_inner, t_inner)

    def observe_window(self, *, t_comm: float,
                       t_inner: Optional[float] = None,
                       warmup: bool = False) -> None:
        if self._burst > 0:
            self._burst -= 1
        self._measured_this_window = True
        self.windows += 1
        if self.windows <= self.skip_windows:
            return
        if warmup:
            t_comm = t_comm * self.warmup_scale
        self.t_comm = self._ema(self.t_comm, t_comm)
        if t_inner is not None:
            self.t_inner = self._ema(self.t_inner, t_inner)

    def current_delay(self) -> int:
        # NOTE: ``is None`` checks, not truthiness — a legitimately
        # measured 0.0 (coarse timer, sub-ms collective) is a valid
        # sample and resolves to d*=0; only division by a non-positive
        # t_inner defers to the fallback.
        if (self.windows < self.min_windows + self.skip_windows
                or self.t_comm is None
                or self.t_inner is None or self.t_inner <= 0):
            return self.fallback.initial_delay()
        d = math.ceil(self.t_comm / self.t_inner)
        return max(0, min(int(d), self.tc.sync_interval - 1))
