"""Sync controllers: decision objects generalizing delay-only control.

:mod:`repro.sync.delay` resolves a *scalar* d\\* — how many inner steps to
hide the outer collective behind. But d\\* is capped at
``sync_interval − 1``: when the measured t_comm stays exposed even at the
maximum legal delay, no amount of overlap fixes the window, and the right
move is to change *what crosses the wire* — drop the payload width, or
re-stage the reduce hierarchically (communication characteristics vary
with scale and topology, arXiv:2408.10197; ZeRO++'s quantized-collective
tuning, arXiv:2306.10209). A :class:`SyncController` therefore emits a
:class:`SyncDecision` — ``(delay, strategy)`` — instead of a bare int:

- :class:`DelayDecisionAdapter` wraps any legacy
  :class:`~repro.sync.delay.DelayController` into the decision protocol
  (strategy always ``None`` = keep the configured one).
- :class:`AdaptiveSyncController` owns a *strategy ladder* — successively
  cheaper wire formats for the same semantic reduce — and a
  :class:`~repro.sync.delay.MeasuredDelayController`. When measurement
  completes and the unclamped d\\* = ceil(t_comm/t_inner) still exceeds the
  legal window, it steps down the ladder, resets measurement (fresh
  t_comm statistics for the new wire format; t_inner carries over — the
  inner step does not change), and decides the max legal delay until the
  new numbers are in.
- :class:`ScriptedSyncController` replays a fixed window-indexed decision
  script — the deterministic seam the simulator↔Trainer lockstep tests
  (and offline replay of a recorded adaptive run) drive both paths with.

The runners consume controllers uniformly: ``tick_window()`` after every
outer dispatch, ``observe_*`` while ``wants_measurement`` holds, then
``current_decision()`` — a strategy change flushes the in-flight window
and re-jits the sync steps off the new strategy's plan (DESIGN.md §9).
"""

from __future__ import annotations

import math
from typing import Mapping, NamedTuple, Optional, Sequence, Union

from repro.sync.delay import (DelayController, FixedDelayController,
                              MeasuredDelayController)
from repro.sync.strategies import (Chunked, FlatFP32, Hierarchical,
                                   Int8Wire, Quantized)


class SyncDecision(NamedTuple):
    """One controller verdict: the delay for the following windows, and
    an optional strategy to switch to (``None`` = keep the current one).
    Consumers adopt the delay through :meth:`clamped_delay` so the legal
    window ``[0, sync_interval − 1]`` is enforced in exactly one place."""

    delay: int
    strategy: Optional[object] = None  # OuterSyncStrategy | None

    def clamped_delay(self, sync_interval: int) -> int:
        """The decision's delay clamped to the legal in-flight window —
        the single clamp the Trainer and the simulator both adopt (so a
        change to the legal-window rule cannot desynchronize them)."""
        return max(0, min(int(self.delay), int(sync_interval) - 1))


class SyncController:
    """Protocol: decides (and re-decides) delay *and* strategy."""

    def initial_decision(self) -> SyncDecision:
        raise NotImplementedError

    @property
    def wants_measurement(self) -> bool:
        """True while the host loop should wall-clock sync windows."""
        return False

    def observe_step(self, t_inner: float) -> None:
        """Record one inner step's wall-clock seconds."""

    def observe_window(self, *, t_comm: float,
                       t_inner: Optional[float] = None,
                       warmup: bool = False) -> None:
        """Record one measured sync window (dispatch-to-ready seconds).
        ``warmup=True`` marks a warmup accumulate window (fp32 Δθ on the
        wire regardless of strategy — see
        :meth:`repro.sync.delay.DelayController.observe_window`)."""

    def tick_window(self) -> None:
        """Note that one sync window elapsed (measured or not)."""

    def current_decision(self) -> SyncDecision:
        return self.initial_decision()

    @property
    def delay_controller(self) -> Optional[DelayController]:
        """The underlying scalar-delay controller, when one exists (the
        Trainer's legacy ``delay_controller`` attribute reads through)."""
        return None


class DelayDecisionAdapter(SyncController):
    """A legacy :class:`DelayController` as a fixed-strategy decision
    source — the default ``sync_delay="auto"`` path, byte-for-byte the
    pre-decision behavior."""

    def __init__(self, delay_controller: DelayController):
        self._delay = delay_controller

    def initial_decision(self) -> SyncDecision:
        return SyncDecision(self._delay.initial_delay(), None)

    @property
    def wants_measurement(self) -> bool:
        return self._delay.wants_measurement

    def observe_step(self, t_inner: float) -> None:
        self._delay.observe_step(t_inner)

    def observe_window(self, *, t_comm: float,
                       t_inner: Optional[float] = None,
                       warmup: bool = False) -> None:
        self._delay.observe_window(t_comm=t_comm, t_inner=t_inner,
                                   warmup=warmup)

    def tick_window(self) -> None:
        self._delay.tick_window()

    def current_decision(self) -> SyncDecision:
        return SyncDecision(self._delay.current_delay(), None)

    @property
    def delay_controller(self) -> DelayController:
        return self._delay


class AdaptiveSyncController(SyncController):
    """Measured delay resolution + strategy switching on exposure.

    ``ladder`` is the ordered tuple of strategies to fall through,
    position 0 being the configured starting strategy (see
    :func:`default_ladder`). After each completed measurement phase the
    controller computes the *unclamped* d\\* = ceil(t_comm / t_inner); if
    it exceeds the legal maximum (``sync_interval − 1`` — the collective
    stays exposed even fully overlapped) and a lower rung exists, the
    decision carries the next rung and measurement restarts against the
    new wire format. ``remeasure_every`` is forwarded to the underlying
    measured controller so long runs keep re-sampling.
    """

    def __init__(self, tc, *, ladder: Sequence,
                 fallback: Optional[DelayController] = None,
                 min_windows: int = 2, max_windows: int = 6,
                 skip_windows: int = 1, remeasure_every: int = 0,
                 warmup_scale: float = 1.0):
        if not ladder:
            raise ValueError("adaptive sync needs a non-empty ladder")
        self.tc = tc
        self.ladder = tuple(ladder)
        self.rung = 0
        self.min_windows = int(min_windows)
        self.skip_windows = int(skip_windows)
        # the measurement phase must be able to resolve: at least
        # skip (compile) + min (EMA) windows long
        self.max_windows = max(int(max_windows),
                               self.min_windows + self.skip_windows)
        self.remeasure_every = int(remeasure_every)
        self.warmup_scale = float(warmup_scale)
        self._measure = self._fresh_measure(
            fallback if isinstance(fallback, DelayController)
            else FixedDelayController(0, tc.sync_interval))

    def _fresh_measure(self, fallback: DelayController,
                       t_inner: Optional[float] = None):
        m = MeasuredDelayController(
            self.tc, fallback=fallback, min_windows=self.min_windows,
            max_windows=self.max_windows, skip_windows=self.skip_windows,
            remeasure_every=self.remeasure_every,
            warmup_scale=self.warmup_scale)
        # the inner step does not change across strategy switches — carry
        # the EMA so the fresh t_comm resolves against live numbers
        m.t_inner = t_inner
        return m

    @property
    def max_legal_delay(self) -> int:
        return max(self.tc.sync_interval - 1, 0)

    def initial_decision(self) -> SyncDecision:
        return SyncDecision(self._measure.initial_delay(), None)

    @property
    def wants_measurement(self) -> bool:
        return self._measure.wants_measurement

    def observe_step(self, t_inner: float) -> None:
        self._measure.observe_step(t_inner)

    def observe_window(self, *, t_comm: float,
                       t_inner: Optional[float] = None,
                       warmup: bool = False) -> None:
        self._measure.observe_window(t_comm=t_comm, t_inner=t_inner,
                                     warmup=warmup)

    def tick_window(self) -> None:
        self._measure.tick_window()

    def _exposed_at_max(self) -> bool:
        m = self._measure
        if (m.wants_measurement
                or m.windows < m.min_windows + m.skip_windows
                or m.t_comm is None
                or m.t_inner is None or m.t_inner <= 0):
            return False
        return math.ceil(m.t_comm / m.t_inner) > self.max_legal_delay

    def current_decision(self) -> SyncDecision:
        if self._exposed_at_max() and self.rung + 1 < len(self.ladder):
            self.rung += 1
            # fully exposed: overlap as much as legally possible while the
            # cheaper wire format is measured from scratch
            self._measure = self._fresh_measure(
                FixedDelayController(self.max_legal_delay,
                                     self.tc.sync_interval),
                t_inner=self._measure.t_inner)
            return SyncDecision(self.max_legal_delay, self.ladder[self.rung])
        return SyncDecision(self._measure.current_delay(), None)

    @property
    def delay_controller(self) -> DelayController:
        return self._measure


class ScriptedSyncController(SyncController):
    """Replay a fixed decision script keyed by 1-based window index.

    ``script`` maps the index of a completed sync window onto either a
    full :class:`SyncDecision` or a bare strategy (the standing delay —
    the last decided one, initially ``delay`` — is then kept). Windows
    without an entry keep the standing delay and a ``None`` strategy. Never asks for measurement — decisions are
    data, which is what makes simulator↔Trainer lockstep tests (and
    replaying a recorded adaptive run) deterministic.
    """

    def __init__(self, delay: int, script: Optional[Mapping[int, Union[
            SyncDecision, object]]] = None):
        self.delay = int(delay)
        self.script = dict(script or {})
        self.windows = 0
        self._current = SyncDecision(self.delay, None)

    def initial_decision(self) -> SyncDecision:
        return SyncDecision(self.delay, None)

    def tick_window(self) -> None:
        self.windows += 1
        entry = self.script.get(self.windows)
        if entry is None:
            # keep the standing delay; never re-emit a strategy
            self._current = SyncDecision(self._current.delay, None)
        elif isinstance(entry, SyncDecision):
            self._current = entry
        else:  # a bare strategy
            self._current = SyncDecision(self._current.delay, entry)

    def current_decision(self) -> SyncDecision:
        return self._current


def _is_hierarchical(strategy) -> bool:
    if isinstance(strategy, Hierarchical):
        return True
    inner = getattr(strategy, "inner", None)
    return _is_hierarchical(inner) if inner is not None else False


def _core_ladder(strategy):
    """Successively cheaper wire formats for the same semantic reduce."""
    if isinstance(strategy, Chunked):
        return [Chunked(inner=i, num_chunks=strategy.num_chunks)
                for i in _core_ladder(strategy.inner)]
    if isinstance(strategy, Hierarchical):
        return [Hierarchical(inner=i) for i in _core_ladder(strategy.inner)]
    if isinstance(strategy, Quantized):
        return [strategy] + ([Quantized(4, strategy.block)]
                             if strategy.bits > 4 else [])
    if isinstance(strategy, Int8Wire):
        import dataclasses

        # replace() rather than a fresh Int8Wire: the downgrade must keep
        # the rs/ag wire-path flag (reduce_scatter) along with the block
        return [strategy] + ([dataclasses.replace(strategy, bits=4)]
                             if strategy.bits > 4 else [])
    if isinstance(strategy, FlatFP32):
        return [strategy, Quantized(8, 256), Quantized(4, 256)]
    return [strategy]


def default_ladder(strategy, *, num_pods: int = 1):
    """The default adaptive ladder for a configured strategy.

    Rung 0 is the strategy itself; each following rung halves the wire
    width (int8 → int4; fp32 → int8 → int4 via the numerically exact
    :class:`Quantized` payload). When the mesh has pods and the chain is
    not already hierarchical, a final rung toggles the two-stage reduce
    on the cheapest wire format — the topology-aware last resort
    (arXiv:2408.10197): only 1/pods of the endpoints keep exchanging.
    """
    rungs = _core_ladder(strategy)
    if num_pods > 1 and not _is_hierarchical(strategy):
        last = rungs[-1]
        if isinstance(last, Chunked):
            hier = Chunked(inner=Hierarchical(inner=last.inner),
                           num_chunks=last.num_chunks)
        else:
            hier = Hierarchical(inner=last)
        rungs.append(hier)
    return tuple(rungs)
