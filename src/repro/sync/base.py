"""The outer-sync strategy protocol (DESIGN.md §7).

PR 1/2 grew the outer collective three orthogonal knobs (delayed sync,
blockwise quantization, hierarchical two-stage reduce, chunked dispatch)
expressed as loose ``TrainConfig`` flags branched on in the distributed
steps, the simulator, and the Trainer. This module makes the collective a
first-class object instead: an :class:`OuterSyncStrategy` owns

- **planning** — :meth:`OuterSyncStrategy.plan` splits the Δθ leaf tree
  into contiguous spans (one per dispatch chunk) and declares whether the
  strategy carries an error-feedback residual;
- **dispatch** — :meth:`OuterSyncStrategy.reduce_leaf` is the per-leaf
  collective run inside the distributed ``shard_map`` (given a
  :class:`ReduceCtx` naming the mesh axes), and
  :meth:`OuterSyncStrategy.sim_dispatch` is the simulator's numeric model
  of the same reduction over ``(G, ...)``-stacked replicas;
- **apply** — :meth:`OuterSyncStrategy.apply` installs a dispatched target
  with the stale-delta correction (per chunk, on the chunked combinator);
- **delay** — :meth:`OuterSyncStrategy.make_delay_controller` is the
  injection point for resolving ``sync_delay="auto"`` (analytic model or
  on-line measurement, see :mod:`repro.sync.delay`).

Concrete strategies live in :mod:`repro.sync.strategies`; every legacy
flag combination resolves (via :func:`repro.sync.strategies.resolve_strategy`)
to a strategy that is bit-identical to the old flag-branched path.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Mapping, NamedTuple, Optional, Tuple

import jax

from repro.core.outer import outer_apply, outer_reduce


class SyncPlan(NamedTuple):
    """Host-side dispatch plan for one strategy × param tree.

    ``spans`` are contiguous ``[lo, hi)`` index ranges into the flattened
    Δθ leaf list; each span dispatches (and applies) as its own XLA
    computation, carrying its own per-chunk dispatch state.
    ``wire_format`` names what actually crosses the slow exchange axes:
    ``"fp32"`` for full-width (or dequantized-payload) collectives,
    ``"int8+scales"`` / ``"int4+scales"`` for the packed ring exchange of
    :class:`~repro.sync.strategies.Int8Wire`. ``transport`` names *how*
    it crosses: ``"collective"`` for pmean/psum-lowered strategies, or
    the backend-resolved wire transport (``"dma"`` | ``"ring"`` |
    ``"psum"``, see ``kernels/ring_allreduce.resolve_transport``) for the
    packed ring exchange.
    """

    num_leaves: int
    spans: Tuple[Tuple[int, int], ...]
    needs_residual: bool
    name: str
    wire_format: str = "fp32"
    transport: str = "collective"
    # Whether the strategy carries the second (gather-leg) error-feedback
    # residual of the reduce-scatter + all-gather wire path (DESIGN.md
    # §14) in ``OuterState.residual2``. Trailing with a default so
    # existing pickled/compared plans keep their layout.
    needs_residual2: bool = False

    @property
    def num_chunks(self) -> int:
        return len(self.spans)


class ChunkDispatch(NamedTuple):
    """One in-flight chunk (a leaf span) of a dispatched outer sync.

    ``targets`` are the synchronized fp32 leaves for the span (identical
    across groups); ``snapshots`` the (G,)-stacked θ_dispatch leaves,
    materialized fresh because inner steps donate the live params during
    the in-flight window. Apply installs ``target + (θ_t − snapshot)``
    per leaf — the *partial* stale-delta correction: early-arriving chunks
    can land while later chunks' collectives are still in flight.
    """

    targets: Tuple[Any, ...]
    snapshots: Tuple[Any, ...]


@dataclass(frozen=True)
class ReduceCtx:
    """Mesh-axis context threaded to :meth:`OuterSyncStrategy.reduce_leaf`.

    ``exchange_axes`` is what the payload exchange reduces over — the full
    manual set at the top level; the hierarchical combinator narrows it to
    the slow (pod) axes after its full-precision stage-1 mean.
    ``axis_sizes`` carries the static mesh-axis sizes: ring-based wire
    strategies need the endpoint count at trace time (Python-level hop
    loops), which collectives-only strategies never did. ``axis_coords``
    carries the *traced* per-shard coordinate along each manual axis
    (an ``arange`` sharded over the axis, sliced inside the body): jax
    0.4.x cannot lower ``lax.axis_index`` inside partial-manual
    shard_map, so the step builder threads the coordinates in as data
    (:meth:`with_coords`).
    """

    manual: Tuple[str, ...]
    fast_axes: Tuple[str, ...]
    slow_axes: Tuple[str, ...]
    exchange_axes: Tuple[str, ...]
    use_pallas: bool = False
    axis_sizes: Optional[Mapping[str, int]] = None
    axis_coords: Optional[Mapping[str, Any]] = None
    # Sharded-exchange context (DESIGN.md §10): the auto (GSPMD) axes of
    # the mesh, the mesh itself (constraints inside partial-manual
    # shard_map must be NamedShardings — bare PartitionSpecs raise on
    # jax 0.4.x), and the current leaf's PartitionSpec over those axes,
    # threaded per leaf by the step builder (:meth:`with_leaf_spec`, the
    # same data-threading pattern as ``axis_coords``).
    auto_axes: Tuple[str, ...] = ()
    mesh: Optional[Any] = None
    leaf_spec: Optional[Any] = None
    # Elastic-membership context (DESIGN.md §11): ``weights`` is the
    # traced (G,) fp32 participation vector in canonical source order
    # (row-major linearized over the manual axes — the same order the
    # wire gathers stack sources in), replicated to every shard;
    # ``weight`` is this shard's own scalar weight, sliced out of the
    # vector by the step builder from the threaded ``axis_coords``.
    # ``None`` (the default) means fixed membership — every strategy
    # falls back to its original unweighted collective, so existing
    # traces are byte-for-byte unchanged.
    weights: Optional[Any] = None
    weight: Optional[Any] = None

    def narrowed(self, exchange_axes: Tuple[str, ...]) -> "ReduceCtx":
        return dataclasses.replace(self, exchange_axes=exchange_axes)

    def with_membership(self, weights, weight) -> "ReduceCtx":
        """Per-trace copy carrying the elastic participation weights."""
        return dataclasses.replace(self, weights=weights, weight=weight)

    def with_coords(self, axis_coords) -> "ReduceCtx":
        """Per-trace copy carrying the shard's manual-axis coordinates."""
        return dataclasses.replace(self, axis_coords=axis_coords)

    def with_leaf_spec(self, leaf_spec) -> "ReduceCtx":
        """Per-leaf copy carrying the leaf's PartitionSpec (auto axes)."""
        return dataclasses.replace(self, leaf_spec=leaf_spec)

    def auto_size(self) -> int:
        """Static shard count over the auto axes (Π auto-axis sizes)."""
        sizes = self.axis_sizes or {}
        a = 1
        for ax in self.auto_axes:
            a *= int(sizes.get(ax, 1))
        return a

    def exchange_size(self) -> int:
        """Static endpoint count of the payload exchange (Π axis sizes)."""
        sizes = self.axis_sizes or {}
        e = 1
        for ax in self.exchange_axes:
            if ax not in sizes:
                raise ValueError(
                    f"exchange axis {ax!r} has no size in "
                    f"ReduceCtx.axis_sizes (have {sorted(sizes)}); the "
                    f"wire ring needs static ring sizes")
            e *= int(sizes[ax])
        return e


def weighted_psum_mean(d, weight, axes):
    """``psum(d·w) · (1/psum(w))`` — the weighted collective mean.

    The elastic-membership replacement for ``pmean(d, axes)`` inside the
    manual region (DESIGN.md §11): each shard contributes its group's
    participation weight, absent groups contribute 0, and normalization
    is by the live weight sum. Within-group multiplicity (several shards
    of one group inside ``axes``) cancels because ``w`` is constant over
    the group. An all-zero round yields 0, not NaN (the membership
    controller rejects empty rounds before dispatch).

    At all-ones weights this is bit-identical to ``pmean``: ``d · 1.0``
    is IEEE-exact, the psum reduces in the same order, and the traced
    reciprocal of the weight sum (``1.0/E.0``, correctly rounded f32
    division) equals the constant ``1/E`` that XLA's strength-reduced
    constant division multiplies by (cf. the reciprocal-multiply note on
    ``repro.kernels.ref.quantize_blockwise_ref``) — asserted by tests
    for every strategy.
    """
    import jax.numpy as jnp

    w = jnp.asarray(weight, jnp.float32)
    num = jax.lax.psum(d * w, axes)
    sw = jax.lax.psum(w, axes)
    inv = jnp.where(sw > 0, jnp.float32(1.0) / sw, jnp.float32(0.0))
    return num * inv


def weighted_stack_mean(stacked, weights):
    """(G, ...) stack × (G,) weights -> weighted mean over axis 0.

    The simulator-side counterpart of :func:`weighted_psum_mean` (used
    where strategies reduce a stacked axis with ``jnp.mean(axis=0)``):
    ``sum(x·w) · (1/Σw)``, 0 on an all-zero mask. Bit-identical to
    ``jnp.mean`` at all-ones weights by the same argument.
    """
    import jax.numpy as jnp

    w = jnp.asarray(weights, jnp.float32)
    wb = w.reshape((w.shape[0],) + (1,) * (stacked.ndim - 1))
    num = jnp.sum(stacked * wb, axis=0)
    sw = jnp.sum(w)
    inv = jnp.where(sw > 0, jnp.float32(1.0) / sw, jnp.float32(0.0))
    return num * inv


def constrain_to_spec(x, spec, ctx: ReduceCtx):
    """``with_sharding_constraint`` over the auto axes, as a NamedSharding.

    Inside a partial-manual ``shard_map`` on jax 0.4.x a bare
    PartitionSpec constraint raises (no mesh context is installed there),
    so the sharded strategies build ``NamedSharding(ctx.mesh, spec)``
    explicitly. Constraints never change values — only the layout GSPMD
    picks — so wrapping a reduce in them is numerically the identity.
    No-op when the ctx carries no mesh/spec (unit tests, simulator).
    """
    if spec is None or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding

    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, spec))
    except (ValueError, TypeError, RuntimeError):
        # mesh axis absent / non-divisible dim -> leave the layout to GSPMD
        return x


def balanced_spans(sizes, num_chunks: int) -> Tuple[Tuple[int, int], ...]:
    """Split leaf indices into <= num_chunks contiguous spans of ~equal
    element count (the chunk payloads that dispatch as separate XLA
    computations). Every span is non-empty."""
    n = len(sizes)
    num_chunks = max(1, min(num_chunks, n))
    total = sum(sizes)
    spans, lo, acc = [], 0, 0
    for i, s in enumerate(sizes):
        acc += s
        # close the span once it reaches its fair share, keeping enough
        # leaves behind for the remaining chunks
        remaining_chunks = num_chunks - len(spans)
        if (acc >= total * (len(spans) + 1) / num_chunks
                and n - (i + 1) >= remaining_chunks - 1) or i == n - 1:
            spans.append((lo, i + 1))
            lo = i + 1
            if len(spans) == num_chunks:
                break
    if lo < n:  # fold any tail into the last span
        spans[-1] = (spans[-1][0], n)
    return tuple(spans)


def _leaf_sizes(pshapes):
    leaves = jax.tree_util.tree_leaves(pshapes)
    sizes = []
    for leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= int(d)
        sizes.append(n)
    return sizes


class OuterSyncStrategy:
    """Base class / protocol for outer-sync strategies.

    Subclasses override the per-leaf distributed reduce and the simulator
    reduction; the dispatch/apply composition and the delay-controller
    hook are shared.
    """

    # Whether this strategy carries a per-group error-feedback residual in
    # ``OuterState.residual`` (compressed payloads only).
    needs_residual: bool = False
    # Whether it also carries the gather-leg residual in
    # ``OuterState.residual2`` (the rs/ag wire path, DESIGN.md §14). When
    # True, ``reduce_leaf``/``sim_reduce`` receive and return the residual
    # argument as an ``(r1, r2)`` pair; combinators pass it through
    # opaquely.
    needs_residual2: bool = False
    # Whether the reduce runs as two stages (fp32 fast-domain mean, then
    # the payload exchange over the slow domain).
    two_stage: bool = False
    # What actually crosses the slow exchange axes (see SyncPlan).
    wire_format: str = "fp32"
    # Whether the outer state (momentum/anchor/residual) and dispatch
    # buffers should be pinned to the per-leaf auto-axis shardings via jit
    # out_shardings, so outer-state memory per device stops scaling with
    # full model size (DESIGN.md §10).
    sharded_state: bool = False

    # ------------------------------------------------------------- identity
    @property
    def name(self) -> str:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name}>"

    def transport_name(self, mesh=None) -> str:
        """How the payload crosses the slow exchange axes (SyncPlan field).

        ``"collective"`` for pmean/psum-lowered strategies; Int8Wire
        overrides with the backend-resolved wire transport. Resolved with
        the Pallas ring lane assumed available (``use_pallas=True``) —
        dispatch re-resolves against the actual ``ReduceCtx.use_pallas``.
        """
        return "collective"

    # ------------------------------------------------------------- planning
    def plan(self, pshapes, tc, mesh=None) -> SyncPlan:
        """Single fused span by default; the chunked combinator splits."""
        n = len(jax.tree_util.tree_leaves(pshapes))
        return SyncPlan(num_leaves=n, spans=((0, n),),
                        needs_residual=self.needs_residual, name=self.name,
                        wire_format=self.wire_format,
                        transport=self.transport_name(mesh),
                        needs_residual2=self.needs_residual2)

    # ------------------------------------------------- distributed dispatch
    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        """One Δθ leaf -> (globally averaged payload, new residual | None).

        Runs inside the distributed ``shard_map``; ``ctx`` names the mesh
        axes. Must be bit-identical to the legacy flag branch it replaces.
        """
        raise NotImplementedError

    # --------------------------------------------------- simulator dispatch
    def sim_dispatch(self, group_params, outer, tc, *, mu, lr, num_pods=1,
                     weights=None):
        """(G, ...)-stacked replicas -> (target_f32, new OuterState).

        Default: per-group Δθ, strategy-specific reduction, then the
        Nesterov math of :func:`repro.core.outer.outer_reduce`.
        ``weights`` is the optional (G,) elastic-membership participation
        vector (DESIGN.md §11); ``None`` keeps the fixed-membership mean.
        """
        import jax.numpy as jnp

        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
            group_params, outer.anchor)
        residual = outer.residual
        if self.needs_residual2:
            # rs/ag strategies thread both residuals as an opaque pair —
            # combinators forward it untouched; the core unpacks it.
            residual = (outer.residual, outer.residual2)
        delta_avg, new_res = self.sim_reduce(
            delta, residual, tc, num_pods=num_pods, weights=weights)
        if self.needs_residual2:
            new_r1, new_r2 = new_res
            return outer_reduce(outer, delta_avg, tc, mu=mu, lr=lr,
                                residual=new_r1, residual2=new_r2)
        return outer_reduce(outer, delta_avg, tc, mu=mu, lr=lr,
                            residual=new_res)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        """Stacked (G, ...) Δθ -> (averaged payload, new residual).

        ``pod_grouped=True`` (set by the hierarchical combinator after its
        stage-1 pod mean) marks the stacked entries as pod-duplicated: the
        exchange endpoints are the ``num_pods`` pods, not the G groups.
        Collective-mean strategies may ignore it (the mean of duplicated
        entries is the pod mean); ring strategies with order-sensitive
        per-source sums must honour it. ``weights`` is the optional (G,)
        membership vector; under ``pod_grouped`` it arrives as per-entry
        pod weight sums (the hierarchical combinator broadcasts each
        pod's weight over its entries).
        """
        raise NotImplementedError

    # ---------------------------------------------------------------- apply
    def apply(self, target_f32, dispatch_params, current_params):
        """Install a dispatched target with the stale-delta correction."""
        return outer_apply(target_f32, dispatch_params, current_params)

    def wire_bytes_per_param(self, tc) -> float:
        """Modeled slow-axis payload width in bytes per parameter.

        4.0 for fp32-wire strategies (including ``Quantized``, whose
        actual collective is an fp32 pmean of the dequantized payload);
        the wire strategies override with ``bits/8 + 4/block``. Used to
        scale warmup ``t_comm`` samples — warmup accumulates exchange
        fp32 Δθ regardless of strategy, so a compressed strategy's
        post-warmup collective is narrower by exactly this ratio.
        """
        return 4.0

    # ------------------------------------------------------ delay injection
    def make_delay_controller(self, tc, mc, pc, *, chip: str = "",
                              measured: bool = True):
        """Deprecated seam (kept as a shim): the scalar-delay half of
        ``sync_delay="auto"`` — measured d* with the analytic step-time
        model as fallback (or model-only with measured=False). New code
        (and the Trainer) goes through :meth:`make_sync_controller`,
        which wraps this controller into the decision protocol."""
        from repro.sync.delay import (MeasuredDelayController,
                                      ModelDelayController)

        model = ModelDelayController(tc, mc, pc, chip=chip)
        if not measured:
            return model
        return MeasuredDelayController(
            tc, fallback=model,
            warmup_scale=self.wire_bytes_per_param(tc) / 4.0)

    # --------------------------------------------------- decision injection
    def make_sync_controller(self, tc, mc, pc, *, chip: str = "",
                             measured: bool = True, adaptive: bool = False,
                             remeasure_every: int = 0):
        """The ``sync_delay="auto"`` hook: a :class:`SyncController`
        emitting ``SyncDecision(delay, strategy)``. The default wraps the
        (deprecated) :meth:`make_delay_controller` result — fixed
        strategy, byte-for-byte the legacy resolution; ``adaptive=True``
        returns an :class:`~repro.sync.controller.AdaptiveSyncController`
        over :func:`~repro.sync.controller.default_ladder` so a t_comm
        that stays exposed at the max legal delay switches the wire
        format instead of freezing (DESIGN.md §9)."""
        from repro.sync.controller import (AdaptiveSyncController,
                                           DelayDecisionAdapter,
                                           default_ladder)

        delay_ctrl = self.make_delay_controller(tc, mc, pc, chip=chip,
                                                measured=measured)
        if not adaptive:
            if remeasure_every and hasattr(delay_ctrl, "remeasure_every"):
                delay_ctrl.remeasure_every = int(remeasure_every)
            return DelayDecisionAdapter(delay_ctrl)
        from repro.sync.delay import MeasuredDelayController

        fallback = (delay_ctrl.fallback
                    if isinstance(delay_ctrl, MeasuredDelayController)
                    else delay_ctrl)
        return AdaptiveSyncController(
            tc, ladder=default_ladder(
                self, num_pods=getattr(pc, "num_pods", 1)),
            fallback=fallback, remeasure_every=remeasure_every,
            warmup_scale=self.wire_bytes_per_param(tc) / 4.0)
