"""Pluggable outer-sync strategies (DESIGN.md §7) + controllers (§9).

The outer collective — the only global communication in a Pier run — is a
first-class, composable object here: ``resolve_strategy(tc)`` maps a
config (grouped ``OuterCommConfig`` or the legacy flat flags, via the
deprecation shim) onto an ``OuterSyncStrategy`` consumed by the
distributed steps, the simulator, and the Trainer. ``SyncController``
generalizes the delay controllers into decision objects: measured
t_comm/t_inner resolves the overlap delay *and* can switch strategy
mid-run (``AdaptiveSyncController``).
"""

from repro.sync.base import (ChunkDispatch, OuterSyncStrategy, ReduceCtx,
                             SyncPlan, balanced_spans, weighted_psum_mean,
                             weighted_stack_mean)
from repro.sync.controller import (AdaptiveSyncController,
                                   DelayDecisionAdapter,
                                   ScriptedSyncController, SyncController,
                                   SyncDecision, default_ladder)
from repro.sync.delay import (DelayController, FixedDelayController,
                              MeasuredDelayController, ModelDelayController)
from repro.sync.membership import (ChurnEvent, ChurnSchedule,
                                   EventMembership, MembershipController)
from repro.sync.strategies import (Chunked, FlatFP32, Hierarchical,
                                   Int8Wire, Quantized, Sharded,
                                   resolve_strategy, strategy_name,
                                   validate_pod_grouping)

__all__ = [
    "ChunkDispatch", "OuterSyncStrategy", "ReduceCtx", "SyncPlan",
    "balanced_spans", "weighted_psum_mean", "weighted_stack_mean",
    "AdaptiveSyncController", "DelayDecisionAdapter",
    "ScriptedSyncController", "SyncController", "SyncDecision",
    "default_ladder",
    "DelayController", "FixedDelayController", "MeasuredDelayController",
    "ModelDelayController",
    "ChurnEvent", "ChurnSchedule", "EventMembership",
    "MembershipController",
    "Chunked", "FlatFP32", "Hierarchical", "Int8Wire", "Quantized",
    "Sharded", "resolve_strategy", "strategy_name",
    "validate_pod_grouping",
]
