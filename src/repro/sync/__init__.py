"""Pluggable outer-sync strategies (DESIGN.md §7).

The outer collective — the only global communication in a Pier run — is a
first-class, composable object here: ``resolve_strategy(tc)`` maps a
config (grouped ``OuterCommConfig`` or the legacy flat flags, via the
deprecation shim) onto an ``OuterSyncStrategy`` consumed by the
distributed steps, the simulator, and the Trainer.
"""

from repro.sync.base import (ChunkDispatch, OuterSyncStrategy, ReduceCtx,
                             SyncPlan, balanced_spans)
from repro.sync.delay import (DelayController, FixedDelayController,
                              MeasuredDelayController, ModelDelayController)
from repro.sync.strategies import (Chunked, FlatFP32, Hierarchical,
                                   Int8Wire, Quantized, resolve_strategy,
                                   strategy_name, validate_pod_grouping)

__all__ = [
    "ChunkDispatch", "OuterSyncStrategy", "ReduceCtx", "SyncPlan",
    "balanced_spans",
    "DelayController", "FixedDelayController", "MeasuredDelayController",
    "ModelDelayController",
    "Chunked", "FlatFP32", "Hierarchical", "Int8Wire", "Quantized",
    "resolve_strategy", "strategy_name", "validate_pod_grouping",
]
