"""Concrete outer-sync strategies + the legacy-flag resolver (DESIGN.md §7).

- :class:`FlatFP32` — the seed collective: one flat fp32 pmean of Δθ over
  every manual (group) axis. Bit-identical to the pre-strategy path.
- :class:`Quantized` — blockwise-quantized payload (int8/int4 values +
  per-block fp32 absmax scales) with an error-feedback residual carried
  group-locally in ``OuterState.residual``. The *dequantized* value is
  exchanged — the numeric model of the wire format at fp32 wire width.
- :class:`Int8Wire` — the true wire format (DESIGN.md §8): the actual
  packed ``(q, scales)`` pairs cross the slow exchange axes through a
  ring exchange (Pallas remote-DMA on TPU, ``ppermute`` reference
  elsewhere) and are reduced with per-source-scale sum semantics —
  numerically the same payload mean as :class:`Quantized`, with the bytes
  win real instead of accounted.
- :class:`Sharded` — auto-axis combinator (DESIGN.md §10): each device
  compresses and exchanges only its Δθ *shard* along the auto (GSPMD)
  axes — the per-leaf ``PartitionSpec`` threaded through
  ``ReduceCtx.leaf_spec`` — so the outer exchange (and, with
  ``sharded_state``, the outer momentum/anchor/residual) stops scaling
  with full model size. fp32 inner stays bit-identical to the replicated
  path; quantized inner is block-content-identical to :class:`Quantized`.
- :class:`Hierarchical` — two-stage combinator: full-precision mean over
  the fast intra-pod axes first, then the *inner* strategy's exchange over
  the slow pod axes (1/pods of the traffic crosses the slow domain).
- :class:`Chunked` — span combinator: the Δθ leaf tree dispatches as
  ``num_chunks`` contiguous spans, each its own XLA computation with its
  own per-chunk :class:`~repro.sync.base.ChunkDispatch`, so early chunks'
  collectives (and applies) overlap later chunks' quantization.

:func:`resolve_strategy` maps an :class:`~repro.config.OuterCommConfig`
(or a ``TrainConfig`` carrying one — including every legacy flat-flag
combination via the deprecation shim) onto the equivalent strategy object.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.outer import compress_delta, outer_reduce
from repro.sync.base import (OuterSyncStrategy, ReduceCtx, SyncPlan,
                             balanced_spans, constrain_to_spec, _leaf_sizes,
                             weighted_psum_mean, weighted_stack_mean)


def _can_pad_in_manual() -> bool:
    """Whether in-graph pad/slice of auto-sharded values inside the
    partial-manual shard_map region is safe.

    jaxlib 0.4.x trips an XLA partitioner CHECK (hlo_sharding_util
    IsManualSubgroup) repartitioning padded flat payloads there, so
    :class:`Sharded` keeps ragged leaves on the replicated round trip;
    modern jax (the new shard_map, jax >= 0.5) lowers the pad fine and
    takes the shard-local quantize path. Module-level so tests can
    exercise the gate both ways by monkeypatching.
    """
    return compat.HAS_NEW_SHARD_MAP


@dataclass(frozen=True)
class FlatFP32(OuterSyncStrategy):
    """Flat fp32 pmean of Δθ over the manual axes — the seed collective."""

    @property
    def name(self) -> str:
        return "flat-fp32"

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        if ctx.exchange_axes:
            if ctx.weight is not None:
                d = weighted_psum_mean(d, ctx.weight, ctx.exchange_axes)
            else:
                d = jax.lax.pmean(d, ctx.exchange_axes)
        return d, r

    def sim_dispatch(self, group_params, outer, tc, *, mu, lr, num_pods=1,
                     weights=None):
        # Mean the replicas BEFORE subtracting the anchor — the seed
        # simulator's operation order, preserved bit for bit (mean-then-
        # subtract and subtract-then-mean agree mathematically, not in
        # floating point).
        if weights is None:
            mean_params = jax.tree.map(
                lambda p: jnp.mean(p.astype(jnp.float32), axis=0),
                group_params)
        else:
            mean_params = jax.tree.map(
                lambda p: weighted_stack_mean(p.astype(jnp.float32),
                                              weights), group_params)
        delta = jax.tree.map(
            lambda m, a: m - a.astype(jnp.float32), mean_params, outer.anchor)
        return outer_reduce(outer, delta, tc, mu=mu, lr=lr)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        if weights is None:
            return jax.tree.map(lambda d: jnp.mean(d, axis=0),
                                delta), residual
        return jax.tree.map(lambda d: weighted_stack_mean(d, weights),
                            delta), residual


@dataclass(frozen=True)
class Quantized(OuterSyncStrategy):
    """Blockwise-quantized Δθ payload with error feedback.

    Each group (or pod, under :class:`Hierarchical`) quantizes its payload
    to ``bits`` with per-``block`` fp32 absmax scales; the *dequantized*
    value — exactly what int8+scales deliver on the wire — is exchanged,
    and what quantization dropped is carried in the residual so the error
    telescopes across syncs instead of biasing the Nesterov momentum.
    """

    bits: int = 8
    block: int = 256

    needs_residual = True

    @property
    def name(self) -> str:
        return f"quantized(int{self.bits},block={self.block})"

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        d, r = compress_delta(d, r, bits=self.bits, block=self.block,
                              use_pallas=ctx.use_pallas)
        if ctx.exchange_axes:
            if ctx.weight is not None:
                d = weighted_psum_mean(d, ctx.weight, ctx.exchange_axes)
            else:
                d = jax.lax.pmean(d, ctx.exchange_axes)
        return d, r

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        payload, new_res = jax.vmap(
            lambda d, r: compress_delta(d, r, bits=self.bits,
                                        block=self.block))(delta, residual)
        if weights is None:
            return jax.tree.map(lambda d: jnp.mean(d, axis=0),
                                payload), new_res
        return jax.tree.map(lambda d: weighted_stack_mean(d, weights),
                            payload), new_res


@dataclass(frozen=True)
class Int8Wire(OuterSyncStrategy):
    """True int8 wire format: ring exchange of the packed (q, scales) pairs.

    Same blockwise quantization + error feedback as :class:`Quantized`,
    but the *actual* quantized payload crosses the slow exchange axes —
    packed int8 (or nibble-packed int4) values plus per-block fp32 absmax
    scales — through a store-and-forward ring (Pallas remote-DMA on a real
    TPU, a ``jax.lax.ppermute`` reference ring elsewhere). Each endpoint
    accumulates the per-source dequantized partials in canonical source
    order and multiplies by ``1/E`` (per-source-scale sum semantics,
    DESIGN.md §8), so every endpoint produces bit-identical results and
    the payload mean equals :class:`Quantized`'s dequantized-payload mean.

    ``reduce_scatter=True`` replaces the full-payload ring with the
    explicit reduce-scatter → all-gather wire path (DESIGN.md §14):
    endpoint e reduces only slot e of every source's payload
    (``kernels.ring_allreduce.reduce_scatter_qs``), re-quantizes its
    reduced 1/E shard behind a *second* error-feedback residual
    (``OuterState.residual2``), and all-gathers the packed ``(q2, s2)``
    pair (``allgather_qs``) — per-device sent bytes drop from
    (E−1)·payload to 2·(E−1)/E·payload, and the residual/payload pair
    still telescopes exactly: ``reduced + r2 == dequant(q2, s2) + r2'``
    per slot. Both residuals thread as an opaque ``(r1, r2)`` pair (see
    ``OuterSyncStrategy.needs_residual2``).
    """

    bits: int = 8
    block: int = 256
    reduce_scatter: bool = False

    needs_residual = True

    @property
    def needs_residual2(self) -> bool:  # type: ignore[override]
        return self.reduce_scatter

    @property
    def name(self) -> str:
        if self.reduce_scatter:
            return f"rs-ag(int{self.bits},block={self.block})"
        return f"int{self.bits}-wire(block={self.block})"

    @property
    def wire_format(self) -> str:  # type: ignore[override]
        if self.reduce_scatter:
            return f"int{self.bits}+scales/rs-ag"
        return f"int{self.bits}+scales"

    def wire_bytes_per_param(self, tc) -> float:
        return self.bits / 8.0 + 4.0 / self.block

    def transport_name(self, mesh=None) -> str:
        from repro.kernels.ring_allreduce import resolve_transport

        names = ("data_outer",)
        if mesh is not None:
            from repro.launch.mesh import manual_axes

            names = manual_axes(mesh) or names
        return resolve_transport(axis_names=names)

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        from repro.core.outer import quant_fns
        from repro.kernels.ring_allreduce import ring_allreduce_quantized

        if self.reduce_scatter:
            return self._reduce_leaf_rs_ag(d, r, tc, ctx)
        quant, dequant = quant_fns(bits=self.bits, block=self.block,
                                   use_pallas=ctx.use_pallas)
        c = d.astype(jnp.float32)
        if r is not None:
            c = c + r.astype(jnp.float32)
        flat = c.reshape(-1)
        n = flat.shape[0]
        q, s = quant(flat)
        # the locally dequantized payload: exactly what every other
        # endpoint reconstructs from our (q, s) on the wire — the error
        # feedback telescopes against the value the wire delivers
        payload_local = dequant(q, s)[:n].reshape(c.shape)
        new_r = c - payload_local
        if not ctx.exchange_axes or ctx.exchange_size() <= 1:
            return payload_local, new_r
        # ctx.weights rides in exchange order (row-major over the
        # exchange axes — pod-level under Hierarchical, which narrows
        # the ctx with pod weight sums); None keeps the 1/E sum.
        avg = ring_allreduce_quantized(
            q, s, axis_names=ctx.exchange_axes, axis_sizes=ctx.axis_sizes,
            bits=self.bits, block=self.block, use_pallas=ctx.use_pallas,
            axis_coords=ctx.axis_coords, weights=ctx.weights)
        return avg[:n].reshape(c.shape), new_r

    def _reduce_leaf_rs_ag(self, d, r, tc, ctx: ReduceCtx):
        """The reduce-scatter + all-gather exchange of one Δθ leaf.

        ``r`` arrives as the opaque ``(r1, r2)`` residual pair (or None on
        the stateless path). The second residual is *stored* full-size in
        the leaf's shape — zeros outside this endpoint's own slot — so
        the OuterState layout (and its sharding specs) stay uniform; the
        slot is sliced out/scattered back around the exchange. Slot
        padding positions beyond the leaf carry exact zeros end to end
        (zero-padded blocks reduce to zero, a zero residual re-quantizes
        to zero), so truncating the stored residual to the leaf is
        lossless — the invariant tests/test_rs_ag_wire.py proves.
        """
        from repro.core.outer import quant_fns
        from repro.kernels.ref import wire_shard_blocks
        from repro.kernels.ring_allreduce import (_linear_exchange_idx,
                                                  allgather_qs,
                                                  reduce_scatter_qs)

        r1, r2 = r if isinstance(r, tuple) else (r, None)
        quant, dequant = quant_fns(bits=self.bits, block=self.block,
                                   use_pallas=ctx.use_pallas)
        c = d.astype(jnp.float32)
        if r1 is not None:
            c = c + r1.astype(jnp.float32)
        flat = c.reshape(-1)
        n = flat.shape[0]
        q, s = quant(flat)
        payload_local = dequant(q, s)[:n].reshape(c.shape)
        new_r1 = c - payload_local
        if not ctx.exchange_axes or ctx.exchange_size() <= 1:
            # no exchange: deliver the local dequant; the gather-leg
            # residual has nothing new to absorb
            return payload_local, (new_r1, r2)
        E = ctx.exchange_size()
        sb = wire_shard_blocks(int(s.shape[0]), E)
        slot = sb * self.block
        _, idx = _linear_exchange_idx(ctx.exchange_axes, ctx.axis_sizes,
                                      ctx.axis_coords)
        reduced = reduce_scatter_qs(
            q, s, axis_names=ctx.exchange_axes, axis_sizes=ctx.axis_sizes,
            bits=self.bits, block=self.block, use_pallas=ctx.use_pallas,
            axis_coords=ctx.axis_coords, weights=ctx.weights)
        # second error feedback on my reduced shard, then the gather leg
        if r2 is None:
            r2_shard = jnp.zeros((slot,), jnp.float32)
        else:
            r2_flat = jnp.pad(r2.astype(jnp.float32).reshape(-1),
                              (0, E * slot - n))
            r2_shard = jax.lax.dynamic_slice(r2_flat, (idx * slot,), (slot,))
        c2 = reduced + r2_shard
        q2, s2 = quant(c2)
        new_r2_shard = c2 - dequant(q2, s2)[:slot]
        payload = allgather_qs(
            q2, s2, axis_names=ctx.exchange_axes, axis_sizes=ctx.axis_sizes,
            bits=self.bits, block=self.block, use_pallas=ctx.use_pallas,
            axis_coords=ctx.axis_coords)
        new_r2 = jax.lax.dynamic_update_slice(
            jnp.zeros((E * slot,), jnp.float32), new_r2_shard,
            (idx * slot,))[:n].reshape(c.shape)
        return payload[:n].reshape(c.shape), (new_r1, new_r2)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        """Exact model of the ring: per-source-scale sum in source order.

        Shares :func:`repro.kernels.ref.dequant_sum_sources` with the
        distributed transport and the test oracle — the same subgraph on
        the same packed stacks, so the sim ↔ distributed equivalence
        binds bit for bit (not just numerically). ``pod_grouped`` (set by
        the hierarchical combinator) marks the stacked entries as
        pod-duplicated: the ring endpoints are then the pods, one
        representative each — including the pod-less ``P == 1`` case,
        where the distributed path quantizes the global mean once with no
        exchange at all.
        """
        from repro.kernels.ref import (dequant_sum_sources, pack_wire,
                                       dequantize_blockwise_ref,
                                       quantize_blockwise_ref)

        if self.reduce_scatter:
            return self._sim_reduce_rs_ag(delta, residual, tc,
                                          num_pods=num_pods,
                                          pod_grouped=pod_grouped,
                                          weights=weights)
        bits, block = self.bits, self.block
        src_w = weights
        if weights is not None and pod_grouped:
            # pod-duplicated stack: the ring endpoints are the pods, so
            # the per-source weights are the per-entry pod weights'
            # representatives (Hierarchical already broadcast each pod's
            # weight sum over its entries)
            P = max(num_pods, 1)
            src_w = jnp.asarray(weights, jnp.float32).reshape(P, -1)[:, 0]

        def leaf(d, r):
            G = d.shape[0]
            c = d.astype(jnp.float32)
            if r is not None:
                c = c + r.astype(jnp.float32)
            flat = c.reshape(G, -1)
            n = flat.shape[1]
            q, s = jax.vmap(lambda x: quantize_blockwise_ref(
                x, bits=bits, block=block))(flat)
            payload_local = jax.vmap(lambda q1, s1: dequantize_blockwise_ref(
                q1, s1, block=block))(q, s)[:, :n].reshape(c.shape)
            new_r = c - payload_local
            if pod_grouped:
                P = max(num_pods, 1)
                q = q.reshape(P, G // P, *q.shape[1:])[:, 0]
                s = s.reshape(P, G // P, *s.shape[1:])[:, 0]
            E = q.shape[0]
            wg = jnp.stack([pack_wire(q[j], bits) for j in range(E)])
            avg = dequant_sum_sources(wg, s, bits=bits, block=block,
                                      weights=src_w)
            return avg[:n].reshape(c.shape[1:]), new_r

        flat_d, treedef = jax.tree_util.tree_flatten(delta)
        flat_r = (treedef.flatten_up_to(residual) if residual is not None
                  else [None] * len(flat_d))
        out = [leaf(d, r) for d, r in zip(flat_d, flat_r)]
        unf = jax.tree_util.tree_unflatten
        return (unf(treedef, [p for p, _ in out]),
                unf(treedef, [r for _, r in out]))

    def _sim_reduce_rs_ag(self, delta, residual, tc, *, num_pods=1,
                          pod_grouped=False, weights=None):
        """Exact model of the rs/ag exchange: the (G,)-stacked sources
        ARE the endpoints, and the whole round trip runs through
        :func:`repro.kernels.ref.rs_ag_qs_ref` — the identical subgraph
        the distributed ``reduce_scatter_qs``/``allgather_qs`` legs
        decompose into, so sim ↔ distributed binds bit for bit.
        ``residual`` is the opaque ``(r1_tree, r2_tree)`` pair."""
        from repro.kernels.ref import (dequantize_blockwise_ref,
                                       quantize_blockwise_ref,
                                       rs_ag_qs_ref, wire_shard_blocks)

        if pod_grouped:
            raise ValueError(
                "the rs/ag wire path does not compose with the "
                "hierarchical two-stage reduce: the reduce-scatter "
                "already owns the slow-axis layout")
        bits, block = self.bits, self.block
        r1_tree, r2_tree = (residual if isinstance(residual, tuple)
                            else (residual, None))

        def leaf(d, r1, r2):
            G = d.shape[0]
            c = d.astype(jnp.float32)
            if r1 is not None:
                c = c + r1.astype(jnp.float32)
            flat = c.reshape(G, -1)
            n = flat.shape[1]
            q, s = jax.vmap(lambda x: quantize_blockwise_ref(
                x, bits=bits, block=block))(flat)
            payload_local = jax.vmap(lambda q1, s1: dequantize_blockwise_ref(
                q1, s1, block=block))(q, s)[:, :n].reshape(c.shape)
            new_r1 = c - payload_local
            E = G
            if E <= 1:
                return payload_local[0], new_r1, (r2 if r2 is not None
                                                  else jnp.zeros_like(c))
            sb = wire_shard_blocks(int(s.shape[1]), E)
            slot = sb * block
            # endpoint g's stored full-size residual2 -> its own slot g
            if r2 is None:
                r2_shards = jnp.zeros((E, slot), jnp.float32)
            else:
                r2_pad = jnp.pad(r2.astype(jnp.float32).reshape(G, -1),
                                 ((0, 0), (0, E * slot - n)))
                r2_shards = r2_pad.reshape(E, E, slot)[
                    jnp.arange(E), jnp.arange(E)]
            payload, new_r2_shards = rs_ag_qs_ref(
                q, s, block=block, bits=bits, residual2=r2_shards,
                weights=weights)
            new_r2 = jnp.zeros((E, E * slot), jnp.float32).reshape(
                E, E, slot).at[jnp.arange(E), jnp.arange(E)].set(
                new_r2_shards).reshape(E, E * slot)[:, :n].reshape(c.shape)
            return payload[:n].reshape(c.shape[1:]), new_r1, new_r2

        flat_d, treedef = jax.tree_util.tree_flatten(delta)
        flat_r1 = (treedef.flatten_up_to(r1_tree) if r1_tree is not None
                   else [None] * len(flat_d))
        flat_r2 = (treedef.flatten_up_to(r2_tree) if r2_tree is not None
                   else [None] * len(flat_d))
        out = [leaf(d, r1, r2)
               for d, r1, r2 in zip(flat_d, flat_r1, flat_r2)]
        unf = jax.tree_util.tree_unflatten
        return (unf(treedef, [p for p, _, _ in out]),
                (unf(treedef, [r1 for _, r1, _ in out]),
                 unf(treedef, [r2 for _, _, r2 in out])))


@dataclass(frozen=True)
class Sharded(OuterSyncStrategy):
    """Auto-axis combinator: exchange only the per-device Δθ shard.

    The replicated strategies materialize every full Δθ leaf on every
    device before the manual-axis pmean — fine at 124M, fatal at 7B with
    tensor/FSDP parallelism, where no device holds a full leaf to begin
    with. This combinator keeps each leaf pinned to its ``param_specs``
    sharding over the auto (GSPMD) axes — the per-leaf ``PartitionSpec``
    threaded through ``ReduceCtx.leaf_spec`` — so GSPMD lowers the
    manual-axis pmean as shard-local collectives (reduce-scatter +
    all-gather shape, ZeRO++-style) and nothing full-size is ever built.

    - ``Sharded(FlatFP32())``: constraints never change values, and the
      pmean is the same reduction — **bit-identical** to the replicated
      flat-fp32 path.
    - ``Sharded(Quantized(...))``: leaves whose size divides
      ``block * A`` (A = auto-axis shard count) quantize shard-locally —
      every shard holds whole quantization blocks, so blockwise absmax
      never crosses a shard boundary and the blocks are bitwise what the
      unsharded :class:`Quantized` produces. Ragged leaves pad in-graph
      to whole per-shard blocks and still quantize shard-locally on
      modern jax; on jaxlib 0.4.x (where the in-graph pad/slice trips a
      partitioner CHECK — see :func:`_can_pad_in_manual`) they fall back
      to the inner replicated round trip. Same numeric model, same
      simulator tolerance.
    - ``Sharded(Int8Wire(...))``: the explicit reduce-scatter +
      all-gather wire exchange (DESIGN.md §14). The combinator force-
      normalizes the inner's ``reduce_scatter=True`` — a full-payload
      ring under the sharded layout would rebuild every leaf on every
      device, the exact thing this combinator exists to avoid — and pins
      the delivered payload and both residuals back to the leaf spec, so
      shard-resident outer state composes with the 1/E wire traffic.

    With ``sharded_state`` the step builder additionally pins the outer
    momentum/anchor/residual(s) and dispatch buffers to the same specs
    via jit ``out_shardings``, so outer-state memory per device scales as
    ~1/(TP×FSDP) (DESIGN.md §10).
    """

    inner: OuterSyncStrategy = FlatFP32()

    sharded_state = True

    def __post_init__(self):
        if isinstance(self.inner, Int8Wire):
            if not self.inner.reduce_scatter:
                # normalize: the sharded wire exchange IS the rs/ag path
                object.__setattr__(
                    self, "inner",
                    dataclasses.replace(self.inner, reduce_scatter=True))
        elif not isinstance(self.inner, (FlatFP32, Quantized)):
            raise ValueError(
                f"Sharded composes FlatFP32, Quantized or Int8Wire, got "
                f"{type(self.inner).__name__}: combinators cannot nest "
                f"inside the sharded exchange")

    @property
    def name(self) -> str:
        return f"sharded[{self.inner.name}]"

    @property
    def needs_residual(self) -> bool:  # type: ignore[override]
        return self.inner.needs_residual

    @property
    def needs_residual2(self) -> bool:  # type: ignore[override]
        return self.inner.needs_residual2

    @property
    def wire_format(self) -> str:  # type: ignore[override]
        return self.inner.wire_format

    def wire_bytes_per_param(self, tc) -> float:
        return self.inner.wire_bytes_per_param(tc)

    def transport_name(self, mesh=None) -> str:
        return self.inner.transport_name(mesh)

    def plan(self, pshapes, tc, mesh=None) -> SyncPlan:
        return self.inner.plan(pshapes, tc, mesh)._replace(name=self.name)

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        d = constrain_to_spec(d, ctx.leaf_spec, ctx)
        if isinstance(self.inner, Int8Wire):
            # the rs/ag exchange owns reduction AND layout: run it, then
            # pin the delivered payload and both residuals back to the
            # leaf's auto-axis spec so the outer state stays shard-resident
            d, rr = self.inner.reduce_leaf(d, r, tc, ctx)
            d = constrain_to_spec(d, ctx.leaf_spec, ctx)
            if isinstance(rr, tuple):
                rr = tuple(
                    constrain_to_spec(x, ctx.leaf_spec, ctx)
                    if x is not None else None for x in rr)
            elif rr is not None:
                rr = constrain_to_spec(rr, ctx.leaf_spec, ctx)
            return d, rr
        if isinstance(self.inner, Quantized):
            block = self.inner.block
            if d.size % (block * max(ctx.auto_size(), 1)) == 0:
                d, r = self._compress_sharded(d, r, ctx)
            elif _can_pad_in_manual():
                # modern jax: pad the flat payload to whole per-shard
                # blocks in-graph and take the shard-local path anyway
                d, r = self._compress_sharded(d, r, ctx, pad=True)
            else:
                # Ragged leaf on jaxlib 0.4.x: padding (or slicing) the
                # flat payload inside the partial-manual region trips an
                # XLA partitioner CHECK
                # (hlo_sharding_util IsManualSubgroup — the same class
                # of CHECK that gates md_dryrun_mini), so leaves that
                # don't divide into whole per-shard blocks keep the
                # inner strategy's replicated round trip. Only small
                # odd leaves land here; the big block-divisible
                # matrices — the bytes that matter — still shard.
                d, r = compress_delta(d, r, bits=self.inner.bits,
                                      block=block,
                                      use_pallas=ctx.use_pallas)
        if ctx.exchange_axes:
            if ctx.weight is not None:
                d = weighted_psum_mean(d, ctx.weight, ctx.exchange_axes)
            else:
                d = jax.lax.pmean(d, ctx.exchange_axes)
        d = constrain_to_spec(d, ctx.leaf_spec, ctx)
        return d, r

    def _compress_sharded(self, d, r, ctx: ReduceCtx, *, pad: bool = False):
        """Shard-local blockwise quantize/dequantize with error feedback.

        Works on the flat payload constrained to one combined auto-axis
        dim. Without ``pad`` the caller guarantees the leaf divides into
        whole per-shard blocks (``n % (block·shards) == 0``), so the
        quantize/dequantize round trip never crosses a shard boundary and
        no in-graph pad/slice is needed. With ``pad`` (ragged leaves on
        modern jax — :func:`_can_pad_in_manual`) the flat payload is
        zero-padded to the next whole per-shard block multiple first and
        the round trip sliced back; zero padding quantizes to zero scales
        and dequantizes to exact zeros, so the blocks covering real data
        are bitwise unchanged.
        """
        from jax.sharding import PartitionSpec as P

        from repro.core.outer import quant_fns

        bits, block = self.inner.bits, self.inner.block
        quant, dequant = quant_fns(bits=bits, block=block,
                                   use_pallas=ctx.use_pallas)
        c = d.astype(jnp.float32)
        if r is not None:
            c = c + r.astype(jnp.float32)
        flat = c.reshape(-1)
        n = flat.shape[0]
        if pad:
            unit = block * max(ctx.auto_size(), 1)
            flat = jnp.pad(flat, (0, -n % unit))
        row = P(tuple(ctx.auto_axes)) if ctx.auto_axes else None
        flat = constrain_to_spec(flat, row, ctx)
        q, s = quant(flat)
        q = constrain_to_spec(q, row, ctx)
        s = constrain_to_spec(s, row, ctx)
        payload = dequant(q, s)
        if pad:  # keep the divisible path's graph byte-identical: no slice
            payload = payload[:n]
        payload = payload.reshape(c.shape)
        payload = constrain_to_spec(payload, ctx.leaf_spec, ctx)
        new_r = constrain_to_spec(c - payload, ctx.leaf_spec, ctx)
        return payload, new_r

    def sim_dispatch(self, group_params, outer, tc, *, mu, lr, num_pods=1,
                     weights=None):
        # the sharded exchange is a layout change, not a numeric one: the
        # simulator models it with the inner strategy's reduction
        return self.inner.sim_dispatch(group_params, outer, tc, mu=mu,
                                       lr=lr, num_pods=num_pods,
                                       weights=weights)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        return self.inner.sim_reduce(delta, residual, tc,
                                     num_pods=num_pods,
                                     pod_grouped=pod_grouped,
                                     weights=weights)


@dataclass(frozen=True)
class Hierarchical(OuterSyncStrategy):
    """Two-stage reduce: fp32 intra-pod mean, then ``inner``'s exchange
    over the slow pod axes. Degenerates to ``inner`` over the full manual
    set on a pod-less mesh (where the fast-domain mean is already the full
    reduce)."""

    inner: OuterSyncStrategy = FlatFP32()

    two_stage = True

    def __post_init__(self):
        if getattr(self.inner, "needs_residual2", False):
            raise ValueError(
                "Hierarchical cannot compose the reduce-scatter wire "
                "path: the rs/ag exchange already owns the slow-axis "
                "layout (its shards ARE the endpoints); use the plain "
                "int8-wire ring under Hierarchical, or rs-ag flat / "
                "under Sharded")

    @property
    def name(self) -> str:
        return f"hierarchical[{self.inner.name}]"

    @property
    def needs_residual(self) -> bool:  # type: ignore[override]
        return self.inner.needs_residual

    @property
    def wire_format(self) -> str:  # type: ignore[override]
        return self.inner.wire_format

    @property
    def sharded_state(self) -> bool:  # type: ignore[override]
        return self.inner.sharded_state

    def wire_bytes_per_param(self, tc) -> float:
        return self.inner.wire_bytes_per_param(tc)

    def transport_name(self, mesh=None) -> str:
        return self.inner.transport_name(mesh)

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        inner_ctx = ctx
        if ctx.fast_axes:
            if ctx.weight is not None:
                # stage 1: weighted fast-domain mean; the pod's weight for
                # stage 2 is its live weight sum (a dead pod exchanges a
                # zero payload at weight 0)
                d = weighted_psum_mean(d, ctx.weight, ctx.fast_axes)
                pod_w = jax.lax.psum(
                    jnp.asarray(ctx.weight, jnp.float32), ctx.fast_axes)
                sizes = ctx.axis_sizes or {}
                P = int(sizes.get("pod", 1))
                # per-pod weight sums in pod (slow-axis) order: manual
                # linearization is pod-major, so the (G,) vector reshapes
                # (P, G//P) directly
                pod_vec = jnp.asarray(ctx.weights, jnp.float32
                                      ).reshape(P, -1).sum(axis=1)
                inner_ctx = ctx.narrowed(ctx.slow_axes).with_membership(
                    pod_vec, pod_w)
            else:
                d = jax.lax.pmean(d, ctx.fast_axes)  # stage 1: fast, fp32
                inner_ctx = ctx.narrowed(ctx.slow_axes)
        d, r = self.inner.reduce_leaf(d, r, tc, inner_ctx)
        if r is not None and ctx.fast_axes and self.inner.needs_residual:
            # the residual stopped varying over the fast axes at the
            # stage-1 pmean; re-mark it for the stacked P(manual) spec
            r = compat.pvary(r, ctx.fast_axes)
        return d, r

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        P = max(num_pods, 1)
        leaves = jax.tree_util.tree_leaves(delta)
        if leaves:
            validate_pod_grouping(leaves[0].shape[0], P)

        # stage 1: full-precision mean over the fast intra-pod axis,
        # broadcast back so every group in a pod holds the pod mean
        # (== its payload input; residuals stay pod-identical). P == 1
        # degenerates to reducing the *global* mean once — exactly the
        # distributed path on a pod-less mesh.
        def pod_mean(d):
            G = d.shape[0]
            dp = d.reshape(P, G // P, *d.shape[1:])
            if weights is not None:
                wp = jnp.asarray(weights, jnp.float32).reshape(
                    (P, G // P) + (1,) * (d.ndim - 1))
                sw = jnp.sum(wp, axis=1, keepdims=True)
                inv = jnp.where(sw > 0, jnp.float32(1.0) / sw,
                                jnp.float32(0.0))
                pm = jnp.sum(dp * wp, axis=1, keepdims=True) * inv
            else:
                pm = jnp.mean(dp, axis=1, keepdims=True)
            return jnp.broadcast_to(pm, (P, G // P, *d.shape[1:])
                                    ).reshape(d.shape)

        delta = jax.tree.map(pod_mean, delta)
        entry_w = weights
        if weights is not None:
            # per-entry pod weight sums (broadcast over each pod's
            # entries): the inner reduction weighs pod means by pod
            # liveness, and ring inners pick the [:, 0] representatives
            wp = jnp.asarray(weights, jnp.float32).reshape(P, -1)
            entry_w = jnp.broadcast_to(
                wp.sum(axis=1, keepdims=True), wp.shape).reshape(-1)
        return self.inner.sim_reduce(delta, residual, tc,
                                     num_pods=num_pods, pod_grouped=True,
                                     weights=entry_w)


@dataclass(frozen=True)
class Chunked(OuterSyncStrategy):
    """Span combinator: dispatch the Δθ leaf tree as ``num_chunks``
    contiguous spans, each its own XLA computation over ``inner``'s
    reduction, each carrying its own per-chunk dispatch state so apply can
    start on early-arriving chunks. Numerically identical to ``inner``
    (the per-leaf math never changes); only host dispatch order does."""

    inner: OuterSyncStrategy = FlatFP32()
    num_chunks: int = 2

    def __post_init__(self):
        if getattr(self.inner, "needs_residual2", False):
            raise ValueError(
                "Chunked cannot (yet) compose the reduce-scatter wire "
                "path: per-chunk threading of the second residual is a "
                "recorded follow-up (DESIGN.md §14); use rs-ag with "
                "chunks=1")

    @property
    def name(self) -> str:
        return f"chunked({self.num_chunks})[{self.inner.name}]"

    @property
    def needs_residual(self) -> bool:  # type: ignore[override]
        return self.inner.needs_residual

    @property
    def two_stage(self) -> bool:  # type: ignore[override]
        return self.inner.two_stage

    @property
    def wire_format(self) -> str:  # type: ignore[override]
        return self.inner.wire_format

    @property
    def sharded_state(self) -> bool:  # type: ignore[override]
        return self.inner.sharded_state

    def wire_bytes_per_param(self, tc) -> float:
        return self.inner.wire_bytes_per_param(tc)

    def transport_name(self, mesh=None) -> str:
        return self.inner.transport_name(mesh)

    def plan(self, pshapes, tc, mesh=None) -> SyncPlan:
        sizes = _leaf_sizes(pshapes)
        # clamp to the leaf count: more chunks than leaves would plan
        # empty spans (an empty tree keeps the fused single span, which
        # dispatch handles as a no-op computation)
        chunks = max(1, min(self.num_chunks, len(sizes)))
        spans = (balanced_spans(sizes, chunks) if sizes
                 else ((0, 0),))
        return SyncPlan(num_leaves=len(sizes), spans=spans,
                        needs_residual=self.needs_residual, name=self.name,
                        wire_format=self.wire_format,
                        transport=self.transport_name(mesh))

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        return self.inner.reduce_leaf(d, r, tc, ctx)

    def sim_dispatch(self, group_params, outer, tc, *, mu, lr, num_pods=1,
                     weights=None):
        return self.inner.sim_dispatch(group_params, outer, tc, mu=mu,
                                       lr=lr, num_pods=num_pods,
                                       weights=weights)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1,
                   pod_grouped=False, weights=None):
        return self.inner.sim_reduce(delta, residual, tc,
                                     num_pods=num_pods,
                                     pod_grouped=pod_grouped,
                                     weights=weights)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


def validate_pod_grouping(num_groups: int, num_pods: int) -> None:
    """The hierarchical two-stage reduce partitions the G groups into
    ``num_pods`` equal pods; an indivisible count used to surface as an
    opaque reshape error deep inside ``sim_reduce`` — fail loudly and
    early instead (plan time / run construction)."""
    P = max(int(num_pods), 1)
    if num_groups % P != 0:
        raise ValueError(
            f"hierarchical reduce needs num_pods ({P}) to divide the "
            f"group count ({num_groups}); got {num_groups} % {P} = "
            f"{num_groups % P}")


def resolve_strategy(cfg) -> OuterSyncStrategy:
    """Map an ``OuterCommConfig`` (or a ``TrainConfig`` carrying one) onto
    the equivalent strategy object. Every legacy flat-flag combination
    resolves here — the strategies are bit-identical to the flag branches
    they replaced (asserted by tests/test_sync_strategies.py)."""
    comm = getattr(cfg, "outer_comm", cfg)
    core: OuterSyncStrategy
    if comm.compression == "quantize":
        core = Quantized(bits=comm.bits, block=comm.block)
    elif comm.compression == "int8-wire":
        core = Int8Wire(bits=comm.bits, block=comm.block)
    elif comm.compression == "rs-ag":
        core = Int8Wire(bits=comm.bits, block=comm.block,
                        reduce_scatter=True)
    elif comm.compression == "none":
        core = FlatFP32()
    else:
        raise ValueError(f"unknown outer compression {comm.compression!r}")
    if getattr(comm, "sharded", False):
        core = Sharded(inner=core)
    if comm.hierarchical:
        core = Hierarchical(inner=core)
    if comm.chunks > 1:
        core = Chunked(inner=core, num_chunks=comm.chunks)
    return core


def strategy_name(*, bits: int = 32, block: int = 256,
                  hierarchical: bool = False, chunks: int = 1,
                  sharded: bool = False,
                  compression: Optional[str] = None) -> str:
    """Resolved-strategy name for benchmark knobs (bits >= 32 = fp32).

    ``compression`` pins the wire format explicitly (``"int8-wire"``,
    ``"rs-ag"``, ...); when ``None`` it is inferred from ``bits`` the
    legacy way (fp32 vs blockwise quantize)."""
    from repro.config import OuterCommConfig

    if compression is None:
        compression = "none" if bits >= 32 else "quantize"
    comm = OuterCommConfig(
        compression=compression,
        bits=bits if bits < 32 else 8, block=block,
        hierarchical=hierarchical, chunks=chunks, sharded=sharded)
    return resolve_strategy(comm).name
