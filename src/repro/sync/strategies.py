"""Concrete outer-sync strategies + the legacy-flag resolver (DESIGN.md §7).

- :class:`FlatFP32` — the seed collective: one flat fp32 pmean of Δθ over
  every manual (group) axis. Bit-identical to the pre-strategy path.
- :class:`Quantized` — blockwise-quantized payload (int8/int4 values +
  per-block fp32 absmax scales) with an error-feedback residual carried
  group-locally in ``OuterState.residual``.
- :class:`Hierarchical` — two-stage combinator: full-precision mean over
  the fast intra-pod axes first, then the *inner* strategy's exchange over
  the slow pod axes (1/pods of the traffic crosses the slow domain).
- :class:`Chunked` — span combinator: the Δθ leaf tree dispatches as
  ``num_chunks`` contiguous spans, each its own XLA computation with its
  own per-chunk :class:`~repro.sync.base.ChunkDispatch`, so early chunks'
  collectives (and applies) overlap later chunks' quantization.

:func:`resolve_strategy` maps an :class:`~repro.config.OuterCommConfig`
(or a ``TrainConfig`` carrying one — including every legacy flat-flag
combination via the deprecation shim) onto the equivalent strategy object.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.outer import compress_delta, outer_reduce
from repro.sync.base import (OuterSyncStrategy, ReduceCtx, SyncPlan,
                             balanced_spans, _leaf_sizes)


@dataclass(frozen=True)
class FlatFP32(OuterSyncStrategy):
    """Flat fp32 pmean of Δθ over the manual axes — the seed collective."""

    @property
    def name(self) -> str:
        return "flat-fp32"

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        if ctx.exchange_axes:
            d = jax.lax.pmean(d, ctx.exchange_axes)
        return d, r

    def sim_dispatch(self, group_params, outer, tc, *, mu, lr, num_pods=1):
        # Mean the replicas BEFORE subtracting the anchor — the seed
        # simulator's operation order, preserved bit for bit (mean-then-
        # subtract and subtract-then-mean agree mathematically, not in
        # floating point).
        mean_params = jax.tree.map(
            lambda p: jnp.mean(p.astype(jnp.float32), axis=0), group_params)
        delta = jax.tree.map(
            lambda m, a: m - a.astype(jnp.float32), mean_params, outer.anchor)
        return outer_reduce(outer, delta, tc, mu=mu, lr=lr)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1):
        return jax.tree.map(lambda d: jnp.mean(d, axis=0), delta), residual


@dataclass(frozen=True)
class Quantized(OuterSyncStrategy):
    """Blockwise-quantized Δθ payload with error feedback.

    Each group (or pod, under :class:`Hierarchical`) quantizes its payload
    to ``bits`` with per-``block`` fp32 absmax scales; the *dequantized*
    value — exactly what int8+scales deliver on the wire — is exchanged,
    and what quantization dropped is carried in the residual so the error
    telescopes across syncs instead of biasing the Nesterov momentum.
    """

    bits: int = 8
    block: int = 256

    needs_residual = True

    @property
    def name(self) -> str:
        return f"quantized(int{self.bits},block={self.block})"

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        d, r = compress_delta(d, r, bits=self.bits, block=self.block,
                              use_pallas=ctx.use_pallas)
        if ctx.exchange_axes:
            d = jax.lax.pmean(d, ctx.exchange_axes)
        return d, r

    def sim_reduce(self, delta, residual, tc, *, num_pods=1):
        payload, new_res = jax.vmap(
            lambda d, r: compress_delta(d, r, bits=self.bits,
                                        block=self.block))(delta, residual)
        return jax.tree.map(lambda d: jnp.mean(d, axis=0), payload), new_res


@dataclass(frozen=True)
class Hierarchical(OuterSyncStrategy):
    """Two-stage reduce: fp32 intra-pod mean, then ``inner``'s exchange
    over the slow pod axes. Degenerates to ``inner`` over the full manual
    set on a pod-less mesh (where the fast-domain mean is already the full
    reduce)."""

    inner: OuterSyncStrategy = FlatFP32()

    two_stage = True

    @property
    def name(self) -> str:
        return f"hierarchical[{self.inner.name}]"

    @property
    def needs_residual(self) -> bool:  # type: ignore[override]
        return self.inner.needs_residual

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        inner_ctx = ctx
        if ctx.fast_axes:
            d = jax.lax.pmean(d, ctx.fast_axes)  # stage 1: fast domain, fp32
            inner_ctx = ctx.narrowed(ctx.slow_axes)
        d, r = self.inner.reduce_leaf(d, r, tc, inner_ctx)
        if r is not None and ctx.fast_axes and self.inner.needs_residual:
            # the residual stopped varying over the fast axes at the
            # stage-1 pmean; re-mark it for the stacked P(manual) spec
            r = compat.pvary(r, ctx.fast_axes)
        return d, r

    def sim_reduce(self, delta, residual, tc, *, num_pods=1):
        P = max(num_pods, 1)

        # stage 1: full-precision mean over the fast intra-pod axis,
        # broadcast back so every group in a pod holds the pod mean
        # (== its payload input; residuals stay pod-identical). P == 1
        # degenerates to reducing the *global* mean once — exactly the
        # distributed path on a pod-less mesh.
        def pod_mean(d):
            G = d.shape[0]
            pm = jnp.mean(d.reshape(P, G // P, *d.shape[1:]), axis=1,
                          keepdims=True)
            return jnp.broadcast_to(pm, (P, G // P, *d.shape[1:])
                                    ).reshape(d.shape)

        delta = jax.tree.map(pod_mean, delta)
        return self.inner.sim_reduce(delta, residual, tc, num_pods=num_pods)


@dataclass(frozen=True)
class Chunked(OuterSyncStrategy):
    """Span combinator: dispatch the Δθ leaf tree as ``num_chunks``
    contiguous spans, each its own XLA computation over ``inner``'s
    reduction, each carrying its own per-chunk dispatch state so apply can
    start on early-arriving chunks. Numerically identical to ``inner``
    (the per-leaf math never changes); only host dispatch order does."""

    inner: OuterSyncStrategy = FlatFP32()
    num_chunks: int = 2

    @property
    def name(self) -> str:
        return f"chunked({self.num_chunks})[{self.inner.name}]"

    @property
    def needs_residual(self) -> bool:  # type: ignore[override]
        return self.inner.needs_residual

    @property
    def two_stage(self) -> bool:  # type: ignore[override]
        return self.inner.two_stage

    def plan(self, pshapes, tc, mesh=None) -> SyncPlan:
        sizes = _leaf_sizes(pshapes)
        spans = balanced_spans(sizes, self.num_chunks)
        return SyncPlan(num_leaves=len(sizes), spans=spans,
                        needs_residual=self.needs_residual, name=self.name)

    def reduce_leaf(self, d, r, tc, ctx: ReduceCtx):
        return self.inner.reduce_leaf(d, r, tc, ctx)

    def sim_dispatch(self, group_params, outer, tc, *, mu, lr, num_pods=1):
        return self.inner.sim_dispatch(group_params, outer, tc, mu=mu,
                                       lr=lr, num_pods=num_pods)

    def sim_reduce(self, delta, residual, tc, *, num_pods=1):
        return self.inner.sim_reduce(delta, residual, tc, num_pods=num_pods)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


def resolve_strategy(cfg) -> OuterSyncStrategy:
    """Map an ``OuterCommConfig`` (or a ``TrainConfig`` carrying one) onto
    the equivalent strategy object. Every legacy flat-flag combination
    resolves here — the strategies are bit-identical to the flag branches
    they replaced (asserted by tests/test_sync_strategies.py)."""
    comm = getattr(cfg, "outer_comm", cfg)
    core: OuterSyncStrategy
    if comm.compression == "quantize":
        core = Quantized(bits=comm.bits, block=comm.block)
    elif comm.compression == "none":
        core = FlatFP32()
    else:
        raise ValueError(f"unknown outer compression {comm.compression!r}")
    if comm.hierarchical:
        core = Hierarchical(inner=core)
    if comm.chunks > 1:
        core = Chunked(inner=core, num_chunks=comm.chunks)
    return core


def strategy_name(*, bits: int = 32, block: int = 256,
                  hierarchical: bool = False, chunks: int = 1) -> str:
    """Resolved-strategy name for benchmark knobs (bits >= 32 = fp32)."""
    from repro.config import OuterCommConfig

    comm = OuterCommConfig(
        compression="none" if bits >= 32 else "quantize",
        bits=bits if bits < 32 else 8, block=block,
        hierarchical=hierarchical, chunks=chunks)
    return resolve_strategy(comm).name
