"""GQA / MQA / MHA attention with RoPE, qk-norm, sliding window, KV cache.

Reference (pure-jnp) math lives here; the Pallas flash-attention kernel in
``repro.kernels`` is selected with ``use_pallas=True`` (TPU target; validated
in interpret mode on CPU).

Cache layout: ``{"k": (B, S_cache, Hkv, hd), "v": ..., "length": int32 ()}``
where ``S_cache`` is the window size for sliding-window layers and the full
context otherwise. Sliding-window caches are ring buffers indexed by absolute
position mod window; every slot stores its absolute position in ``"pos"``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.parallel.axes import logical_constraint

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# attention chunking policy (memory-efficient q-blocked attention)
# ---------------------------------------------------------------------------
# "auto": chunk when S_q * S_kv exceeds _AUTO_THRESHOLD (bounds the scores
# buffer — the XLA-visible analogue of flash attention's tiling, used when
# the Pallas kernel is off); "never": always materialize full scores (exact
# FLOPs accounting for the dry-run cost compiles); int: explicit chunk size.

import contextlib
import threading


class _ChunkPolicy(threading.local):
    def __init__(self):
        self.value = "auto"


_CHUNK_POLICY = _ChunkPolicy()
_AUTO_THRESHOLD = 1 << 24  # 16M score elements
_AUTO_CHUNK = 1024


@contextlib.contextmanager
def chunk_policy(value):
    prev = _CHUNK_POLICY.value
    _CHUNK_POLICY.value = value
    try:
        yield
    finally:
        _CHUNK_POLICY.value = prev


def _resolve_chunk(sq: int, skv: int):
    pol = _CHUNK_POLICY.value
    if pol == "never":
        return 0
    if pol == "auto":
        if sq > 1 and sq * skv > _AUTO_THRESHOLD:
            return min(_AUTO_CHUNK, sq)
        return 0
    return min(int(pol), sq) if sq > 1 else 0


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, *, cross: bool = False):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": L.dense_init(ks[0], (cfg.d_model, cfg.num_heads, hd), dtype=pd),
        "wk": L.dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads, hd), dtype=pd),
        "wv": L.dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads, hd), dtype=pd),
        "wo": L.out_proj_init(
            ks[3], (cfg.num_heads, hd, cfg.d_model), cfg.num_layers, dtype=pd
        ),
    }
    if cfg.use_qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), pd)
        p["k_norm"] = jnp.ones((hd,), pd)
    return p


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def gqa_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, Hkv, hd)
    v: jax.Array,  # (B, Skv, Hkv, hd)
    *,
    q_positions: jax.Array,  # (Sq,) or (B, Sq) absolute positions
    kv_positions: jax.Array,  # (Skv,) or (B, Skv); -1 marks invalid slots
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    use_pallas: bool = False,
) -> jax.Array:
    """Grouped-query attention with positional masking. Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv

    if use_pallas and Sq > 1:
        from repro.kernels import ops as kops

        if kops.flash_attention_supported(q, k, v, window=window, softcap=softcap):
            return kops.flash_attention(
                q, k, v, causal=causal, window=window, softcap=softcap
            )

    qp = q_positions if q_positions.ndim == 2 else q_positions[None]
    kp = kv_positions if kv_positions.ndim == 2 else kv_positions[None]
    qp = jnp.broadcast_to(qp, (B, Sq))

    def block(qblk, qpblk):
        """Attention of a q block against the full K/V. (B,sq,H,hd)."""
        sq = qblk.shape[1]
        mask = kp[:, None, :] >= 0
        if causal:
            mask &= kp[:, None, :] <= qpblk[:, :, None]
        if window > 0:
            mask &= qpblk[:, :, None] - kp[:, None, :] < window
        qg = qblk.reshape(B, sq, Hkv, G, hd)
        scores = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
            k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
        if softcap > 0:
            scores = softcap * jnp.tanh(scores / softcap)
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
        return out.reshape(B, sq, H, hd).astype(q.dtype)

    chunk = _resolve_chunk(Sq, k.shape[1])
    if chunk == 0 or Sq % chunk != 0:
        return block(q, qp)
    # q-blocked memory-efficient path: scores buffer is (chunk, Skv)
    nblk = Sq // chunk
    qb = jnp.moveaxis(q.reshape(B, nblk, chunk, H, hd), 1, 0)
    pb = jnp.moveaxis(qp.reshape(B, nblk, chunk), 1, 0)
    outb = jax.lax.map(lambda args: block(*args), (qb, pb))
    return jnp.moveaxis(outb, 0, 1).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# layer application (train / prefill / decode)
# ---------------------------------------------------------------------------


def _project_qkv(p, x, xkv, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, L.cast(p["wq"], cfg))
    k = jnp.einsum("bsd,dhk->bshk", xkv, L.cast(p["wk"], cfg))
    v = jnp.einsum("bsd,dhk->bshk", xkv, L.cast(p["wv"], cfg))
    if "q_norm" in p:
        q = L.rms_norm_headwise(q, p["q_norm"])
        k = L.rms_norm_headwise(k, p["k_norm"])
    return q, k, v


def apply_self_attention(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # (S,) absolute positions of x's tokens
    window: int = 0,
    cache: Optional[dict] = None,
    use_pallas: bool = False,
    return_kv: bool = False,
    causal: bool = True,
):
    """Self-attention over x.

    - training / prefill: ``cache=None``; set ``return_kv=True`` in prefill to
      get the (k, v) streams back for cache assembly.
    - decode: ``cache`` given, x is the single new token (S == 1); the cache is
      a ring buffer for sliding-window layers (slot = pos % window) and a
      linear buffer otherwise.

    Returns (out, extra) where extra is the new cache (decode), the (k, v)
    pair (prefill with return_kv), or None.
    """
    q, k, v = _project_qkv(p, x, x, cfg)
    if cfg.positional == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    q = logical_constraint(q, "batch", None, "tp", None)

    if cache is None:
        out = gqa_attention(
            q, k, v,
            q_positions=positions, kv_positions=positions,
            causal=causal, window=window, softcap=0.0, use_pallas=use_pallas,
        )
        extra = (k, v) if return_kv else None
    else:
        cache_k, cache_v, cache_pos = cache["k"], cache["v"], cache["pos"]
        S_cache = cache_k.shape[1]
        B = x.shape[0]
        start = cache["length"]
        slot = start % S_cache if window > 0 else start
        pos_row = jnp.broadcast_to(positions[None].astype(jnp.int32), (B, 1))
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
        cache_pos = jax.lax.dynamic_update_slice(cache_pos, pos_row, (0, slot))
        out = gqa_attention(
            q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
            q_positions=positions, kv_positions=cache_pos,
            causal=True, window=window, softcap=0.0, use_pallas=False,
        )
        extra = {
            "k": cache_k, "v": cache_v, "pos": cache_pos,
            "length": start + 1,
        }

    out = logical_constraint(out, "batch", None, "tp", None)
    out = jnp.einsum("bshk,hkd->bsd", out, L.cast(p["wo"], cfg))
    return out, extra


def apply_cross_attention(p, x, encoder_kv, cfg: ModelConfig):
    """Cross-attention (whisper decoder). encoder_kv = (k, v) precomputed."""
    q = jnp.einsum("bsd,dhk->bshk", x, L.cast(p["wq"], cfg))
    k, v = encoder_kv
    Skv = k.shape[1]
    out = gqa_attention(
        q, k, v,
        q_positions=jnp.full((x.shape[1],), Skv, jnp.int32),  # attend to all
        kv_positions=jnp.arange(Skv, dtype=jnp.int32),
        causal=False,
    )
    return jnp.einsum("bshk,hkd->bsd", out, L.cast(p["wo"], cfg))


def encoder_kv(p, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (decode cache)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, L.cast(p["wk"], cfg))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, L.cast(p["wv"], cfg))
    return k, v


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def cache_from_kv(
    cfg: ModelConfig, k, v, positions, *, max_len: int, window: int = 0
):
    """Assemble a decode cache from prefill (k, v) streams.

    For sliding-window layers only the last ``window`` tokens are kept, laid
    out in ring order (slot = pos % window) so decode inserts continue the
    ring seamlessly.
    """
    B, S = k.shape[0], k.shape[1]
    cache = init_cache(cfg, B, max_len, window=window)
    size = cache["k"].shape[1]
    keep = min(S, size)
    k_keep = k[:, S - keep:]
    v_keep = v[:, S - keep:]
    pos_keep = jnp.broadcast_to(
        positions[S - keep:][None].astype(jnp.int32), (B, keep))
    if window > 0:
        slots = (positions[S - keep:] % size).astype(jnp.int32)
        cache["k"] = cache["k"].at[:, slots].set(k_keep.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, slots].set(v_keep.astype(cache["v"].dtype))
        cache["pos"] = cache["pos"].at[:, slots].set(pos_keep)
    else:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_keep.astype(cache["k"].dtype), (0, S - keep, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_keep.astype(cache["v"].dtype), (0, S - keep, 0, 0))
        cache["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], pos_keep, (0, S - keep))
    cache["length"] = jnp.asarray(S, jnp.int32)
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, window: int = 0):
    """Empty KV cache. ``pos`` = -1 marks unwritten slots."""
    hd = cfg.resolved_head_dim
    size = min(max_len, window) if window > 0 else max_len
    dt = L.compute_dtype(cfg)
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, hd), dt),
        "pos": jnp.full((batch, size), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }
