"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    log a_t = -c * softplus(Lambda) * r_t   # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill parallelizes the linear recurrence with
``jax.lax.associative_scan``; decode keeps O(1) state — so long_500k runs
natively. Block layout (Griffin recurrent block): two input linears, a
short causal conv, the RG-LRU, a GeLU gate branch, and an output linear.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.ssm import _causal_conv1d

_C = 8.0  # Griffin's fixed gate sharpness
_MAX_SQRT_GRADIENT = 1000.0


def init_rglru(key, cfg: ModelConfig):
    D = cfg.d_model
    W = cfg.resolved_lru_width
    ks = jax.random.split(key, 8)
    pd = jnp.dtype(cfg.param_dtype)
    # Lambda init so that a^c is uniform-ish in [0.9, 0.999] (Griffin A.2)
    u = jax.random.uniform(ks[5], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "w_x": L.dense_init(ks[0], (D, W), dtype=pd),  # recurrent branch in
        "w_y": L.dense_init(ks[1], (D, W), dtype=pd),  # gate branch in
        "conv": L.dense_init(ks[2], (cfg.conv1d_width, W), scale=0.1, dtype=pd),
        "w_a": L.dense_init(ks[3], (W, W), scale=0.01, dtype=pd),
        "b_a": jnp.zeros((W,), pd),
        "w_i": L.dense_init(ks[4], (W, W), scale=0.01, dtype=pd),
        "b_i": jnp.zeros((W,), pd),
        "lambda": lam.astype(pd),
        "w_down": L.out_proj_init(ks[6], (W, D), cfg.num_layers, dtype=pd),
    }


def _rglru_gates(p, u):
    """u: (B, S, W) conv output (fp32). Returns (log_a, gated_input)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["w_a"].astype(jnp.float32))
        + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["w_i"].astype(jnp.float32))
        + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a2 = jnp.exp(2 * log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a2, 1.0 / _MAX_SQRT_GRADIENT**2, 1.0))
    return log_a, beta * (i * u)


def _linear_scan(log_a, x0, h0: Optional[jax.Array]):
    """h_t = a_t h_{t-1} + x0_t via associative scan. log_a/x0: (B,S,W)."""
    if h0 is not None:
        x0 = x0.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(left, right):
        la_l, x_l = left
        la_r, x_r = right
        return la_l + la_r, jnp.exp(la_r) * x_l + x_r

    _, h = jax.lax.associative_scan(combine, (log_a, x0), axis=1)
    return h


def apply_rglru(p, x, cfg: ModelConfig, *, state=None, return_state=False):
    """Griffin recurrent block. state=None -> parallel scan; else one step."""
    B, S, D = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, L.cast(p["w_y"], cfg)))
    u = jnp.einsum("bsd,dw->bsw", x, L.cast(p["w_x"], cfg))
    if state is None:
        uc, _ = _causal_conv1d(u, L.cast(p["conv"], cfg))
        log_a, x0 = _rglru_gates(p, uc.astype(jnp.float32))
        h = _linear_scan(log_a, x0, None)
        new_state = None
        if return_state:
            W = p["conv"].shape[0]
            new_state = {
                "hidden": h[:, -1].astype(jnp.float32),
                "conv": u[:, -(W - 1):].astype(L.compute_dtype(cfg)),
            }
    else:
        uc, new_conv = _causal_conv1d(u, L.cast(p["conv"], cfg), state["conv"])
        log_a, x0 = _rglru_gates(p, uc.astype(jnp.float32))
        h = jnp.exp(log_a[:, 0]) * state["hidden"] + x0[:, 0]
        new_state = {"hidden": h, "conv": new_conv}
        h = h[:, None]
    out = jnp.einsum("bsw,wd->bsd", (h.astype(x.dtype) * gate),
                     L.cast(p["w_down"], cfg))
    return out, new_state


def init_rglru_state(cfg: ModelConfig, batch: int):
    W = cfg.resolved_lru_width
    return {
        "hidden": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, W), L.compute_dtype(cfg)),
    }
