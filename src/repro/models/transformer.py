"""Composable decoder (and encoder-decoder) stack covering all families.

A decoder layer is built from the config's block pattern:

  - "attn"        pre-norm self-attention (+ MLP/MoE sub-block)
  - "local_attn"  sliding-window attention (window = cfg.local_window)
  - "mla"         selected via cfg.attention_kind == "mla" for attn blocks
  - "mlstm"/"slstm"  xLSTM blocks (self-contained: no separate MLP if d_ff==0)
  - "rglru"       Griffin recurrent block (+ MLP sub-block)

MoE architectures replace the MLP with the routed-experts layer from layer
``first_dense_layers`` onward.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.parallel.axes import logical_constraint


def _layer_has_mlp(cfg: ModelConfig, kind: str) -> bool:
    if kind in ("mlstm", "slstm"):
        return False
    return cfg.d_ff > 0 or cfg.is_moe


def _layer_uses_moe(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.is_moe and layer_idx >= cfg.first_dense_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_decoder_layer(key, cfg: ModelConfig, layer_idx: int, *, cross: bool = False):
    kind = cfg.block_kind(layer_idx)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": L.init_norm(ks[0], cfg)}
    if kind in ("attn", "local_attn"):
        if cfg.attention_kind == "mla":
            p["mix"] = MLA.init_mla(ks[1], cfg)
        else:
            p["mix"] = A.init_attention(ks[1], cfg)
    elif kind == "mlstm":
        p["mix"] = SSM.init_mlstm(ks[1], cfg)
    elif kind == "slstm":
        p["mix"] = SSM.init_slstm(ks[1], cfg)
    elif kind == "rglru":
        p["mix"] = RG.init_rglru(ks[1], cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cross:
        p["norm_cross"] = L.init_norm(ks[2], cfg)
        p["cross"] = A.init_attention(ks[3], cfg, cross=True)
    if _layer_has_mlp(cfg, kind):
        p["norm2"] = L.init_norm(ks[4], cfg)
        if _layer_uses_moe(cfg, layer_idx):
            p["mlp"] = MOE.init_moe(ks[5], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[5], cfg)
    return p


def init_encoder_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "norm1": L.init_norm(ks[0], cfg),
        "mix": A.init_attention(ks[1], cfg),
        "norm2": L.init_norm(ks[2], cfg),
        "mlp": L.init_mlp(ks[3], cfg),
    }


def layer_segments(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(prefix, cycle_len, n_cycles, suffix) for the scan-layers layout.

    Layers [0, prefix) and [prefix + n*C, L) stay unrolled (structure
    differs / remainder); the middle n cycles of C layers are stacked and
    executed with ``lax.scan`` — one compiled cycle body regardless of depth.
    """
    C = len(cfg.block_pattern)
    prefix = cfg.first_dense_layers if cfg.is_moe else 0
    rest = cfg.num_layers - prefix
    n_cycles = rest // C
    suffix = rest - n_cycles * C
    return prefix, C, n_cycles, suffix


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def is_scanned(layers) -> bool:
    return isinstance(layers, dict) and "scan" in layers


def init_params(key, cfg: ModelConfig, *, scan_layers: bool = False):
    ks = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 3)
    cross = cfg.is_encoder_decoder

    def mk(i):
        return init_decoder_layer(ks[2 + i], cfg, i, cross=cross)

    if scan_layers:
        prefix, C, n, suffix = layer_segments(cfg)
        cycles = [
            [mk(prefix + j * C + c) for c in range(C)] for j in range(n)
        ]
        layers = {
            "prefix": [mk(i) for i in range(prefix)],
            "scan": _stack_trees(cycles) if n > 0 else None,
            "suffix": [mk(cfg.num_layers - suffix + i)
                       for i in range(suffix)],
        }
    else:
        layers = [mk(i) for i in range(cfg.num_layers)]

    params: Dict[str, Any] = {
        "embed": L.init_embeddings(ks[0], cfg),
        "final_norm": L.init_norm(ks[1], cfg),
        "layers": layers,
    }
    if cfg.is_encoder_decoder:
        off = 2 + cfg.num_layers
        enc_layers = [init_encoder_layer(ks[off + i], cfg)
                      for i in range(cfg.encoder_layers)]
        if scan_layers:
            enc_layers = {"prefix": [], "suffix": [],
                          "scan": _stack_trees([[l] for l in enc_layers])}
        params["encoder"] = {
            "layers": enc_layers,
            "final_norm": L.init_norm(ks[off + cfg.encoder_layers], cfg),
            "positions": L.dense_init(
                ks[off + cfg.encoder_layers],
                (cfg.encoder_seq_len, cfg.d_model),
                dtype=jnp.dtype(cfg.param_dtype)),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_mix(
    lp, x, cfg: ModelConfig, kind: str, *, positions, state=None,
    use_pallas=False, return_kv=False,
):
    window = cfg.local_window if kind == "local_attn" else cfg.sliding_window
    if kind in ("attn", "local_attn"):
        if cfg.attention_kind == "mla":
            return MLA.apply_mla(
                lp, x, cfg, positions=positions, cache=state,
                use_pallas=use_pallas, return_kv=return_kv)
        return A.apply_self_attention(
            lp, x, cfg, positions=positions, window=window, cache=state,
            use_pallas=use_pallas, return_kv=return_kv)
    if kind == "mlstm":
        return SSM.apply_mlstm(lp, x, cfg, state=state, return_state=return_kv)
    if kind == "slstm":
        return SSM.apply_slstm(lp, x, cfg, state=state, return_state=return_kv)
    if kind == "rglru":
        return RG.apply_rglru(lp, x, cfg, state=state, return_state=return_kv)
    raise ValueError(kind)


def _decoder_layer_fwd(
    lp, x, cfg: ModelConfig, layer_idx: int, *, positions,
    encoder_kv=None, enc_out=None, state=None, use_pallas=False,
    return_kv=False,
):
    """One decoder layer. Returns (x, extra, aux).

    Cross-attention K/V comes either precomputed (``encoder_kv``, decode) or
    is projected here from ``enc_out`` (training/prefill — scan-compatible).
    """
    kind = cfg.block_kind(layer_idx)
    h = L.apply_norm(lp["norm1"], x, cfg)
    mix_out, extra = _apply_mix(
        lp["mix"], h, cfg, kind, positions=positions, state=state,
        use_pallas=use_pallas, return_kv=return_kv)
    x = x + mix_out
    if encoder_kv is None and enc_out is not None:
        encoder_kv = A.encoder_kv(lp["cross"], enc_out, cfg)
    if encoder_kv is not None:
        h = L.apply_norm(lp["norm_cross"], x, cfg)
        x = x + A.apply_cross_attention(lp["cross"], h, encoder_kv, cfg)
    aux = None
    if _layer_has_mlp(cfg, kind):
        h = L.apply_norm(lp["norm2"], x, cfg)
        if _layer_uses_moe(cfg, layer_idx):
            mlp_out, aux = MOE.apply_moe(lp["mlp"], h, cfg)
        else:
            mlp_out = L.apply_mlp(lp["mlp"], h, cfg)
        x = x + mlp_out
    x = logical_constraint(x, "batch", None, None)
    return x, extra, aux


def encode(params, cfg: ModelConfig, frames):
    """Whisper encoder on (stubbed) frame embeddings (B, S_enc, D)."""
    enc = params["encoder"]
    x = frames.astype(L.compute_dtype(cfg))
    x = x + L.cast(enc["positions"], cfg)[None, : x.shape[1]]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def enc_layer(lp, x):
        h = L.apply_norm(lp["norm1"], x, cfg)
        mix, _ = A.apply_self_attention(
            lp["mix"], h, cfg, positions=positions, window=0, cache=None,
            causal=False)  # bidirectional encoder
        x = x + mix
        h = L.apply_norm(lp["norm2"], x, cfg)
        return x + L.apply_mlp(lp["mlp"], h, cfg)

    layers = enc["layers"]
    if is_scanned(layers):
        def body(x, lp):
            return enc_layer(lp[0], x), None
        x, _ = jax.lax.scan(body, x, layers["scan"])
    else:
        for lp in layers:
            x = enc_layer(lp, x)
    return L.apply_norm(enc["final_norm"], x, cfg)


def forward(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    use_pallas: bool = False,
    remat: str = "none",
    collect_kv: bool = False,
):
    """Training/prefill forward. batch: {"tokens": (B,S)[, "frames": ...]}.

    Returns (logits, aux) where aux = {"moe_aux", "moe_z", "kv" (if collected)}.
    """
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = L.embed_tokens(params["embed"], tokens, cfg)

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["frames"])

    # Zero-valued but VMA-varying like x: under shard_map the layer-scan
    # carry must have consistent varying-axes annotations between the init
    # and the body output (the aux losses depend on x inside the body).
    _vma_zero = jnp.sum(x[:0].astype(jnp.float32))
    moe_aux = _vma_zero
    moe_z = _vma_zero

    def run_layer(lp, x, idx):
        return _decoder_layer_fwd(
            lp, x, cfg, idx, positions=positions, enc_out=enc_out,
            state=None, use_pallas=use_pallas, return_kv=collect_kv)

    def _ckpt(fn, static_argnums=()):
        if remat == "full":
            return jax.checkpoint(fn, static_argnums=static_argnums)
        if remat == "selective":
            # save matmul outputs, recompute elementwise/norm chains —
            # the standard "dots saveable" policy: ~no extra matmul FLOPs,
            # most of full remat's activation-memory savings
            return jax.checkpoint(
                fn, static_argnums=static_argnums,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return fn

    run_unrolled = _ckpt(run_layer, static_argnums=(2,))

    layers = params["layers"]
    if is_scanned(layers):
        prefix, C, n, suffix = layer_segments(cfg)
        kv = {"prefix": [], "scan": None, "suffix": []}
        for i, lp in enumerate(layers["prefix"]):
            x, extra, aux = run_unrolled(lp, x, i)
            if aux is not None:
                moe_aux, moe_z = moe_aux + aux["aux_loss"], moe_z + aux["z_loss"]
            kv["prefix"].append(extra)

        if layers["scan"] is not None and n > 0:
            def cycle_body(carry, cycle_lp):
                x, a_aux, a_z = carry
                extras = []
                for c in range(C):
                    x, extra, aux = run_layer(cycle_lp[c], x, prefix + c)
                    if aux is not None:
                        a_aux = a_aux + aux["aux_loss"]
                        a_z = a_z + aux["z_loss"]
                    extras.append(extra)
                ys = extras if collect_kv else None
                return (x, a_aux, a_z), ys

            body = _ckpt(cycle_body)
            (x, moe_aux, moe_z), ys = jax.lax.scan(
                body, (x, moe_aux, moe_z), layers["scan"])
            if collect_kv:
                kv["scan"] = ys  # list per c of stacked (n, ...) pytrees

        for j, lp in enumerate(layers["suffix"]):
            idx = cfg.num_layers - suffix + j
            x, extra, aux = run_unrolled(lp, x, idx)
            if aux is not None:
                moe_aux, moe_z = moe_aux + aux["aux_loss"], moe_z + aux["z_loss"]
            kv["suffix"].append(extra)
        kv_streams = kv
    else:
        kv_streams = []
        for i, lp in enumerate(layers):
            x, extra, aux = run_unrolled(lp, x, i)
            if aux is not None:
                moe_aux = moe_aux + aux["aux_loss"]
                moe_z = moe_z + aux["z_loss"]
            if collect_kv:
                kv_streams.append(extra)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)
    aux_out = {"moe_aux": moe_aux, "moe_z": moe_z}
    if collect_kv:
        aux_out["kv"] = kv_streams
    return logits, aux_out
