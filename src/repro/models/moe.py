"""Mixture-of-Experts layer: top-k routing, shared experts, expert parallel.

Dispatch uses the sort-based "expert slots" formulation rather than GShard's
one-hot einsum: the (tokens, experts, capacity) dispatch tensor is never
materialized (it would be ~3e13 elements at the DeepSeek-V2 production shape).
Instead token->slot indices are computed with an argsort + searchsorted, and
tokens are scattered into a (experts, capacity, d_model) buffer that is
sharded over the ``experts`` logical axis (the model mesh axis) — GSPMD turns
the scatter/gather into the expert-parallel all-to-all.

Aux losses: switch-style load-balance loss + router z-loss, returned so the
train step can add them to the LM loss.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.parallel.axes import logical_constraint


class _DispatchMode(threading.local):
    def __init__(self):
        # "flat": sentinel-slot scatter into a flat (E*C+1, D) buffer — the
        #   only formulation whose GRADIENT survives XLA's SPMD partitioner
        #   (2D-indexed scatter-add into an expert-sharded operand
        #   CHECK-fails in spmd_partitioner_util.cc) -> training default.
        # "indexed": 2D (expert, position) scatter/gather against the
        #   (E, C, D) buffer kept expert-sharded end to end — no flat
        #   replicated buffer, much cheaper dispatch. Inference-only
        #   (forward gathers partition fine).
        self.value = "flat"


_DISPATCH = _DispatchMode()


@contextlib.contextmanager
def dispatch_mode(value: str):
    prev = _DISPATCH.value
    _DISPATCH.value = value
    try:
        yield
    finally:
        _DISPATCH.value = prev


def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": L.dense_init(ks[0], (D, E), scale=0.02 / math.sqrt(D / 768), dtype=pd),
        "w_gate": L.dense_init(ks[1], (E, D, F), dtype=pd),
        "w_up": L.dense_init(ks[2], (E, D, F), dtype=pd),
        "w_down": L.out_proj_init(ks[3], (E, F, D), cfg.num_layers, dtype=pd),
    }
    if cfg.num_shared_experts > 0:
        shared_ff = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = L.init_mlp(ks[4], cfg, d_ff=shared_ff)
    return p


def expert_capacity(num_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert slot count, rounded up to a multiple of 8 for TPU layout."""
    raw = num_tokens * cfg.num_experts_per_tok / cfg.num_experts
    cap = int(math.ceil(raw * cfg.expert_capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)


def apply_moe(p, x, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out, {"aux_loss", "z_loss", "load"})."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    C = expert_capacity(T, cfg)
    xf = x.reshape(T, D)

    # ---- routing (fp32) ----
    logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, K)  # (T, K)
    topk_probs = topk_probs / jnp.clip(
        jnp.sum(topk_probs, axis=-1, keepdims=True), 1e-9)

    # ---- aux losses ----
    # Switch-Transformer load-balance loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    assign = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32))
    fe = assign / (T * K)
    aux_loss = E * jnp.sum(fe * me)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- slot assignment (sort-based) ----
    flat_expert = topk_idx.reshape(-1)  # (T*K,)
    sort_idx = jnp.argsort(flat_expert, stable=True)  # (T*K,)
    sorted_expert = flat_expert[sort_idx]
    # first index of each expert in the sorted order
    first = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    pos_in_expert = jnp.arange(T * K) - first[sorted_expert]
    token_of_assign = jnp.arange(T * K) // K
    indexed = _DISPATCH.value == "indexed"
    if indexed:
        # inference dispatch: (expert, position) scatter/gather against the
        # expert-sharded (E, C, D) buffer (see dispatch_mode docstring)
        pos = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(
            pos_in_expert.astype(jnp.int32))
        eid = flat_expert.astype(jnp.int32)
        buf = jnp.zeros((E, C, D), x.dtype)
        buf = logical_constraint(buf, "experts", None, None)
        buf = buf.at[eid, pos].set(xf[token_of_assign], mode="drop")
        expert_in = logical_constraint(buf, "experts", None, None)
    else:
        kept = pos_in_expert < C
        slot_sorted = jnp.where(kept, sorted_expert * C + pos_in_expert,
                                E * C)
        # invert the sort: slot per assignment; E*C = dropped sentinel.
        # (flat scatter + reshape: the only formulation whose gradient
        # survives XLA's SPMD partitioner — see dispatch_mode docstring)
        slot = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(
            slot_sorted.astype(jnp.int32))
        buf = jnp.zeros((E * C + 1, D), x.dtype)
        buf = buf.at[slot].set(xf[token_of_assign], mode="drop")
        expert_in = buf[: E * C].reshape(E, C, D)
        expert_in = logical_constraint(expert_in, "experts", None, None)

    # ---- expert computation (SwiGLU) ----
    gate = jnp.einsum("ecd,edf->ecf", expert_in, L.cast(p["w_gate"], cfg))
    up = jnp.einsum("ecd,edf->ecf", expert_in, L.cast(p["w_up"], cfg))
    h = jax.nn.silu(gate) * up
    h = logical_constraint(h, "experts", None, None)
    expert_out = jnp.einsum("ecf,efd->ecd", h, L.cast(p["w_down"], cfg))

    # ---- combine: gather back and weight by router prob ----
    if indexed:
        per_assign = expert_out.at[eid, pos].get(
            mode="fill", fill_value=0)  # (T*K, D); dropped -> zeros
    else:
        out_buf = jnp.concatenate(
            [expert_out.reshape(E * C, D), jnp.zeros((1, D), x.dtype)],
            axis=0)
        per_assign = out_buf[slot]  # (T*K, D); dropped -> zero row
    weighted = per_assign * topk_probs.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.sum(weighted.reshape(T, K, D), axis=1).astype(x.dtype)
    out = out.reshape(B, S, D)
    out = logical_constraint(out, "batch", None, None)

    if "shared" in p:
        out = out + L.apply_mlp(p["shared"], x, cfg)

    stats = {"aux_loss": aux_loss, "z_loss": z_loss, "load": fe}
    return out, stats
