from repro.models.registry import (  # noqa: F401
    count_params,
    init_params,
    forward,
    loss_fn,
    init_decode_state,
    decode_step,
    prefill,
)
