"""Multi-head Latent Attention (DeepSeek-V2) [arXiv:2405.04434].

Training/prefill uses the decompressed formulation (materialize per-head K/V
from the latent ``c_kv``); decode uses the *absorbed* formulation against a
latent cache of ``kv_lora_rank + qk_rope_head_dim`` floats per token — the
whole point of MLA (the KV cache is rank-compressed, head-count independent).

Cache layout: ``{"ckv": (B, S, r), "krope": (B, S, dr), "pos": (B, S),
"length": ()}``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.attention import NEG_INF
from repro.parallel.axes import logical_constraint


def init_mla(key, cfg: ModelConfig):
    ks = jax.random.split(key, 10)
    pd = jnp.dtype(cfg.param_dtype)
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    p = {}
    if cfg.q_lora_rank > 0:
        p["w_dq"] = L.dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype=pd)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), pd)
        p["w_uq"] = L.dense_init(ks[1], (cfg.q_lora_rank, H, dn + dr), dtype=pd)
    else:
        p["w_q"] = L.dense_init(ks[1], (cfg.d_model, H, dn + dr), dtype=pd)
    p["w_dkv"] = L.dense_init(ks[2], (cfg.d_model, r), dtype=pd)
    p["kv_norm"] = jnp.ones((r,), pd)
    p["w_kr"] = L.dense_init(ks[3], (cfg.d_model, dr), dtype=pd)
    p["w_uk"] = L.dense_init(ks[4], (r, H, dn), dtype=pd)
    p["w_uv"] = L.dense_init(ks[5], (r, H, dv), dtype=pd)
    p["wo"] = L.out_proj_init(ks[6], (H, dv, cfg.d_model), cfg.num_layers, dtype=pd)
    return p


def _norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _queries(p, x, cfg: ModelConfig, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "w_dq" in p:
        cq = jnp.einsum("bsd,dr->bsr", x, L.cast(p["w_dq"], cfg))
        cq = _norm(cq, p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, L.cast(p["w_uq"], cfg))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, L.cast(p["w_q"], cfg))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, x, cfg: ModelConfig, positions):
    ckv = jnp.einsum("bsd,dr->bsr", x, L.cast(p["w_dkv"], cfg))
    ckv = _norm(ckv, p["kv_norm"])
    krope = jnp.einsum("bsd,dk->bsk", x, L.cast(p["w_kr"], cfg))
    krope = L.apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, krope


def apply_mla(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[dict] = None,
    use_pallas: bool = False,
    return_kv: bool = False,
):
    """Returns (out, extra) mirroring ``apply_self_attention``."""
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    q_nope, q_rope = _queries(p, x, cfg, positions)
    q_nope = logical_constraint(q_nope, "batch", None, "tp", None)

    if cache is None:
        ckv, krope = _latents(p, x, cfg, positions)
        # Decompressed training/prefill path: per-head K/V.
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, L.cast(p["w_uk"], cfg))
        v = jnp.einsum("bsr,rhv->bshv", ckv, L.cast(p["w_uv"], cfg))
        B, S = x.shape[:2]

        def qblock(qn, qr, qpos):
            sq = qn.shape[1]
            scores = (
                jnp.einsum("bqhd,bkhd->bhqk", qn.astype(jnp.float32),
                           k_nope.astype(jnp.float32))
                + jnp.einsum("bqhd,bkd->bhqk", qr.astype(jnp.float32),
                             krope.astype(jnp.float32))
            ) * scale
            mask = positions[None, :] <= qpos[:, None]  # causal
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqk,bkhv->bqhv", probs, v.astype(jnp.float32))
            return out.astype(x.dtype)

        from repro.models.attention import _resolve_chunk
        chunk = _resolve_chunk(S, S)
        if chunk == 0 or S % chunk != 0:
            out = qblock(q_nope, q_rope, positions)
        else:
            nb = S // chunk
            qnb = jnp.moveaxis(q_nope.reshape(B, nb, chunk, *q_nope.shape[2:]), 1, 0)
            qrb = jnp.moveaxis(q_rope.reshape(B, nb, chunk, *q_rope.shape[2:]), 1, 0)
            ppb = positions.reshape(nb, chunk)
            out = jax.lax.map(lambda a: qblock(*a), (qnb, qrb, ppb))
            out = jnp.moveaxis(out, 0, 1).reshape(B, S, *out.shape[3:])
        extra = (ckv, krope) if return_kv else None
    else:
        # Absorbed decode path against the latent cache.
        ckv_new, krope_new = _latents(p, x, cfg, positions)
        start = cache["length"]
        B = x.shape[0]
        cache_ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, start, 0))
        cache_kr = jax.lax.dynamic_update_slice(
            cache["krope"], krope_new.astype(cache["krope"].dtype), (0, start, 0))
        pos_row = jnp.broadcast_to(positions[None].astype(jnp.int32), (B, 1))
        cache_pos = jax.lax.dynamic_update_slice(cache["pos"], pos_row, (0, start))

        # absorb W_UK into q:  q_eff (B, 1, H, r)
        q_eff = jnp.einsum(
            "bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
            p["w_uk"].astype(jnp.float32))
        scores = (
            jnp.einsum("bqhr,bkr->bhqk", q_eff, cache_ckv.astype(jnp.float32))
            + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                         cache_kr.astype(jnp.float32))
        ) * scale
        valid = (cache_pos >= 0) & (cache_pos[:, :] <= positions[None, :])
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, cache_ckv.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhv->bqhv", o_lat, p["w_uv"].astype(jnp.float32))
        out = out.astype(x.dtype)
        extra = {
            "ckv": cache_ckv, "krope": cache_kr, "pos": cache_pos,
            "length": start + 1,
        }

    out = logical_constraint(out, "batch", None, "tp", None)
    out = jnp.einsum("bshv,hvd->bsd", out, L.cast(p["wo"], cfg))
    return out, extra


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = L.compute_dtype(cfg)
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dt),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }


def mla_cache_from_kv(cfg: ModelConfig, ckv, krope, positions, *, max_len: int):
    B, S = ckv.shape[0], ckv.shape[1]
    cache = init_mla_cache(cfg, B, max_len)
    cache["ckv"] = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
    cache["krope"] = jax.lax.dynamic_update_slice(
        cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0))
    cache["pos"] = cache["pos"].at[:, :S].set(
        jnp.broadcast_to(positions[None].astype(jnp.int32), (B, S)))
    cache["length"] = jnp.asarray(S, jnp.int32)
    return cache
