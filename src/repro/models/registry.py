"""Model registry: the public entry points every subsystem uses.

``init_params`` / ``forward`` / ``loss_fn`` for training;
``init_decode_state`` / ``prefill`` / ``decode_step`` for serving;
``count_params`` for 6ND roofline accounting.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models import transformer as T

init_params = T.init_params
forward = T.forward


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    use_pallas: bool = False,
    remat: str = "none",
):
    """Next-token cross-entropy (+ MoE aux losses). batch["labels"]: (B, S).

    Positions with label < 0 are masked out.
    """
    logits, aux = forward(
        params, cfg, batch, use_pallas=use_pallas, remat=remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    total = loss
    if cfg.is_moe:
        total = total + cfg.router_aux_loss_coef * aux["moe_aux"]
        total = total + 1e-4 * aux["moe_z"]
    metrics = {
        "lm_loss": loss,
        "moe_aux": aux["moe_aux"],
        "moe_z": aux["moe_z"],
        "tokens": jnp.sum(mask),
    }
    return total, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _layer_state(cfg: ModelConfig, layer_idx: int, batch: int, max_len: int):
    kind = cfg.block_kind(layer_idx)
    if kind in ("attn", "local_attn"):
        if cfg.attention_kind == "mla":
            return MLA.init_mla_cache(cfg, batch, max_len)
        window = cfg.local_window if kind == "local_attn" else cfg.sliding_window
        return A.init_cache(cfg, batch, max_len, window=window)
    if kind == "mlstm":
        return SSM.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return SSM.init_slstm_state(cfg, batch)
    if kind == "rglru":
        return RG.init_rglru_state(cfg, batch)
    raise ValueError(kind)


def _cross_kv_zeros(cfg: ModelConfig, batch: int):
    hd = cfg.resolved_head_dim
    dt = L.compute_dtype(cfg)
    z = lambda: jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dt)
    return (z(), z())


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      scan_layers: bool = False):
    """Decode state pytree: per-layer caches/states + global position.

    With ``scan_layers`` the per-layer states mirror the stacked param
    layout: {"prefix": [...], "scan": [stacked (n, ...) per cycle pos],
    "suffix": [...]}.
    """
    def mk(i):
        return _layer_state(cfg, i, batch, max_len)

    if scan_layers:
        prefix, C, n, suffix = T.layer_segments(cfg)
        layers = {
            "prefix": [mk(i) for i in range(prefix)],
            "scan": (T._stack_trees(
                [[mk(prefix + j * C + c) for c in range(C)]
                 for j in range(n)]) if n > 0 else None),
            "suffix": [mk(cfg.num_layers - suffix + i) for i in range(suffix)],
        }
    else:
        layers = [mk(i) for i in range(cfg.num_layers)]

    state: Dict[str, Any] = {
        "layers": layers,
        "position": jnp.zeros((), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        if scan_layers:
            prefix, C, n, suffix = T.layer_segments(cfg)
            state["cross_kv"] = {
                "prefix": [_cross_kv_zeros(cfg, batch) for _ in range(prefix)],
                "scan": (T._stack_trees(
                    [[_cross_kv_zeros(cfg, batch) for _ in range(C)]
                     for _ in range(n)]) if n > 0 else None),
                "suffix": [_cross_kv_zeros(cfg, batch) for _ in range(suffix)],
            }
        else:
            state["cross_kv"] = [
                _cross_kv_zeros(cfg, batch) for _ in range(cfg.num_layers)
            ]
    return state


def decode_step(params, cfg: ModelConfig, state, tokens):
    """One serving step: tokens (B, 1) -> (logits (B, 1, V), new_state)."""
    pos = state["position"]
    positions = pos[None].astype(jnp.int32)  # (1,)
    x = L.embed_tokens(params["embed"], tokens, cfg, position_offset=pos)
    layers = params["layers"]
    is_encdec = cfg.is_encoder_decoder

    if T.is_scanned(layers):
        prefix, C, n, suffix = T.layer_segments(cfg)
        new_layers = {"prefix": [], "scan": None, "suffix": []}
        for i, lp in enumerate(layers["prefix"]):
            enc_kv = state["cross_kv"]["prefix"][i] if is_encdec else None
            x, extra, _ = T._decoder_layer_fwd(
                lp, x, cfg, i, positions=positions, encoder_kv=enc_kv,
                state=state["layers"]["prefix"][i])
            new_layers["prefix"].append(extra)

        if layers["scan"] is not None and n > 0:
            xs = (layers["scan"], state["layers"]["scan"])
            if is_encdec:
                xs = xs + (state["cross_kv"]["scan"],)

            def body(x, inputs):
                cycle_lp, cycle_st = inputs[0], inputs[1]
                enc_kvs = inputs[2] if is_encdec else None
                new_sts = []
                for c in range(C):
                    x, extra, _ = T._decoder_layer_fwd(
                        cycle_lp[c], x, cfg, prefix + c,
                        positions=positions,
                        encoder_kv=enc_kvs[c] if enc_kvs else None,
                        state=cycle_st[c])
                    new_sts.append(extra)
                return x, new_sts

            x, new_scan_states = jax.lax.scan(body, x, xs)
            new_layers["scan"] = new_scan_states

        for j, lp in enumerate(layers["suffix"]):
            idx = cfg.num_layers - suffix + j
            enc_kv = state["cross_kv"]["suffix"][j] if is_encdec else None
            x, extra, _ = T._decoder_layer_fwd(
                lp, x, cfg, idx, positions=positions, encoder_kv=enc_kv,
                state=state["layers"]["suffix"][j])
            new_layers["suffix"].append(extra)
    else:
        new_layers = []
        for i, lp in enumerate(layers):
            enc_kv = state["cross_kv"][i] if is_encdec else None
            x, extra, _ = T._decoder_layer_fwd(
                lp, x, cfg, i, positions=positions, encoder_kv=enc_kv,
                state=state["layers"][i])
            new_layers.append(extra)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)
    new_state = dict(state)
    new_state["layers"] = new_layers
    new_state["position"] = pos + 1
    return logits, new_state


def prefill(params, cfg: ModelConfig, batch, *, max_len: int,
            use_pallas: bool = False):
    """Process a full prompt, returning (logits, decode_state).

    Attention layers collect their (k, v)/latent streams during the forward
    and assemble caches; recurrent layers re-run their scan to produce the
    final state (cheap relative to the forward).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    logits, aux = forward(
        params, cfg, batch, use_pallas=use_pallas, collect_kv=True)
    positions = jnp.arange(S, dtype=jnp.int32)

    def build_state(layer_idx, stream):
        """Turn a collected (k, v)/latent/recurrent stream into decode state."""
        kind = cfg.block_kind(layer_idx)
        if kind in ("attn", "local_attn"):
            if cfg.attention_kind == "mla":
                ckv, krope = stream
                return MLA.mla_cache_from_kv(
                    cfg, ckv, krope, positions, max_len=max_len)
            k, v = stream
            window = (cfg.local_window if kind == "local_attn"
                      else cfg.sliding_window)
            return A.cache_from_kv(
                cfg, k, v, positions, max_len=max_len, window=window)
        # recurrent blocks already returned their final state
        return stream

    layers = params["layers"]
    streams = aux["kv"]
    if T.is_scanned(layers):
        prefix, C, n, suffix = T.layer_segments(cfg)
        new_layers = {
            "prefix": [build_state(i, s)
                       for i, s in enumerate(streams["prefix"])],
            "scan": None,
            "suffix": [build_state(cfg.num_layers - suffix + j, s)
                       for j, s in enumerate(streams["suffix"])],
        }
        if streams["scan"] is not None:
            # streams["scan"] is a list (per cycle position c) of stacked
            # (n, ...) streams; vmap the cache builder over the cycle axis.
            new_layers["scan"] = [
                jax.vmap(lambda s, c=c: build_state(prefix + c, s))(sc)
                for c, sc in enumerate(streams["scan"])
            ]
    else:
        new_layers = [build_state(i, s) for i, s in enumerate(streams)]

    state = {"layers": new_layers,
             "position": jnp.asarray(S, jnp.int32)}
    if cfg.is_encoder_decoder:
        enc_out = T.encode(params, cfg, batch["frames"])
        if T.is_scanned(layers):
            prefix, C, n, suffix = T.layer_segments(cfg)
            cross = {
                "prefix": [A.encoder_kv(lp["cross"], enc_out, cfg)
                           for lp in layers["prefix"]],
                "scan": None,
                "suffix": [A.encoder_kv(lp["cross"], enc_out, cfg)
                           for lp in layers["suffix"]],
            }
            if layers["scan"] is not None:
                cross["scan"] = [
                    jax.vmap(
                        lambda lpc: A.encoder_kv(lpc["cross"], enc_out, cfg)
                    )(layers["scan"][c])
                    for c in range(C)
                ]
            state["cross_kv"] = cross
        else:
            state["cross_kv"] = [
                A.encoder_kv(lp["cross"], enc_out, cfg) for lp in layers
            ]
    return logits, state


# ---------------------------------------------------------------------------
# parameter counting (analytic via eval_shape — exact by construction)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0
    routed = 0

    def visit(path, leaf):
        nonlocal total, routed
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if cfg.is_moe and any(k in ("w_gate", "w_up", "w_down") for k in keys):
            if leaf.ndim == 3 and leaf.shape[0] == cfg.num_experts:
                routed += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    if active_only and cfg.is_moe and cfg.num_experts > 0:
        frac = cfg.num_experts_per_tok / cfg.num_experts
        return int(total - routed * (1.0 - frac))
    return int(total)
