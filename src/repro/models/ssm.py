"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory)
[arXiv:2405.04517].

mLSTM supports two equivalent formulations (equivalence is tested):
  - *parallel* (training/prefill): quadratic attention-like form with a
    stabilized log-gate decay matrix — this is the compute hot spot and the
    target of the ``mlstm_chunk`` Pallas kernel;
  - *recurrent* (decode): O(1) state ``(C: (B,H,dh,dh), n: (B,H,dh),
    m: (B,H))`` per layer -> long_500k decode runs natively.

sLSTM has recurrent (previous-h) connections, so training also scans.
Both use exponential gating with the max-tracker stabilizer from the paper.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L

PF = 2  # mLSTM up-projection factor


def _group_norm(h, scale, eps=1e-6):
    """Per-head RMS norm. h: (..., H, dh), scale: (H, dh)."""
    hf = h.astype(jnp.float32)
    ms = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    return (hf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(h.dtype)


def _causal_conv1d(x, kernel, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B, S, C); kernel: (W, C).

    With ``state`` ((B, W-1, C) trailing inputs) performs a streaming step and
    returns (y, new_state).
    """
    W = kernel.shape[0]
    if state is not None:
        ctx = jnp.concatenate([state, x], axis=1)  # (B, W-1+S, C)
        y = sum(
            ctx[:, i : i + x.shape[1]] * kernel[i][None, None]
            for i in range(W)
        )
        new_state = ctx[:, -(W - 1):] if W > 1 else state
        return y, new_state
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1]] * kernel[i][None, None] for i in range(W))
    return y, None


# ===========================================================================
# mLSTM
# ===========================================================================


def init_mlstm(key, cfg: ModelConfig):
    D = cfg.d_model
    Di = PF * D
    H = cfg.num_heads
    dh = Di // H
    ks = jax.random.split(key, 10)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "w_up": L.dense_init(ks[0], (D, 2 * Di), dtype=pd),
        "conv": L.dense_init(ks[1], (cfg.conv1d_width, Di), scale=0.1, dtype=pd),
        # block-diagonal (per-head) q/k/v projections, as in official xLSTM
        "wq": L.dense_init(ks[2], (H, dh, dh), dtype=pd),
        "wk": L.dense_init(ks[3], (H, dh, dh), dtype=pd),
        "wv": L.dense_init(ks[4], (H, dh, dh), dtype=pd),
        "w_igate": L.dense_init(ks[5], (Di, H), scale=0.01, dtype=pd),
        "b_igate": jnp.full((H,), -3.0, pd),  # bias low: mostly-closed input gate
        "w_fgate": L.dense_init(ks[6], (Di, H), scale=0.01, dtype=pd),
        "b_fgate": jnp.full((H,), 3.0, pd),  # bias high: mostly-open forget gate
        "out_norm": jnp.ones((H, dh), pd),
        "w_down": L.out_proj_init(ks[7], (Di, D), cfg.num_layers, dtype=pd),
    }


def _mlstm_qkv_gates(p, x, cfg: ModelConfig, conv_state=None):
    Di = PF * cfg.d_model
    H = cfg.num_heads
    up = jnp.einsum("bsd,de->bse", x, L.cast(p["w_up"], cfg))
    z, m_in = up[..., :Di], up[..., Di:]
    m_c, new_conv_state = _causal_conv1d(m_in, L.cast(p["conv"], cfg), conv_state)
    m_c = jax.nn.silu(m_c)
    B, S = x.shape[:2]
    dh = Di // H
    m_c_h = m_c.reshape(B, S, H, dh)
    m_in_h = m_in.reshape(B, S, H, dh)
    q = jnp.einsum("bshe,hef->bshf", m_c_h, L.cast(p["wq"], cfg))
    k = jnp.einsum("bshe,hef->bshf", m_c_h, L.cast(p["wk"], cfg))
    v = jnp.einsum("bshe,hef->bshf", m_in_h, L.cast(p["wv"], cfg))
    # gate pre-activations (fp32 for stability)
    ig = (jnp.einsum("bse,eh->bsh", m_c.astype(jnp.float32),
                     p["w_igate"].astype(jnp.float32))
          + p["b_igate"].astype(jnp.float32))
    fg = (jnp.einsum("bse,eh->bsh", m_c.astype(jnp.float32),
                     p["w_fgate"].astype(jnp.float32))
          + p["b_fgate"].astype(jnp.float32))
    return z, q, k, v, ig, fg, new_conv_state


def mlstm_parallel(q, k, v, ig, fg):
    """Stabilized quadratic mLSTM. q/k/v: (B,S,H,dh); ig/fg: (B,S,H) logits.

    Returns h: (B,S,H,dh). This is the pure-jnp oracle for the chunkwise
    Pallas kernel.
    """
    B, S, H, dh = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    vf = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(fg)  # (B,S,H)
    F = jnp.cumsum(log_f, axis=1)  # (B,S,H) inclusive cumulative log-forget
    # D_ij = F_i - F_j + i~_j for j <= i
    Dm = F[:, :, None, :] - F[:, None, :, :] + ig[:, None, :, :]  # (B,Si,Sj,H)
    causal = jnp.tril(jnp.ones((S, S), bool))
    Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf)
    m = jnp.max(Dm, axis=2)  # (B,Si,H) row-stabilizer
    Dp = jnp.exp(Dm - m[:, :, None, :])  # (B,Si,Sj,H)
    scores = jnp.einsum("bihd,bjhd->bijh", qf, kf) * Dp
    norm = jnp.maximum(
        jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m))  # (B,Si,H)
    h = jnp.einsum("bijh,bjhd->bihd", scores, vf) / norm[..., None]
    return h.astype(q.dtype)


def mlstm_recurrent_step(state, q, k, v, ig, fg):
    """One decode step. state = (C, n, m); q/k/v: (B,H,dh); ig/fg: (B,H)."""
    C, n, m_prev = state
    dh = q.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    vf = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    m_new = jnp.maximum(log_f + m_prev, ig.astype(jnp.float32))
    f_sc = jnp.exp(log_f + m_prev - m_new)[..., None]
    i_sc = jnp.exp(ig - m_new)[..., None]
    C_new = f_sc[..., None] * C + i_sc[..., None] * (
        kf[..., :, None] * vf[..., None, :])  # (B,H,dh_k,dh_v)
    n_new = f_sc * n + i_sc * kf
    num = jnp.einsum("bhkv,bhk->bhv", C_new, qf)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return (C_new, n_new, m_new), h


def mlstm_final_state(q, k, v, ig, fg):
    """Closed-form end-of-sequence recurrent state (C, n, m).

    Exactly equals running :func:`mlstm_recurrent_step` over the sequence:
    m_S = max_j (F_S - F_j + i_j); C_S = sum_j e^{b_j - m_S} k_j v_j^T.
    """
    dh = q.shape[-1]
    kf = k.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    vf = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    F = jnp.cumsum(log_f, axis=1)  # (B,S,H)
    b = F[:, -1:, :] - F + ig.astype(jnp.float32)  # (B,S,H)
    m = jnp.max(b, axis=1)  # (B,H)
    w = jnp.exp(b - m[:, None, :])  # (B,S,H)
    C = jnp.einsum("bsh,bshd,bshk->bhdk", w, kf, vf)
    n = jnp.einsum("bsh,bshd->bhd", w, kf)
    return (C, n, m)


def apply_mlstm(p, x, cfg: ModelConfig, *, state=None, return_state=False):
    """mLSTM block. state=None -> parallel training form; else decode step."""
    if state is None:
        z, q, k, v, ig, fg, _ = _mlstm_qkv_gates(p, x, cfg)
        if (cfg.mlstm_chunk > 0 and x.shape[1] > cfg.mlstm_chunk
                and x.shape[1] % cfg.mlstm_chunk == 0):
            h = mlstm_chunkwise(q, k, v, ig, fg, chunk=cfg.mlstm_chunk)
        else:
            h = mlstm_parallel(q, k, v, ig, fg)
        new_state = None
        if return_state:
            Di = PF * cfg.d_model
            W = cfg.conv1d_width
            up = jnp.einsum("bsd,de->bse", x, L.cast(p["w_up"], cfg))
            m_in = up[..., Di:]
            new_state = {
                "cell": mlstm_final_state(q, k, v, ig, fg),
                "conv": m_in[:, -(W - 1):].astype(L.compute_dtype(cfg)),
            }
    else:
        cell_state, conv_state = state["cell"], state["conv"]
        z, q, k, v, ig, fg, new_conv = _mlstm_qkv_gates(
            p, x, cfg, conv_state=conv_state)
        cell_state, h = mlstm_recurrent_step(
            cell_state, q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0])
        h = h[:, None]
        new_state = {"cell": cell_state, "conv": new_conv}
    B, S = x.shape[:2]
    h = _group_norm(h, p["out_norm"])
    h = h.reshape(B, S, -1)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, L.cast(p["w_down"], cfg))
    return out, new_state


def mlstm_chunkwise(q, k, v, ig, fg, *, chunk: int):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + inter-chunk scan.

    Mathematically equal to :func:`mlstm_parallel` (tested); O(S·c + S·dh²/c)
    instead of O(S²), and the layout the TPU kernel tiles.
    """
    B, S, H, dh = q.shape
    c = chunk
    assert S % c == 0, (S, c)
    N = S // c
    qf = q.astype(jnp.float32).reshape(B, N, c, H, dh)
    kf = (k.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))).reshape(B, N, c, H, dh)
    vf = v.astype(jnp.float32).reshape(B, N, c, H, dh)
    igf = ig.astype(jnp.float32).reshape(B, N, c, H)
    log_f = jax.nn.log_sigmoid(fg.astype(jnp.float32)).reshape(B, N, c, H)

    Fc = jnp.cumsum(log_f, axis=2)  # within-chunk cumulative log-forget
    f_total = Fc[:, :, -1]  # (B,N,H) total chunk decay
    # per-position quantities
    # b_j = F_total - F_j + i_j : weight of token j's contribution to the
    #       end-of-chunk state; a_i = F_i : decay of carry-in at position i.
    b = f_total[:, :, None] - Fc + igf  # (B,N,c,H)
    a = Fc  # (B,N,c,H)

    def scan_body(carry, xs):
        C_prev, n_prev, m_prev = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qc, kc, vc, ac, bc, ftot, igc, Fcc = xs
        # ---- intra-chunk (as in parallel form, local stabilizer) ----
        Dm = Fcc[:, :, None, :] - Fcc[:, None, :, :] + igc[:, None, :, :]
        causal = jnp.tril(jnp.ones((c, c), bool))
        Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf)
        m_local = jnp.max(Dm, axis=2)  # (B,c,H)
        # ---- inter-chunk: carry-in contribution ----
        m_in = ac + m_prev[:, None, :]  # (B,c,H) stabilizer of carry term
        m_i = jnp.maximum(m_local, m_in)
        Dp = jnp.exp(Dm - m_i[:, :, None, :])
        scores = jnp.einsum("bihd,bjhd->bijh", qc, kc) * Dp
        inter_q = qc * jnp.exp(m_in - m_i)[..., None]  # decayed queries
        num = (jnp.einsum("bijh,bjhd->bihd", scores, vc)
               + jnp.einsum("bihd,bhdk->bihk", inter_q, C_prev))
        den_local = jnp.sum(scores, axis=2)  # (B,c,H)
        den_inter = jnp.einsum("bihd,bhd->bih", inter_q, n_prev)
        den = jnp.maximum(jnp.abs(den_local + den_inter), jnp.exp(-m_i))
        h = num / den[..., None]
        # ---- state update to end of chunk ----
        m_next = jnp.maximum(ftot + m_prev, jnp.max(bc, axis=1))  # (B,H)
        carry_scale = jnp.exp(ftot + m_prev - m_next)  # (B,H)
        token_w = jnp.exp(bc - m_next[:, None, :])  # (B,c,H)
        C_new = (carry_scale[..., None, None] * C_prev
                 + jnp.einsum("bjh,bjhd,bjhk->bhdk", token_w, kc, vc))
        n_new = (carry_scale[..., None] * n_prev
                 + jnp.einsum("bjh,bjhd->bhd", token_w, kc))
        return (C_new, n_new, m_next), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    C0, n0, m0 = L.vary_like((C0, n0, m0), qf)
    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (qf, kf, vf, a, b, f_total, igf, Fc))
    _, hs = jax.lax.scan(scan_body, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)
    return h.astype(q.dtype)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    Di = PF * cfg.d_model
    H = cfg.num_heads
    dh = Di // H
    return {
        "cell": (
            jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -jnp.inf, jnp.float32),
        ),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, Di),
                          L.compute_dtype(cfg)),
    }


# ===========================================================================
# sLSTM
# ===========================================================================


def init_slstm(key, cfg: ModelConfig):
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    ks = jax.random.split(key, 10)
    pd = jnp.dtype(cfg.param_dtype)
    def gate(k):
        return L.dense_init(k, (D, D), dtype=pd)
    return {
        "w_i": gate(ks[0]), "w_f": gate(ks[1]),
        "w_z": gate(ks[2]), "w_o": gate(ks[3]),
        # block-diagonal (per-head) recurrent matrices
        "r_i": L.dense_init(ks[4], (H, dh, dh), scale=0.05, dtype=pd),
        "r_f": L.dense_init(ks[5], (H, dh, dh), scale=0.05, dtype=pd),
        "r_z": L.dense_init(ks[6], (H, dh, dh), scale=0.05, dtype=pd),
        "r_o": L.dense_init(ks[7], (H, dh, dh), scale=0.05, dtype=pd),
        "b_i": jnp.full((D,), -3.0, pd), "b_f": jnp.full((D,), 3.0, pd),
        "b_z": jnp.zeros((D,), pd), "b_o": jnp.zeros((D,), pd),
        "out_norm": jnp.ones((H, dh), pd),
        "w_down": L.out_proj_init(ks[8], (D, D), cfg.num_layers, dtype=pd),
    }


def slstm_cell(p, cfg: ModelConfig, state, xi, xf, xz, xo):
    """One sLSTM step. state=(c,n,m,h) each (B,H,dh); x*: (B,H,dh) projections."""
    c, n, m_prev, h_prev = state
    def rec(r, h):
        return jnp.einsum("bhk,hkd->bhd", h, r.astype(jnp.float32))
    it = xi + rec(p["r_i"], h_prev)
    ft = xf + rec(p["r_f"], h_prev)
    zt = xz + rec(p["r_z"], h_prev)
    ot = xo + rec(p["r_o"], h_prev)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m_prev, it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.exp(log_f + m_prev - m_new)
    c_new = f_sc * c + i_sc * jnp.tanh(zt)
    n_new = f_sc * n + i_sc
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def apply_slstm(p, x, cfg: ModelConfig, *, state=None, return_state=False):
    """sLSTM block: scan over time (training) or one step (decode)."""
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H
    xf32 = x.astype(jnp.float32)
    def proj(w, b):
        return (jnp.einsum("bsd,de->bse", xf32, w.astype(jnp.float32))
                + b.astype(jnp.float32)).reshape(B, S, H, dh)
    xi, xf_, xz, xo = (proj(p[w], p[b]) for w, b in
                       (("w_i", "b_i"), ("w_f", "b_f"),
                        ("w_z", "b_z"), ("w_o", "b_o")))
    if state is None:
        s0 = L.vary_like(init_slstm_state(cfg, B)["cell"], xi)
        def body(s, inputs):
            return slstm_cell(p, cfg, s, *inputs)
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xi, xf_, xz, xo))
        carry, hs = jax.lax.scan(body, s0, xs)
        h = jnp.moveaxis(hs, 0, 1)  # (B,S,H,dh)
        new_state = {"cell": carry} if return_state else None
    else:
        cell, h = slstm_cell(
            p, cfg, state["cell"], xi[:, 0], xf_[:, 0], xz[:, 0], xo[:, 0])
        h = h[:, None]
        new_state = {"cell": cell}
    h = _group_norm(h, p["out_norm"]).reshape(B, S, D)
    out = jnp.einsum("bsd,de->bse", h, L.cast(p["w_down"], cfg))
    return out.astype(x.dtype), new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    dh = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, dh), jnp.float32)
    return {"cell": (z(), z(), jnp.full((batch, H, dh), -30.0, jnp.float32), z())}
