"""Shared building blocks: norms, MLPs, embeddings, initializers.

Everything is functional: ``init_*`` builds a param dict, ``apply`` functions
take ``(params, x, cfg)``. Params are stored in ``cfg.param_dtype`` (fp32
master weights, as the paper's mixed-precision recipe prescribes) and cast to
``cfg.dtype`` (bf16) on use.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.parallel.axes import logical_constraint


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal init; default std 0.02 (GPT-2 / Megatron convention)."""
    std = 0.02 if scale is None else scale
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def out_proj_init(key, shape, num_layers: int, dtype=jnp.float32):
    """Residual-branch output proj init, scaled by 1/sqrt(2L) (GPT-2)."""
    std = 0.02 / math.sqrt(2 * max(num_layers, 1))
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def vary_like(tree, ref):
    """Give constant-initialized scan carries the same varying-manual-axes
    (VMA) annotation as ``ref``. Under partial-manual shard_map a
    ``lax.scan`` carry must match its body output's varying axes; adding a
    zero derived from ``ref`` transfers the annotation at zero cost (XLA
    folds the empty-slice sum away)."""
    zero = jnp.sum(ref[:0].astype(jnp.float32))
    return jax.tree.map(lambda t: t + zero.astype(t.dtype), tree)


def cast(params_leaf, cfg: ModelConfig):
    return params_leaf.astype(compute_dtype(cfg))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(key, cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p, x, cfg: ModelConfig):
    """RMSNorm or LayerNorm computed in fp32, cast back to compute dtype."""
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(orig_dtype)


def rms_norm_headwise(x, scale, eps: float = 1e-6):
    """Per-head qk-norm (Qwen3/Chameleon): normalize over head_dim."""
    orig = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    p = {}
    if cfg.activation == "swiglu":
        p["w_gate"] = dense_init(ks[0], (cfg.d_model, d_ff), dtype=pd)
        p["w_up"] = dense_init(ks[1], (cfg.d_model, d_ff), dtype=pd)
    else:  # gelu
        p["w_up"] = dense_init(ks[1], (cfg.d_model, d_ff), dtype=pd)
    p["w_down"] = out_proj_init(ks[2], (d_ff, cfg.d_model), cfg.num_layers, dtype=pd)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    """Position-wise MLP. x: (..., d_model)."""
    up = jnp.einsum("...d,df->...f", x, cast(p["w_up"], cfg))
    if cfg.activation == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, cast(p["w_gate"], cfg))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    if h.ndim == 3:
        h = logical_constraint(h, "batch", None, "tp")
    out = jnp.einsum("...f,fd->...d", h, cast(p["w_down"], cfg))
    return out


# ---------------------------------------------------------------------------
# embeddings / LM head
# ---------------------------------------------------------------------------


def init_embeddings(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    p = {"tokens": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype=pd)}
    if cfg.positional == "learned":
        p["positions"] = dense_init(
            ks[1], (cfg.max_position_embeddings, cfg.d_model), dtype=pd
        )
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype=pd)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig, *, position_offset=0):
    """tokens: (B, S) int32 -> (B, S, D)."""
    x = jnp.take(cast(p["tokens"], cfg), tokens, axis=0)
    if cfg.positional == "learned":
        positions = position_offset + jnp.arange(tokens.shape[-1])
        x = x + jnp.take(cast(p["positions"], cfg), positions, axis=0)[None]
    x = logical_constraint(x, "batch", None, None)
    return x


def lm_logits(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, V); fp32 logits for a stable loss."""
    table = p["tokens"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.float32), table.astype(jnp.float32)
    )
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    logits = logical_constraint(logits, "batch", None, "tp")
    return logits


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, hd/2)
        angles = angles[None, :, None, :]  # (1, S, 1, hd/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
        angles = angles[:, :, None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)
