"""Central configuration dataclasses for the Pier framework.

Three layers of config compose a run:

- :class:`ModelConfig` — architecture definition (one per assigned arch).
- :class:`ParallelConfig` — mesh / sharding / Pier-group layout.
- :class:`TrainConfig` — optimization hyperparameters, including every Pier
  knob from the paper (warmup proportion ``p``, sync interval ``r``/H,
  momentum-decay schedule, outer LR schedule, offload switch).

All configs are frozen dataclasses so they can be hashed into jit caches and
static arguments.
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import InitVar, dataclass, field
from typing import Optional, Tuple, Union


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture definition.

    One decoder substrate covers dense / MoE / SSM / hybrid / VLM families;
    encoder-decoder (audio) adds a stubbed-frontend encoder stack.
    """

    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention variants -------------------------------------------------
    attention_kind: str = "gqa"  # gqa | mla | none (for pure-SSM layers)
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    positional: str = "rope"  # rope | learned | none
    max_position_embeddings: int = 8192  # only for learned positions
    sliding_window: int = 0  # 0 -> full attention; >0 -> SWA window
    logit_softcap: float = 0.0

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading layers that use the dense MLP
    router_aux_loss_coef: float = 0.001
    expert_capacity_factor: float = 1.25

    # --- SSM / hybrid block pattern -------------------------------------------
    # Cycled over layers. Entries: "attn", "local_attn", "mlstm", "slstm", "rglru".
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048
    lru_width: int = 0  # 0 -> d_model
    conv1d_width: int = 4
    mlstm_chunk: int = 64

    # --- encoder-decoder (audio) ----------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500
    frontend_dim: int = 0  # stubbed frontend embedding dim (0 -> d_model)

    # --- misc ------------------------------------------------------------------
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    activation: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    source: str = ""  # citation for the config

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def block_kind(self, layer_idx: int) -> str:
        """Mixing-block kind ("attn", "mlstm", ...) for a decoder layer."""
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def uses_kv_cache(self, layer_idx: int) -> bool:
        return self.block_kind(layer_idx) in ("attn", "local_attn")

    @property
    def sub_quadratic(self) -> bool:
        """True if every mixing block has O(1)/O(window) decode state."""
        kinds = {self.block_kind(i) for i in range(self.num_layers)}
        if "attn" in kinds and self.sliding_window == 0 and self.attention_kind != "none":
            return False
        if self.attention_kind == "mla" and self.sliding_window == 0 and "attn" in kinds:
            return False
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for 6ND model-FLOPs accounting). Computed analytically
    # so benchmarks do not need to materialize weights.
    def param_count(self) -> int:
        from repro.models.registry import count_params  # lazy: avoid cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params

        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Parallel / mesh configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    """Mesh layout and Pier group structure.

    The production mesh is (data=16, model=16) per pod; Pier refines the data
    axis into ``data_outer × data_inner`` where a *group* = one
    ``(pod, data_outer)`` index (``data_inner × model`` chips). Inner-optimizer
    collectives are confined to ``(data_inner, model)``; the outer optimizer is
    the only thing that ever communicates across ``(pod, data_outer)``.
    """

    data_axis_size: int = 16
    model_axis_size: int = 16
    num_pods: int = 1
    # Number of Pier groups along the data axis *per pod*. Groups per run =
    # num_pods * data_outer. data_inner = data_axis_size // data_outer.
    data_outer: int = 4

    # Sharding toggles
    fsdp: bool = True  # shard params/opt state over data_inner (ZeRO-3 in group)
    shard_experts: bool = True  # expert-parallel over the model axis
    remat: str = "none"  # none | full | selective  (activation checkpointing)
    use_pallas: bool = False  # pallas kernels in the model fwd (TPU only)
    num_microbatches: int = 1  # gradient accumulation inside the inner step
    context_parallel: bool = False  # shard decode KV cache over seq (long_500k)
    scan_layers: bool = False  # lax.scan over layer cycles (compile time + memory)

    @property
    def data_inner(self) -> int:
        assert self.data_axis_size % self.data_outer == 0, (
            f"data axis {self.data_axis_size} not divisible by "
            f"data_outer {self.data_outer}"
        )
        return self.data_axis_size // self.data_outer

    @property
    def num_groups(self) -> int:
        return self.num_pods * self.data_outer

    @property
    def group_size(self) -> int:
        return self.data_inner * self.model_axis_size

    @property
    def num_devices(self) -> int:
        return self.num_pods * self.data_axis_size * self.model_axis_size

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Outer-collective configuration (DESIGN.md §6/§7)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OuterCommConfig:
    """The outer collective's knobs, grouped (DESIGN.md §7).

    ``repro.sync.resolve_strategy`` maps this onto an ``OuterSyncStrategy``
    object; the all-defaults config resolves to the flat fp32 pmean of Δθ —
    the seed collective, bit for bit.
    """

    # "none" keeps the flat fp32 pmean of Δθ. "quantize" sends blockwise-
    # quantized Δθ over the slow domain with per-block fp32 absmax scales
    # and an error-feedback residual (carried in OuterState) so
    # quantization error is re-injected into the next Δθ instead of
    # biasing the Nesterov momentum — numerically exact wire model, fp32
    # on the actual collective. "int8-wire" is the true wire format
    # (DESIGN.md §8): the packed (q, scales) pairs themselves cross the
    # slow axes through a ring exchange with per-source-scale sum
    # semantics; same payload mean as "quantize", real bytes win.
    # "rs-ag" is the reduce-scatter + all-gather variant of the int8 wire
    # (DESIGN.md §14): the quantized payload is sliced into one slot per
    # exchange endpoint, each endpoint reduces only its own slot (with a
    # second error-feedback residual over the re-quantized reduced shard),
    # then the shards are all-gathered — ~2/E of the gather-based wire's
    # per-device bytes.
    compression: str = "none"  # none | quantize | int8-wire | rs-ag
    bits: int = 8  # 4 | 8 (int stored in int8; 4 models packing)
    block: int = 256  # absmax-scale block (elements per scale)
    # Two-stage reduce: full-precision psum over the fast intra-pod axis
    # (data_outer), then exchange over the slow pod axis — only 1/pods of
    # the traffic crosses the slow domain at full width. Degenerates to the
    # flat reduce when the mesh has no pod axis.
    hierarchical: bool = False
    # Chunked dispatch: the Δθ tree is flattened into this many contiguous
    # leaf spans dispatched as separate XLA computations, each carrying its
    # own per-chunk dispatch state so early chunks reduce (and apply) while
    # later ones are still being quantized. 1 = single fused dispatch.
    chunks: int = 1
    # Sharded outer exchange (DESIGN.md §10): each device compresses and
    # exchanges only its Δθ shard along the auto (TP/FSDP) mesh axes, with
    # the outer momentum/anchor/residual sharded alongside via the
    # param_specs tables — outer-state memory per device stops scaling
    # with full model size. fp32 stays bit-identical to the replicated
    # path; quantized keeps the same numeric model and tolerance.
    sharded: bool = False

    def __post_init__(self):
        if self.compression not in ("none", "quantize", "int8-wire",
                                    "rs-ag"):
            raise ValueError(
                f"outer compression must be 'none', 'quantize', "
                f"'int8-wire' or 'rs-ag', got {self.compression!r}")
        if self.compression != "none" and self.bits not in (4, 8):
            raise ValueError(
                f"outer comm bits must be 4 or 8, got {self.bits}")
        if self.block < 1:
            raise ValueError(
                f"outer comm block must be >= 1, got {self.block}")
        if self.chunks < 1:
            raise ValueError(
                f"comm chunks must be >= 1, got {self.chunks}")
        if self.compression == "rs-ag" and self.hierarchical:
            raise ValueError(
                "rs-ag composes a flat exchange: the two-stage "
                "hierarchical reduce cannot thread the second "
                "error-feedback residual through its pod stage")
        if self.compression == "rs-ag" and self.chunks > 1:
            raise ValueError(
                "rs-ag needs chunks=1: per-chunk threading of the "
                "second error-feedback residual is a recorded "
                "follow-up (DESIGN.md §14)")

    def replace(self, **kw) -> "OuterCommConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MembershipConfig:
    """Elastic outer-membership knobs (DESIGN.md §11).

    Present on ``TrainConfig.membership`` only when elastic membership is
    requested: ``None`` (the default) keeps the fixed-membership step
    graphs byte-for-byte — the weighted reduction variants are never even
    built. Membership is a post-warmup concept: the momentum-warmup phase
    trains globally synced and always runs at full membership.
    """

    # A group whose delta has missed more than this many consecutive
    # post-warmup outer events is evicted: its (stale) contribution is
    # discarded and it must bootstrap on rejoin. 0 = evict on the first
    # missed event.
    max_staleness: int = 1
    # Reject an outer event whose live mask has fewer than this many
    # groups (an all-zero mask is always an error).
    min_live: int = 1
    # Where a rejoining group bootstraps its params/opt/outer slice from:
    # "checkpoint" restores the latest complete checkpoint when a
    # CheckpointManager is attached (falling back to anchor when none is
    # available); "anchor" resets to the current outer anchor + fresh
    # inner-optimizer state (always available, deterministic — what the
    # sim <-> Trainer lockstep tests pin).
    rejoin_bootstrap: str = "anchor"  # anchor | checkpoint

    def __post_init__(self):
        if self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}")
        if self.min_live < 1:
            raise ValueError(
                f"min_live must be >= 1, got {self.min_live}")
        if self.rejoin_bootstrap not in ("anchor", "checkpoint"):
            raise ValueError(
                f"rejoin_bootstrap must be 'anchor' or 'checkpoint', "
                f"got {self.rejoin_bootstrap!r}")

    def replace(self, **kw) -> "MembershipConfig":
        return dataclasses.replace(self, **kw)


# Legacy flat TrainConfig fields -> their OuterCommConfig counterparts.
# Accepted as init-only kwargs (and by TrainConfig.replace) for
# backward compatibility; reads keep working through properties.
_LEGACY_COMM_FIELDS = {
    "outer_compression": "compression",
    "outer_comm_bits": "bits",
    "outer_comm_block": "block",
    "hierarchical_reduce": "hierarchical",
    "comm_chunks": "chunks",
}


# ---------------------------------------------------------------------------
# Training / optimizer configuration (Table I of the paper + Pier §IV/§V)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "pier"  # pier | diloco | adamw

    # ---- inner optimizer (AdamW, Table I) ----
    inner_lr: float = 4e-4
    inner_min_lr: float = 4e-5
    adam_beta1: float = 0.9
    adam_beta2: float = 0.999
    adam_eps: float = 1e-8
    weight_decay: float = 0.1
    clip_grad: float = 1.0
    lr_schedule: str = "cosine"  # cosine | wsd | constant
    lr_warmup_frac: float = 0.02
    wsd_decay_frac: float = 0.1  # for MiniCPM's WSD schedule

    # ---- run shape ----
    total_steps: int = 100_000
    global_batch_size: int = 512
    seq_len: int = 1024
    seed: int = 0

    # ---- Pier / DiLoCo outer optimizer ----
    sync_interval: int = 50  # r / H in the paper
    # Delayed (overlapped) outer sync: the globally averaged Δθ gathered at
    # sync step t is applied at step t + sync_delay, hiding the cross-group
    # all-reduce behind the next ``sync_delay`` inner steps (Pier §V system
    # architecture). 0 = eager (bit-identical to the classic path). Must be
    # < sync_interval so an apply always lands before the next dispatch.
    # "auto" = resolve d* at startup from the benchmarks/overlap.py step-time
    # model (mesh + --chip hint); the launcher must replace it with an int
    # before the schedule runs (falls back to 0 with no estimate).
    sync_delay: Union[int, str] = 0

    # ---- outer collective (grouped; DESIGN.md §6/§7) ----
    # The strategy-defining knobs live in OuterCommConfig;
    # ``repro.sync.resolve_strategy(tc)`` turns them into the
    # OuterSyncStrategy object the runtimes consume. ``None`` means "all
    # defaults" (flat fp32 pmean — the seed collective).
    outer_comm: Optional[OuterCommConfig] = None
    # Elastic outer membership (DESIGN.md §11): ``None`` keeps fixed
    # membership (today's graphs, byte for byte); a MembershipConfig
    # enables the weighted variable-membership reduction, staleness
    # eviction, and churn scripting in the simulator/Trainer.
    membership: Optional[MembershipConfig] = None
    # Deprecated flat spellings of the OuterCommConfig knobs. Accepted as
    # init-only kwargs and folded into ``outer_comm`` (explicit flat values
    # override the grouped config); reads keep working via properties.
    outer_compression: InitVar[Optional[str]] = None
    outer_comm_bits: InitVar[Optional[int]] = None
    outer_comm_block: InitVar[Optional[int]] = None
    hierarchical_reduce: InitVar[Optional[bool]] = None
    comm_chunks: InitVar[Optional[int]] = None
    warmup_frac: float = 0.10  # p: lazy-start proportion
    outer_optimizer: str = "nesterov_torch"  # nesterov_torch | nesterov_classic | sgd
    outer_momentum: float = 0.9  # terminal mu
    # momentum decay schedule (Alg. 2): list of (frac_lo, frac_hi, mu)
    momentum_decay: Tuple[Tuple[float, float, float], ...] = (
        (0.10, 0.15, 0.99),
        (0.15, 0.20, 0.95),
        (0.20, 1.01, 0.90),
    )
    # outer LR schedule (§V): warmup 0->1 over [p, outer_lr_warmup_end], then
    # mid value until outer_lr_mid_end, then final value.
    outer_lr_warmup_end: float = 0.20
    outer_lr_mid: float = 1.1
    outer_lr_mid_end: float = 0.80
    outer_lr_final: float = 0.9
    fixed_outer_lr: float = 0.7  # DiLoCo baseline's recommended constant
    momentum_warmup: bool = True  # Alg. 1 (disabled for vanilla DiLoCo)
    lazy_start: bool = True  # AdamW phase before switching (DiLoCo: off)

    # ---- memory ----
    offload_outer_state: bool = False  # host-memory offload of anchor + M (§V)
    opt_state_dtype: str = "float32"  # float32 (paper) | bfloat16 (beyond-paper)

    # ---- loss ----
    z_loss_coef: float = 0.0

    def replace(self, **kw) -> "TrainConfig":
        """``dataclasses.replace`` with the legacy-flat-knob shim.

        Legacy keys (``outer_compression``, ``comm_chunks``, ...) are
        folded into ``outer_comm`` so e.g.
        ``tc.replace(hierarchical_reduce=True)`` keeps working.
        """
        legacy = {k: kw.pop(k) for k in tuple(kw) if k in _LEGACY_COMM_FIELDS}
        if legacy:
            _warn_legacy_comm(legacy)
            base = kw.get("outer_comm") or self.outer_comm or OuterCommConfig()
            kw["outer_comm"] = base.replace(
                **{_LEGACY_COMM_FIELDS[k]: v for k, v in legacy.items()})
        cur = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.init}
        cur.update(kw)
        return TrainConfig(**cur)

    def __post_init__(self, outer_compression, outer_comm_bits,
                      outer_comm_block, hierarchical_reduce, comm_chunks):
        # ---- legacy flat outer-comm knobs -> grouped OuterCommConfig ----
        legacy = {k: v for k, v in (
            ("outer_compression", outer_compression),
            ("outer_comm_bits", outer_comm_bits),
            ("outer_comm_block", outer_comm_block),
            ("hierarchical_reduce", hierarchical_reduce),
            ("comm_chunks", comm_chunks)) if v is not None}
        comm = self.outer_comm or OuterCommConfig()
        if legacy:
            _warn_legacy_comm(legacy)
            comm = comm.replace(
                **{_LEGACY_COMM_FIELDS[k]: v for k, v in legacy.items()})
        object.__setattr__(self, "outer_comm", comm)
        if isinstance(self.sync_delay, str):
            if self.sync_delay != "auto":
                raise ValueError(
                    f"sync_delay must be an int or 'auto', "
                    f"got {self.sync_delay!r}")
        else:
            if self.sync_delay < 0:
                raise ValueError(
                    f"sync_delay must be >= 0, got {self.sync_delay}")
            if self.sync_delay >= self.sync_interval:
                raise ValueError(
                    f"sync_delay ({self.sync_delay}) must be < sync_interval "
                    f"({self.sync_interval}): the in-flight Δθ must be "
                    "applied before the next dispatch")
    @property
    def warmup_steps(self) -> int:
        return int(self.total_steps * self.warmup_frac)

    def mu_at(self, step: int) -> float:
        """Momentum-decay schedule (Algorithm 2, lines 12-18)."""
        frac = step / max(self.total_steps, 1)
        for lo, hi, mu in self.momentum_decay:
            if lo <= frac < hi:
                return mu
        return self.outer_momentum

    def outer_lr_at(self, step: int) -> float:
        """Outer LR schedule from §V (Implementation)."""
        frac = step / max(self.total_steps, 1)
        p = self.warmup_frac
        if frac < p:
            return 0.0  # outer optimizer not applied during lazy start
        if frac < self.outer_lr_warmup_end:
            span = self.outer_lr_warmup_end - p
            return (frac - p) / max(span, 1e-9)
        if frac < self.outer_lr_mid_end:
            return self.outer_lr_mid
        return self.outer_lr_final


def _warn_legacy_comm(legacy: dict) -> None:
    warnings.warn(
        f"flat TrainConfig outer-collective knobs {sorted(legacy)} are "
        f"deprecated; use TrainConfig(outer_comm=OuterCommConfig(...)) "
        f"(see DESIGN.md §7)", DeprecationWarning, stacklevel=3)


def _legacy_comm_property(comm_field: str, legacy_name: str):
    def get(self):
        return getattr(self.outer_comm, comm_field)

    get.__doc__ = (f"Deprecated read-through for "
                   f"``outer_comm.{comm_field}`` (legacy ``{legacy_name}``).")
    return property(get)


# The legacy flat names stay readable (tc.outer_compression, ...) —
# they read through to the grouped config. Installed after class creation
# because the names double as InitVar parameters of the generated
# __init__ (the deprecation shim for writes).
for _legacy, _grouped in _LEGACY_COMM_FIELDS.items():
    setattr(TrainConfig, _legacy, _legacy_comm_property(_grouped, _legacy))
del _legacy, _grouped


# ---------------------------------------------------------------------------
# Input shapes (assigned suite)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclass(frozen=True)
class RunConfig:
    """Everything needed to launch one run."""

    model: ModelConfig = field(default_factory=ModelConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
