"""AdamW from scratch (optax is not available in this environment).

Matches the decoupled-weight-decay formulation used by Megatron-LM / PyTorch:

    m <- b1 m + (1-b1) g           v <- b2 v + (1-b2) g^2
    m_hat = m / (1-b1^t)           v_hat = v / (1-b2^t)
    theta <- theta - lr * (m_hat / (sqrt(v_hat) + eps) + wd * theta)

Optimizer state dtype is configurable (paper: fp32 state with bf16 model;
``bfloat16`` state is the beyond-paper memory lever for the 1T configs).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    count: jax.Array  # () int32
    mu: Any  # first-moment pytree
    nu: Any  # second-moment pytree


def adamw_init(params, tc: TrainConfig) -> AdamWState:
    dt = jnp.dtype(tc.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def _decay_mask(path) -> bool:
    """True if this parameter gets weight decay (matmuls yes; norms/bias no)."""
    keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
    name = keys[-1] if keys else ""
    if name in ("scale", "bias") or name.startswith("b_"):
        return False
    if "norm" in name or name == "lambda":
        return False
    if name == "positions":  # positional embeddings: no decay (GPT-2 convention)
        return False
    return True


def adamw_update(
    grads, state: AdamWState, params, tc: TrainConfig, lr: jax.Array
):
    """One AdamW step. Returns (new_params, new_state).

    Params/grads may be any matching pytrees; moments are stored in
    ``tc.opt_state_dtype`` and the update math runs in fp32.
    """
    b1, b2, eps = tc.adam_beta1, tc.adam_beta2, tc.adam_eps
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    sdt = jnp.dtype(tc.opt_state_dtype)

    decay_flags = {}

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        m_hat = mf / c1
        v_hat = vf / c2
        step = m_hat / (jnp.sqrt(v_hat) + eps)
        if _decay_mask(path):
            step = step + tc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    p_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.mu)
    v_leaves = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves):
        np_, nm, nv = upd(path, p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, new_p), AdamWState(
        count=count, mu=unf(treedef, new_m), nu=unf(treedef, new_v))
