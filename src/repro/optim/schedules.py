"""Inner learning-rate schedules: cosine (Table I), WSD (MiniCPM), constant.

All schedules are pure jnp functions of the step so they can live inside the
jitted train step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def lr_at(tc: TrainConfig, step: jax.Array) -> jax.Array:
    """Inner LR at ``step`` (0-based), as a traced fp32 scalar."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    total = jnp.float32(tc.total_steps)
    warm = jnp.maximum(jnp.float32(tc.lr_warmup_frac) * total, 1.0)
    peak = jnp.float32(tc.inner_lr)
    floor = jnp.float32(tc.inner_min_lr)

    warm_lr = peak * (s + 1.0) / warm

    if tc.lr_schedule == "constant":
        main_lr = peak
    elif tc.lr_schedule == "wsd":
        # warmup -> stable -> decay (MiniCPM): exponential-ish linear decay
        decay_start = total * (1.0 - tc.wsd_decay_frac)
        frac = jnp.clip((s - decay_start) / jnp.maximum(total - decay_start, 1.0),
                        0.0, 1.0)
        main_lr = peak + (floor - peak) * frac
    else:  # cosine
        prog = jnp.clip((s - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
        main_lr = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))

    return jnp.where(s < warm, warm_lr, main_lr)
