"""Paged/blocked KV cache for continuous-batching decode (DESIGN.md §12).

Instead of one dense (B, max_len, Hkv, hd) buffer per sequence, K/V lives
in a fixed pool of ``num_blocks`` blocks of ``block_size`` token slots,
shared by every sequence and every attention layer:

    k_pool / v_pool   (L_kv, num_blocks, block_size, Hkv, hd)

A sequence owns an ordered list of physical block ids (its *block table*);
logical token ``t`` lives in block ``table[t // block_size]`` slot
``t % block_size``. Blocks are handed out by a host-side free-list
:class:`BlockAllocator` and returned when the sequence completes, so pool
memory is bounded by *live tokens*, not ``batch × max_len`` — the memory
feature that makes mixed-length continuous batching viable at scale.

Physical block 0 is a reserved *sink*: empty decode slots in a batched
step point their table at it, so their (garbage) writes land somewhere
harmless and never corrupt a live sequence.

int8 block format: with ``quantized=True`` the pools store int8 values
plus one fp32 absmax scale per (block, slot, kv-head) row of ``hd``
elements — exactly the ``kernels/quantize.py`` blockwise wire format with
``block = hd``, produced by the same Pallas kernel at write time and
consumed by the decode kernel's in-VMEM dequant (elementwise-identical to
``kernels/ref.py:dequantize_blockwise_ref``, asserted in tests). KV-cache
HBM drops ~4x (int8 payload + fp32/hd scale overhead) for a documented
logit tolerance (DESIGN.md §12).

Device-side write helpers here are pure jnp scatters, traced inside the
jitted decode/prefill steps of ``parallel/steps.build_paged_serve_steps``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops as kops
from repro.models import layers as L

SINK_BLOCK = 0  # reserved physical block for inactive decode slots


@dataclass(frozen=True)
class PagedCacheConfig:
    """Shape/format of the shared block pool."""

    num_blocks: int = 64  # total physical blocks, incl. the sink
    block_size: int = 16  # token slots per block
    quantized: bool = False  # int8 blocks + fp32 per-(slot, head) scales
    quant_bits: int = 8
    dtype: Optional[str] = None  # unquantized pool dtype; None = compute dtype

    def __post_init__(self):
        if self.num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is the sink)")
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1: {self.block_size}")

    def blocks_for(self, num_tokens: int) -> int:
        """Blocks needed to hold ``num_tokens`` token slots."""
        return -(-num_tokens // self.block_size)

    def pool_dtype(self, cfg: ModelConfig):
        """Element dtype of unquantized pools (the model compute dtype
        unless overridden, e.g. fp32 for the parity tests)."""
        return jnp.dtype(self.dtype) if self.dtype else L.compute_dtype(cfg)


def kv_layer_indices(cfg: ModelConfig) -> List[int]:
    """Decoder layers that carry a KV cache (attn / local_attn blocks)."""
    return [i for i in range(cfg.num_layers) if cfg.uses_kv_cache(i)]


def paged_supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Whether the paged decode path covers this architecture.

    MLA's latent cache and SSM/rgLRU recurrent state keep the existing
    dense decode path (``ModelConfig.attention_kind`` dispatch); the paged
    pool covers the mha/gqa/mqa KV-cache families.
    """
    if cfg.attention_kind != "gqa":
        return False, f"attention_kind={cfg.attention_kind!r} (dense path)"
    if cfg.is_encoder_decoder:
        return False, "encoder-decoder (dense path)"
    kinds = {cfg.block_kind(i) for i in range(cfg.num_layers)}
    bad = kinds - {"attn", "local_attn"}
    if bad:
        return False, f"recurrent blocks {sorted(bad)} (dense path)"
    if cfg.num_heads % max(cfg.num_kv_heads, 1) != 0:
        return False, (f"H={cfg.num_heads} not a multiple of "
                       f"Hkv={cfg.num_kv_heads}")
    return True, ""


def init_pools(cfg: ModelConfig, pcfg: PagedCacheConfig) -> Dict[str, Any]:
    """Zero-initialized pool pytree for every KV-carrying layer."""
    lkv = len(kv_layer_indices(cfg))
    hd = cfg.resolved_head_dim
    shape = (lkv, pcfg.num_blocks, pcfg.block_size, cfg.num_kv_heads, hd)
    if pcfg.quantized:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.float32),
            "v_scale": jnp.zeros(shape[:-1], jnp.float32),
        }
    dt = pcfg.pool_dtype(cfg)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def pool_nbytes(cfg: ModelConfig, pcfg: PagedCacheConfig) -> int:
    """HBM footprint of the pool (the benchmark's occupancy denominator)."""
    lkv = len(kv_layer_indices(cfg))
    hd = cfg.resolved_head_dim
    elems = (lkv * pcfg.num_blocks * pcfg.block_size * cfg.num_kv_heads * hd)
    if pcfg.quantized:
        return 2 * (elems + elems // hd * 4)  # int8 payload + fp32 scales
    return 2 * elems * jnp.dtype(pcfg.pool_dtype(cfg)).itemsize


# ---------------------------------------------------------------------------
# device-side writes (traced inside the jitted serve steps)
# ---------------------------------------------------------------------------


def _quantize_rows(x: jnp.ndarray, *, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise-quantize the trailing hd axis: one fp32 scale per row.

    Reuses the ``kernels/quantize.py`` Pallas kernel with ``block = hd`` —
    the same absmax/reciprocal-multiply math as the outer collective's
    wire format, so the dequant oracle is shared.
    """
    hd = x.shape[-1]
    flat = x.astype(jnp.float32).reshape(-1)
    q, s = kops.quantize_blockwise(flat, bits=bits, block=hd)
    return q.reshape(x.shape), s.reshape(x.shape[:-1])


def write_token(pools: Dict[str, Any], layer: int, block_ids, slots,
                k, v, *, pcfg: PagedCacheConfig) -> Dict[str, Any]:
    """Scatter one decode step's K/V: block_ids/slots (B,), k/v (B, Hkv, hd)."""
    out = dict(pools)
    if pcfg.quantized:
        kq, ks = _quantize_rows(k, bits=pcfg.quant_bits)
        vq, vs = _quantize_rows(v, bits=pcfg.quant_bits)
        out["k"] = pools["k"].at[layer, block_ids, slots].set(kq)
        out["v"] = pools["v"].at[layer, block_ids, slots].set(vq)
        out["k_scale"] = pools["k_scale"].at[layer, block_ids, slots].set(ks)
        out["v_scale"] = pools["v_scale"].at[layer, block_ids, slots].set(vs)
        return out
    dt = pools["k"].dtype
    out["k"] = pools["k"].at[layer, block_ids, slots].set(k.astype(dt))
    out["v"] = pools["v"].at[layer, block_ids, slots].set(v.astype(dt))
    return out


def write_prefill(pools: Dict[str, Any], layer: int, block_table,
                  k, v, *, pcfg: PagedCacheConfig) -> Dict[str, Any]:
    """Scatter a prefilled sequence's K/V stream into its blocks.

    ``k``/``v`` are (S, Hkv, hd) with S a whole number of blocks (the
    engine pads prompts to a block multiple; pad slots are masked at
    attention time by ``context_lens``); ``block_table`` is (S / bs,).
    """
    bs = pcfg.block_size
    nb, rem = divmod(k.shape[0], bs)
    if rem:
        raise ValueError(
            f"prefill stream length {k.shape[0]} is not a whole number of "
            f"blocks of {bs}; pad the prompt to a block multiple")
    kb = k.reshape(nb, bs, *k.shape[1:])
    vb = v.reshape(nb, bs, *v.shape[1:])
    out = dict(pools)
    if pcfg.quantized:
        kq, ks = _quantize_rows(kb, bits=pcfg.quant_bits)
        vq, vs = _quantize_rows(vb, bits=pcfg.quant_bits)
        out["k"] = pools["k"].at[layer, block_table].set(kq)
        out["v"] = pools["v"].at[layer, block_table].set(vq)
        out["k_scale"] = pools["k_scale"].at[layer, block_table].set(ks)
        out["v_scale"] = pools["v_scale"].at[layer, block_table].set(vs)
        return out
    dt = pools["k"].dtype
    out["k"] = pools["k"].at[layer, block_table].set(kb.astype(dt))
    out["v"] = pools["v"].at[layer, block_table].set(vb.astype(dt))
    return out


# ---------------------------------------------------------------------------
# host-side block allocator
# ---------------------------------------------------------------------------


class BlockAllocator:
    """Free-list allocator over the physical blocks of one pool.

    Host-side and strictly bookkeeping — device code only ever sees the
    block ids it hands out. Invariants (property-tested):

    - a block is never handed out twice without an intervening ``free``;
    - ``free`` of an unallocated block raises (double-free guard);
    - ``num_free + len(allocated)`` is conserved at ``num_blocks - 1``
      (block 0 is the reserved sink and never circulates).
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the sink)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, SINK_BLOCK, -1))
        self._allocated: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset:
        return frozenset(self._allocated)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        blk = self._free.pop()
        self._allocated.add(blk)
        return blk

    def alloc_many(self, n: int) -> List[int]:
        if n > self.num_free:
            raise RuntimeError(
                f"KV block pool exhausted: need {n}, have {self.num_free}")
        return [self.alloc() for _ in range(n)]

    def free(self, block: int) -> None:
        if block not in self._allocated:
            raise ValueError(
                f"freeing block {block} that is not allocated "
                f"(double free or sink/out-of-range id)")
        self._allocated.remove(block)
        self._free.append(block)

    def free_many(self, blocks) -> None:
        for b in blocks:
            self.free(b)
