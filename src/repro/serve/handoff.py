"""Train→serve hot handoff (DESIGN.md §12).

The Pier trainer checkpoints through :class:`~repro.checkpoint.manager.
CheckpointManager`, whose manifest-last write order makes "complete"
well-defined: a checkpoint directory is live iff its ``manifest.json``
exists and every archive it names passes the CRC sweep —
``latest_step()`` already applies that filter, so the poller never
half-reads a checkpoint the trainer is still writing.

:class:`CheckpointPoller` watches the directory and, when a new complete
step appears, loads *serve params only* (no optimizer moments, no outer
state) and hands them to the engine via ``engine.set_params`` — which
takes effect at the next decode-step boundary. In-flight sequences keep
their KV blocks: their already-cached K/V was produced by the old params
(the usual serving-side relaxation of a hot swap; sequences started after
the swap are pure new-params), so nothing is dropped, recomputed, or
leaked.

Both on-disk conventions are understood:

- trainer (``launch/train.py``): ``state.npz`` holding a (G,)-stacked
  :class:`TrainState` — the poller slices group ``group`` (default 0) off
  every param leaf, i.e. serves one Pier replica;
- plain ``params.npz`` holding an unstacked param tree (the simulator /
  tests convention).
"""

from __future__ import annotations

import json
import os
from typing import Any, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, _path_key


class CheckpointPoller:
    """Poll a checkpoint directory for new complete steps.

    ``template`` is a pytree of the *unstacked* serve params (arrays or
    ShapeDtypeStructs) giving the expected shapes/dtypes; a checkpoint
    whose param leaves do not match is rejected loudly rather than served.
    """

    def __init__(self, manager: Union[str, CheckpointManager], template,
                 *, group: int = 0):
        self.mgr = (CheckpointManager(manager)
                    if isinstance(manager, str) else manager)
        self.template = template
        self.group = group
        self.seen_step: Optional[int] = None
        self.swapped_steps: List[int] = []

    def poll(self) -> Optional[Tuple[int, Any]]:
        """(step, params) when a newer complete checkpoint exists, else None."""
        step = self.mgr.latest_step()
        if step is None or (self.seen_step is not None
                            and step <= self.seen_step):
            return None
        params = self._load(step)
        self.seen_step = step
        return step, params

    def on_step(self, engine) -> None:
        """``engine.run(on_step=poller.on_step)`` — swap at step boundaries."""
        got = self.poll()
        if got is not None:
            step, params = got
            engine.set_params(params)
            self.swapped_steps.append(step)

    # ------------------------------------------------------------------ load

    def _load(self, step: int):
        path = os.path.join(self.mgr.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        trees = manifest.get("trees", {})
        if "params" in trees:
            npz, prefix, stacked = "params.npz", "", False
        elif "state" in trees:
            npz, prefix, stacked = "state.npz", "params/", True
        else:
            raise ValueError(
                f"checkpoint step_{step:08d} carries neither a 'params' nor "
                f"a 'state' tree (found {sorted(trees)}); nothing to serve")
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(self.template)
        leaves = []
        with np.load(os.path.join(path, npz)) as data:
            for p, leaf in flat_t:
                key = prefix + _path_key(p)
                if key not in data:
                    raise ValueError(
                        f"checkpoint step_{step:08d}: param {key!r} missing "
                        f"from {npz}")
                arr = data[key]
                if stacked:
                    if arr.shape[0] <= self.group:
                        raise ValueError(
                            f"checkpoint step_{step:08d}: group {self.group} "
                            f"out of range for {key!r} with shape {arr.shape}")
                    arr = arr[self.group]
                if tuple(arr.shape) != tuple(leaf.shape):
                    raise ValueError(
                        f"checkpoint step_{step:08d}: {key!r} shape "
                        f"{arr.shape} != serve template {leaf.shape}")
                leaves.append(jax.device_put(
                    jnp.asarray(arr, jnp.dtype(leaf.dtype))))
        return jax.tree_util.tree_unflatten(treedef, leaves)
