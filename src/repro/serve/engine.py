"""Continuous-batching serve engine over the paged KV pool (DESIGN.md §12).

The engine owns the request queue, the :class:`~repro.serve.kv_cache.
BlockAllocator`, and ``max_slots`` decode slots. Each :meth:`step` is one
engine iteration:

1. **Admission** — pop waiting requests into free slots while the
   admission policy holds. The policy is *conservative full reservation*:
   a request is admitted only if the free list can cover every block it
   can ever need (padded prompt + ``max_new_tokens``), and those blocks
   are allocated up front — an admitted sequence can never hit pool
   exhaustion mid-flight, so there is no preemption path to get wrong.
   A ``token_budget`` additionally caps the summed live tokens.
2. **Prefill** — newly admitted prompts run one at a time (B=1) through
   ``prefill_step``; the prompt is right-padded to a block multiple
   (masked at decode by ``context_lens``) and the first token sampled
   from the last real position's logits.
3. **Decode** — one fused ``decode_step`` over all ``max_slots`` slots;
   empty slots carry ``context_len 0`` and compute into the sink block.
4. **Completion** — sequences reaching ``max_new_tokens`` (or ``eos_id``)
   leave their slot and return their blocks to the pool.

``continuous=False`` degrades to static batching — admission only when
every slot is empty, so a whole wave must drain before the next starts —
which is exactly the baseline ``benchmarks/serve_bench.py`` compares
against.

Latency accounting is wall-clock per engine step, attributed to every
token emitted in that step; the engine calls ``block_until_ready`` each
step so the timings are honest on-device numbers, not dispatch times.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ParallelConfig
from repro.models import transformer as T
from repro.serve import kv_cache as KC


@dataclass
class EngineConfig:
    max_slots: int = 4           # fused-decode batch width
    max_new_tokens: int = 32     # default per-request cap
    token_budget: int = 0        # cap on summed live tokens; 0 = pool-bound
    continuous: bool = True      # False = static-batching baseline
    greedy: bool = True
    temperature: float = 1.0
    eos_id: int = -1             # -1 = never; requests run to max_new_tokens
    seed: int = 0                # sampling stream (greedy=False)
    max_blocks_per_seq: int = 0  # block-table width; 0 = whole pool


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0         # trace timestamp (bench bookkeeping)


@dataclass
class RequestResult:
    uid: int
    prompt_len: int
    tokens: List[int]
    arrival: float
    admitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    token_times: List[float] = field(default_factory=list)


@dataclass
class _Seq:
    """A live sequence occupying a decode slot."""

    req: Request
    blocks: List[int]            # all reserved physical blocks, in order
    pos: int                     # absolute position of the NEXT token fed
    next_token: int
    result: RequestResult


class ServeEngine:
    """Continuous-batching engine; see the module docstring for the loop."""

    def __init__(self, params, cfg: ModelConfig, bundle,
                 pcfg: KC.PagedCacheConfig, ecfg: EngineConfig):
        ok, why = KC.paged_supported(cfg)
        if not ok:
            raise ValueError(f"paged serving unsupported for {cfg.name}: {why}")
        if T.is_scanned(params["layers"]):
            raise ValueError("paged serving expects unstacked layer params")
        self.params = params
        self.cfg = cfg
        self.bundle = bundle
        self.pcfg = pcfg
        self.ecfg = ecfg
        self.alloc = KC.BlockAllocator(pcfg.num_blocks)
        self.pools = bundle.init_pools()
        self.waiting: deque = deque()
        self.slots: List[Optional[_Seq]] = [None] * ecfg.max_slots
        self._rng = np.random.default_rng(ecfg.seed)
        self._uid = 0
        # Block-table width = the longest admissible sequence in blocks.
        # It is baked into the compiled decode step (the kernel grid walks
        # the whole table), so keep it as tight as the workload allows.
        self.table_width = ecfg.max_blocks_per_seq or (pcfg.num_blocks - 1)
        self.finished: List[RequestResult] = []
        self.stats: Dict[str, Any] = {
            "steps": 0, "prefills": 0, "decode_steps": 0,
            "tokens_out": 0, "peak_blocks": 0,
        }

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               arrival: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self._uid += 1
        self.waiting.append(Request(
            uid=self._uid, prompt=prompt,
            max_new_tokens=max_new_tokens or self.ecfg.max_new_tokens,
            arrival=arrival))
        return self._uid

    # -- admission policy ----------------------------------------------------

    def _blocks_needed(self, req: Request) -> int:
        padded = -(-len(req.prompt) // self.pcfg.block_size) * self.pcfg.block_size
        return self.pcfg.blocks_for(padded + req.max_new_tokens)

    def _live_tokens(self) -> int:
        return sum(s.pos for s in self.slots if s is not None)

    def _admissible(self, req: Request) -> bool:
        need = self._blocks_needed(req)
        if need > self.table_width:
            raise ValueError(
                f"request {req.uid} needs {need} blocks > pool capacity "
                f"{self.table_width}")
        if need > self.alloc.num_free:
            return False
        budget = self.ecfg.token_budget
        if budget and self._live_tokens() + len(req.prompt) > budget:
            return False
        return True

    # -- engine iteration ----------------------------------------------------

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.ecfg.greedy:
            return int(np.argmax(logits_row))
        z = logits_row / max(self.ecfg.temperature, 1e-6)
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    def _admit_and_prefill(self, now: float) -> None:
        if not self.ecfg.continuous and any(s is not None for s in self.slots):
            return  # static batching: wait for the whole wave to drain
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.waiting:
                continue
            if not self._admissible(self.waiting[0]):
                break  # FIFO: don't let short requests starve long ones
            req = self.waiting.popleft()
            blocks = self.alloc.alloc_many(self._blocks_needed(req))
            bs = self.pcfg.block_size
            S = len(req.prompt)
            padded = -(-S // bs) * bs
            prompt = np.zeros((1, padded), np.int32)
            prompt[0, :S] = req.prompt
            logits, self.pools = self.bundle.prefill_step(
                self.params, jnp.asarray(prompt), self.pools,
                jnp.asarray(blocks[: padded // bs], jnp.int32),
                jnp.asarray(S - 1, jnp.int32))
            first = self._sample(
                np.asarray(jax.block_until_ready(logits)[0], np.float32))
            t_first = time.perf_counter()
            res = RequestResult(
                uid=req.uid, prompt_len=S, tokens=[first],
                arrival=req.arrival, admitted_at=now, first_token_at=t_first)
            res.token_times.append(t_first)
            self.slots[i] = _Seq(req=req, blocks=blocks, pos=S,
                                 next_token=first, result=res)
            self.stats["prefills"] += 1
            self.stats["tokens_out"] += 1

    def _decode_batch(self) -> None:
        B = self.ecfg.max_slots
        tokens = np.zeros((B,), np.int32)
        positions = np.zeros((B,), np.int32)
        context = np.zeros((B,), np.int32)
        tables = np.full((B, self.table_width), -1, np.int32)
        live = False
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            live = True
            tokens[i] = s.next_token
            positions[i] = s.pos
            context[i] = s.pos + 1
            tables[i, : len(s.blocks)] = s.blocks
        if not live:
            return
        logits, self.pools = self.bundle.decode_step(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(tables),
            jnp.asarray(context))
        logits = np.asarray(jax.block_until_ready(logits), np.float32)
        now = time.perf_counter()
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tok = self._sample(logits[i])
            s.pos += 1
            s.result.tokens.append(tok)
            s.result.token_times.append(now)
            s.next_token = tok
            self.stats["tokens_out"] += 1
            done = (len(s.result.tokens) >= s.req.max_new_tokens
                    or tok == self.ecfg.eos_id)
            if done:
                s.result.finished_at = now
                self.alloc.free_many(s.blocks)
                self.finished.append(s.result)
                self.slots[i] = None
        self.stats["decode_steps"] += 1

    def step(self) -> bool:
        """One engine iteration. Returns True while work remains."""
        now = time.perf_counter()
        self._admit_and_prefill(now)
        in_use = self.alloc.num_free
        self.stats["peak_blocks"] = max(
            self.stats["peak_blocks"],
            (self.pcfg.num_blocks - 1) - in_use)
        self._decode_batch()
        self.stats["steps"] += 1
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def run(self, on_step: Optional[Callable[["ServeEngine"], None]] = None,
            max_steps: int = 100000) -> List[RequestResult]:
        """Drive :meth:`step` until the queue drains.

        ``on_step`` runs between engine steps — the hot-handoff hook
        (``serve/handoff.py`` swaps ``self.params`` there, which lands at
        the next step boundary without touching in-flight sequences).
        """
        for _ in range(max_steps):
            if on_step is not None:
                on_step(self)
            if not self.step():
                break
        else:
            raise RuntimeError("engine did not drain within max_steps")
        return sorted(self.finished, key=lambda r: r.uid)

    # -- hot handoff ---------------------------------------------------------

    def set_params(self, params) -> None:
        """Swap the served params; takes effect at the next step boundary."""
        if T.is_scanned(params["layers"]):
            raise ValueError("paged serving expects unstacked layer params")
        self.params = params

    # -- occupancy -----------------------------------------------------------

    @property
    def occupancy(self) -> float:
        usable = self.pcfg.num_blocks - 1
        return (usable - self.alloc.num_free) / usable


# ---------------------------------------------------------------------------
# shared generate() helper (launch/serve.py + examples/serve_decode.py)
# ---------------------------------------------------------------------------


def generate(params, cfg: ModelConfig, pc: ParallelConfig, mesh, prompts,
             num_tokens: int, *, greedy: bool = True, temperature: float = 1.0,
             seed: int = 0, frames=None,
             pcfg: Optional[KC.PagedCacheConfig] = None):
    """Generate ``num_tokens`` per prompt row. Returns ((B, num_tokens)
    np.int32 generated tokens, info dict).

    Paged-supported architectures go through the continuous-batching
    engine (one request per prompt row); MLA / SSM / encoder-decoder
    configs use the dense ``build_serve_steps`` path — the one
    prefill+decode loop both the launcher and the example used to
    copy-paste lives here now.
    """
    from repro.parallel.steps import (build_paged_serve_steps,
                                      build_serve_steps)

    prompts = np.asarray(prompts, np.int32)
    B, S = prompts.shape
    ok, why = KC.paged_supported(cfg)
    if ok and frames is None and not T.is_scanned(params["layers"]):
        if pcfg is None:
            bs = KC.PagedCacheConfig().block_size
            padded = -(-S // bs) * bs
            need = KC.PagedCacheConfig().blocks_for(padded + num_tokens)
            pcfg = KC.PagedCacheConfig(num_blocks=need * B + 1)
        need = pcfg.blocks_for(
            -(-S // pcfg.block_size) * pcfg.block_size + num_tokens)
        bundle = build_paged_serve_steps(cfg, pc, mesh, pcfg=pcfg)
        engine = ServeEngine(params, cfg, bundle, pcfg, EngineConfig(
            max_slots=B, max_new_tokens=num_tokens, greedy=greedy,
            temperature=temperature, seed=seed, max_blocks_per_seq=need))
        for b in range(B):
            engine.submit(prompts[b], num_tokens)
        results = engine.run()
        out = np.stack([np.asarray(r.tokens[:num_tokens], np.int32)
                        for r in results])
        return out, {"path": "paged", "engine": engine}

    # dense fallback: static batch, lockstep positions
    bundle = build_serve_steps(cfg, pc, mesh, batch=B,
                               max_len=S + num_tokens)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.is_encoder_decoder:
        if frames is None:
            raise ValueError("encoder-decoder serving needs frames")
        batch_in["frames"] = frames
    rng = np.random.default_rng(seed)

    def sample(logits):
        arr = np.asarray(logits[:, -1], np.float32)  # (B, V)
        if greedy:
            return np.argmax(arr, axis=-1).astype(np.int32)
        z = arr / max(temperature, 1e-6)
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z) / np.exp(z).sum(axis=-1, keepdims=True)
        return np.stack([rng.choice(arr.shape[-1], p=p[b])
                         for b in range(B)]).astype(np.int32)

    logits, state = bundle.prefill_step(params, batch_in)
    next_tok = sample(logits)
    generated = [next_tok]
    for _ in range(num_tokens - 1):
        logits, state = bundle.serve_step(params, state, jnp.asarray(next_tok[:, None]))
        next_tok = sample(logits)
        generated.append(next_tok)
    out = np.stack(generated, axis=1)  # (B, num_tokens)
    return out, {"path": "dense", "bundle": bundle}
