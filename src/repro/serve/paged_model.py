"""Model forward passes against the paged KV pool (DESIGN.md §12).

Two entry points, both pure functions jitted by
``parallel/steps.build_paged_serve_steps``:

- :func:`paged_prefill` — run the ordinary training forward with
  ``collect_kv=True`` over one (padded) prompt and scatter the collected
  K/V streams into the sequence's blocks. Pad tokens' K/V lands in the
  pool but is masked at decode time by ``context_lens``.
- :func:`paged_decode_step` — one token per active slot, per-sequence
  positions (unlike the dense ``registry.decode_step`` lockstep scalar
  position), attention via the ``kernels/decode_attention.py`` Pallas
  kernel gathering through each sequence's block table.

Only architectures passing ``kv_cache.paged_supported`` come through
here — every decoder layer is attn/local_attn with a gqa-family head
layout, so the layer loop needs exactly the norm/attn/mlp residual
structure of ``transformer._decoder_layer_fwd`` (MoE MLPs included).
MLA / SSM / rgLRU / encoder-decoder configs use the dense path.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops as kops
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.serve import kv_cache as KC


def _embed(p, tokens, positions, cfg: ModelConfig):
    """tokens (B, 1) + per-sequence absolute positions (B,) -> (B, 1, D).

    ``layers.embed_tokens`` broadcasts one scalar offset across the batch
    (lockstep dense decode); continuous batching needs a position per
    sequence, so learned-position lookup happens per row here.
    """
    x = jnp.take(L.cast(p["tokens"], cfg), tokens, axis=0)
    if cfg.positional == "learned":
        pos_emb = jnp.take(L.cast(p["positions"], cfg), positions, axis=0)
        x = x + pos_emb[:, None]
    return x


def _layer_window(cfg: ModelConfig, layer_idx: int) -> int:
    kind = cfg.block_kind(layer_idx)
    return cfg.local_window if kind == "local_attn" else cfg.sliding_window


def paged_prefill(params, cfg: ModelConfig, tokens, pools, block_table, *,
                  pcfg: KC.PagedCacheConfig, use_pallas: bool = False):
    """Prefill one prompt into its blocks.

    tokens (1, S) with S a multiple of ``pcfg.block_size`` (engine pads;
    right-padding is harmless under the causal mask — pad K/V is masked
    by ``context_lens`` at decode time). block_table (S / bs,) int32
    physical block ids. Returns (logits (1, S, V), pools).
    """
    if T.is_scanned(params["layers"]):
        raise ValueError("paged serving expects unstacked layer params")
    logits, aux = T.forward(
        params, cfg, {"tokens": tokens}, use_pallas=use_pallas,
        collect_kv=True)
    for kv_i, li in enumerate(KC.kv_layer_indices(cfg)):
        k, v = aux["kv"][li]
        pools = KC.write_prefill(pools, kv_i, block_table, k[0], v[0],
                                 pcfg=pcfg)
    return logits, pools


def paged_decode_step(params, cfg: ModelConfig, pools, tokens, positions,
                      block_tables, context_lens, *,
                      pcfg: KC.PagedCacheConfig):
    """One decode step over every slot of the batch.

    tokens (B,) int32 — the token being fed at ``positions`` (B,) int32
    (its absolute index, so after an S-token prefill the first decode
    feeds the sampled token at position S). block_tables (B, T) int32.
    context_lens (B,) int32 — tokens visible *including* this one
    (``positions + 1`` for live slots, 0 for empty slots, whose rows
    compute garbage into the sink block and come out as zero logits).

    Returns (logits (B, V) fp32, pools).
    """
    B = tokens.shape[0]
    bs = pcfg.block_size
    active = context_lens > 0
    # Empty slots write their garbage K/V to the reserved sink block.
    rows = jnp.arange(B)
    blk_idx = jnp.clip(positions // bs, 0, block_tables.shape[1] - 1)
    write_blocks = jnp.where(active, block_tables[rows, blk_idx],
                             KC.SINK_BLOCK).astype(jnp.int32)
    slots = (positions % bs).astype(jnp.int32)
    pos2d = positions[:, None]  # (B, 1)

    x = _embed(params["embed"], tokens[:, None], positions, cfg)
    quantized = "k_scale" in pools
    for kv_i, li in enumerate(KC.kv_layer_indices(cfg)):
        lp = params["layers"][li]
        h = L.apply_norm(lp["norm1"], x, cfg)
        q, k, v = A._project_qkv(lp["mix"], h, h, cfg)  # (B, 1, H/Hkv, hd)
        if cfg.positional == "rope":
            q = L.apply_rope(q, pos2d, cfg.rope_theta)
            k = L.apply_rope(k, pos2d, cfg.rope_theta)
        pools = KC.write_token(pools, kv_i, write_blocks, slots,
                               k[:, 0], v[:, 0], pcfg=pcfg)
        out = kops.paged_decode_attention(
            q[:, 0], pools["k"][kv_i], pools["v"][kv_i],
            block_tables, context_lens,
            pools["k_scale"][kv_i] if quantized else None,
            pools["v_scale"][kv_i] if quantized else None,
            window=_layer_window(cfg, li))
        x = x + jnp.einsum("bshk,hkd->bsd", out[:, None],
                           L.cast(lp["mix"]["wo"], cfg))
        if "mlp" in lp:
            h = L.apply_norm(lp["norm2"], x, cfg)
            if cfg.is_moe and li >= cfg.first_dense_layers:
                mlp_out, _ = MOE.apply_moe(lp["mlp"], h, cfg)
            else:
                mlp_out = L.apply_mlp(lp["mlp"], h, cfg)
            x = x + mlp_out

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)[:, 0]  # (B, V)
    logits = jnp.where(active[:, None], logits, 0.0)
    return logits, pools
