"""Serving subsystem (DESIGN.md §12): the other half of the Pier loop.

- :mod:`repro.serve.kv_cache` — paged/blocked KV cache: a fixed pool of
  KV blocks, a host-side free-list allocator, per-sequence block tables,
  and an optional int8 block format reusing ``kernels/quantize.py``.
- :mod:`repro.serve.paged_model` — single-token decode forward against the
  paged pool (the ``kernels/decode_attention.py`` Pallas kernel).
- :mod:`repro.serve.engine` — continuous-batching engine: admission
  control, prefill/decode interleaving, eviction, latency accounting.
- :mod:`repro.serve.handoff` — train→serve hot handoff: poll
  ``CheckpointManager`` for new complete steps and hot-swap params into
  the running engine between decode steps.
"""

from repro.serve.engine import (EngineConfig, Request, RequestResult,  # noqa: F401
                                ServeEngine, generate)
from repro.serve.handoff import CheckpointPoller  # noqa: F401
from repro.serve.kv_cache import (BlockAllocator, PagedCacheConfig,  # noqa: F401
                                  paged_supported)
