"""Jit'd wrappers: the integration surface between kernels and the system.

Every wrapper dispatches through the KernelBackend registry
(kernels/backend.py): each kernel entry point resolves its lane (compiled
Pallas, interpreted Pallas, or the jnp oracle) from the process-wide
backend and its per-kernel capability table — there is no ``interpret``
threading here anymore.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention import paged_decode_attention as _paged_decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pier_update import pier_update as _pier_update
from repro.kernels.quantize import (dequantize_blockwise as _dequantize,
                                    quantize_blockwise as _quantize)
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention_supported(q, k, v, *, window: int = 0,
                              softcap: float = 0.0) -> bool:
    B, S, H, hd = q.shape
    if hd % 8 != 0 or hd > 256:
        return False
    if k.shape[2] and q.shape[2] % k.shape[2] != 0:
        return False
    return S >= 16


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    return _flash(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=128, block_kv=128)


# ---------------------------------------------------------------------------
# paged decode attention (serving, DESIGN.md §12)
# ---------------------------------------------------------------------------


def paged_decode_attention(q, k_pool, v_pool, block_tables, context_lens,
                           k_scales=None, v_scales=None, *,
                           window: int = 0, softcap: float = 0.0):
    """Single-query attention through a block table (kernels/decode_attention).

    q (B, H, hd); pools (N, bs, Hkv, hd) [+ (N, bs, Hkv) fp32 scales when
    int8-quantized]; block_tables (B, T) int32; context_lens (B,) int32.
    """
    return _paged_decode(
        q, k_pool, v_pool, block_tables, context_lens, k_scales, v_scales,
        window=window, softcap=softcap)


# ---------------------------------------------------------------------------
# fused Pier outer update (over whole pytrees)
# ---------------------------------------------------------------------------


def pier_update_leaf(a, m, d, tc, *, mu, lr):
    """Fused Pier outer update on one leaf (any shape) -> (p_f32, m_new).

    The single-leaf building block of ``core.outer.outer_reduce_leaves``
    (the use_pallas path of both the fused and the chunked span-wise
    outer reduce).
    """
    shape = m.shape
    p1, m1 = _pier_update(
        a.reshape(-1), m.reshape(-1), d.reshape(-1),
        jnp.asarray(mu, jnp.float32), jnp.asarray(lr, jnp.float32),
        formulation=tc.outer_optimizer)
    return p1.reshape(shape), m1.reshape(shape).astype(m.dtype)


# ---------------------------------------------------------------------------
# blockwise Δθ quantize / dequantize (compressed outer collective)
# ---------------------------------------------------------------------------


def quantize_blockwise(x, *, bits: int = 8, block: int = 256):
    """Flat (N,) -> (q int8 (nblocks*block,), scales f32 (nblocks,))."""
    return _quantize(x, bits=bits, block=block)


def dequantize_blockwise(q, scales, *, block: int = 256):
    """Inverse of :func:`quantize_blockwise` (padded payload, fp32)."""
    return _dequantize(q, scales, block=block)


# NOTE: the int8-wire ring all-reduce (kernels/ring_allreduce.py) is NOT
# wrapped here: its transport resolves backend-aware from the strategy's
# ReduceCtx (use_pallas + resolve_transport), not per-call, so the
# Int8Wire strategy imports it directly.


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, *, eps: float = 1e-5):
    return _rmsnorm(x, scale, eps=eps)
