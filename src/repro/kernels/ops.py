"""Jit'd wrappers: the integration surface between kernels and the system.

``interpret`` resolves backend-aware (kernels/backend.py): compiled Mosaic
on a real TPU, interpreter mode elsewhere (the kernels execute their Python
bodies for correctness validation). The same BlockSpecs drive both.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.backend import default_interpret, on_tpu
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pier_update import pier_update as _pier_update
from repro.kernels.quantize import (dequantize_blockwise as _dequantize,
                                    quantize_blockwise as _quantize)
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def _interpret() -> bool:
    return default_interpret(None)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention_supported(q, k, v, *, window: int = 0,
                              softcap: float = 0.0) -> bool:
    B, S, H, hd = q.shape
    if hd % 8 != 0 or hd > 256:
        return False
    if k.shape[2] and q.shape[2] % k.shape[2] != 0:
        return False
    return S >= 16


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    return _flash(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=128, block_kv=128, interpret=_interpret())


# ---------------------------------------------------------------------------
# fused Pier outer update (over whole pytrees)
# ---------------------------------------------------------------------------


def pier_outer_update(state, delta_avg, tc, *, mu, lr, residual=None):
    """Drop-in replacement for core.outer.outer_update (use_pallas path).

    state: OuterState; delta_avg: pytree of fp32 deltas. ``residual`` is the
    new error-feedback residual to store (compressed collective); ``None``
    carries the state's own through.
    Returns (new_params_f32_tree, new OuterState).
    """
    from repro.core.outer import OuterState  # local import to avoid cycle

    flat_m, treedef = jax.tree_util.tree_flatten(state.momentum)
    flat_a = treedef.flatten_up_to(state.anchor)
    flat_d = treedef.flatten_up_to(delta_avg)
    new_p, new_m = [], []
    for m, a, d in zip(flat_m, flat_a, flat_d):
        shape = m.shape
        p1, m1 = _pier_update(
            a.reshape(-1), m.reshape(-1), d.reshape(-1),
            jnp.asarray(mu, jnp.float32), jnp.asarray(lr, jnp.float32),
            formulation=tc.outer_optimizer, interpret=_interpret())
        new_p.append(p1.reshape(shape))
        new_m.append(m1.reshape(shape).astype(m.dtype))
    unf = jax.tree_util.tree_unflatten
    params_f32 = unf(treedef, new_p)
    sdt = flat_m[0].dtype if flat_m else jnp.float32
    new_state = OuterState(
        momentum=unf(treedef, new_m),
        anchor=jax.tree.map(lambda p: p.astype(sdt), params_f32),
        num_syncs=state.num_syncs + 1,
        residual=residual if residual is not None else state.residual,
    )
    return params_f32, new_state


# ---------------------------------------------------------------------------
# blockwise Δθ quantize / dequantize (compressed outer collective)
# ---------------------------------------------------------------------------


def quantize_blockwise(x, *, bits: int = 8, block: int = 256):
    """Flat (N,) -> (q int8 (nblocks*block,), scales f32 (nblocks,))."""
    return _quantize(x, bits=bits, block=block, interpret=_interpret())


def dequantize_blockwise(q, scales, *, block: int = 256):
    """Inverse of :func:`quantize_blockwise` (padded payload, fp32)."""
    return _dequantize(q, scales, block=block, interpret=_interpret())


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, *, eps: float = 1e-5):
    return _rmsnorm(x, scale, eps=eps, interpret=_interpret())
