from repro.kernels import ops  # noqa: F401
from repro.kernels.backend import (kernel_lane,  # noqa: F401
                                   reset_backend_cache, resolve_backend,
                                   set_kernel_backend)
