"""Fused Pier outer-update Pallas kernel (Alg. 2 lines 20-21).

The unfused update reads θ_anchor, M, Δθ and writes θ', M', anchor' as six
separate HBM-bound elementwise ops (XLA usually fuses some but keeps fp32
temporaries). This kernel streams one (block,) panel of each operand through
VMEM and emits both outputs in a single pass — the op is purely
memory-bandwidth-bound, so one fused pass is its roofline.

μ and lr arrive as (1, 1) SMEM scalars so one compiled kernel serves every
step of the μ-decay / outer-LR schedules (no recompilation when they change).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_kernel
from repro.kernels.ref import pier_update_ref

_BLOCK = 4096  # lanes*32 panels: multiple of the (8,128) fp32 VMEM tile


def _update_kernel(mu_ref, lr_ref, a_ref, m_ref, d_ref, p_out, m_out, *,
                   formulation: str):
    mu = mu_ref[0, 0]
    lr = lr_ref[0, 0]
    a = a_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    m_new = mu * m + d
    if formulation == "nesterov_torch":
        step = mu * m_new + d
    elif formulation == "nesterov_classic":
        step = mu * m + d
    else:  # sgd
        step = m_new
    p_out[...] = (a + lr * step).astype(p_out.dtype)
    m_out[...] = m_new.astype(m_out.dtype)


def pier_update(
    anchor: jax.Array,  # flattened (N,) — any dtype
    momentum: jax.Array,  # (N,)
    delta: jax.Array,  # (N,)
    mu: jax.Array,  # scalar
    lr: jax.Array,  # scalar
    *,
    formulation: str = "nesterov_torch",
    block: int = _BLOCK,
    interpret: Optional[bool] = None,
):
    """Returns (new_params_f32, new_momentum) for one flat leaf.

    ``interpret=None`` dispatches through the KernelBackend registry:
    compiled Mosaic on tpu-mosaic, the interpreter off-accelerator, and
    the jnp oracle on gpu-triton (SMEM scalars don't lower to Triton) and
    jnp-ref. An explicit bool forces the Pallas body (legacy override).
    """
    impl, interpret = resolve_kernel("pier_update", interpret)
    if impl == "jnp":
        return _pier_update_jnp(anchor, momentum, delta, mu, lr,
                                formulation=formulation)
    return _pier_update_pallas(anchor, momentum, delta, mu, lr,
                               formulation=formulation, block=block,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("formulation",))
def _pier_update_jnp(anchor, momentum, delta, mu, lr, *, formulation):
    p, m = pier_update_ref(anchor, momentum, delta, mu=mu, lr=lr,
                           formulation=formulation)
    # match the kernel's output dtypes: p fp32, m in the momentum dtype
    return p, m.astype(momentum.dtype)


@functools.partial(
    jax.jit, static_argnames=("formulation", "block", "interpret"))
def _pier_update_pallas(anchor, momentum, delta, mu, lr, *,
                        formulation, block, interpret):
    (n,) = anchor.shape
    np_ = ((n + block - 1) // block) * block
    if np_ != n:
        anchor, momentum, delta = (
            jnp.pad(t, (0, np_ - n)) for t in (anchor, momentum, delta))
    grid = (np_ // block,)
    mu2 = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)

    p_new, m_new = pl.pallas_call(
        functools.partial(_update_kernel, formulation=formulation),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), momentum.dtype),
        ],
        interpret=interpret,
    )(mu2, lr2, anchor, momentum, delta)
    return p_new[:n], m_new[:n]
