"""KernelBackend registry: which lowering lane serves each kernel entry.

Four backends (DESIGN.md §13), resolved once per process:

- ``tpu-mosaic``  — compiled Pallas (Mosaic) on real TPU devices.
- ``gpu-triton``  — Pallas-on-Triton lowering for the kernels whose bodies
  are portable (plain ``pl.BlockSpec`` only), with a per-kernel jnp
  fallback for the TPU-idiomatic ones (SMEM scalars, VMEM scratch,
  scalar-prefetch grids, remote DMA — none of which Triton lowers).
- ``interpret``   — ``pallas_call(interpret=True)``: the kernel Python
  bodies execute on the host. The default off-accelerator, and the lane
  every bitwise kernel-vs-oracle test pins.
- ``jnp-ref``     — the :mod:`repro.kernels.ref` oracles as a dispatchable
  lane: a full training/serving step with no Pallas anywhere (CI's
  backend-matrix job proves it).

Resolution order: :func:`set_kernel_backend` (the launcher's
``--kernel-backend``) > the ``REPRO_KERNEL_BACKEND`` env var > platform
auto-detect. It happens lazily at the first kernel call — never at import
time, so ``jax_platform_name`` / distributed init can still run first —
and :func:`reset_backend_cache` drops the cached answer (tests, and any
launcher that re-initializes the platform).

Kernels keep their ``interpret: Optional[bool] = None`` signatures: an
explicit bool is the legacy per-call override (always the Pallas body,
interpreted or compiled as requested — the bitwise test harness);
``None`` dispatches through :func:`resolve_kernel`.

Lives in its own module (not ``ops.py``) because the kernel modules
cannot import ``ops`` without a cycle.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Mapping, Optional, Tuple

import jax

# The three lanes a kernel entry point can resolve to.
COMPILED = "pallas-compiled"    # pl.pallas_call, compiled lowering
INTERPRET = "pallas-interpret"  # pl.pallas_call(interpret=True)
JNP = "jnp"                     # the kernels/ref.py oracle

BACKEND_NAMES = ("tpu-mosaic", "gpu-triton", "interpret", "jnp-ref")

# Per-kernel capability table: which lane serves each kernel on each
# backend, and why (DESIGN.md §13 carries the prose version).
#
# gpu-triton column: quantize/dequantize/rmsnorm use only plain
# ``pl.BlockSpec`` tiling — portable to the Triton lowering. The rest are
# TPU-idiomatic and fall back to the jnp oracle there:
#   pier_update       — (1,1) μ/lr scalars in ``pltpu.SMEM``
#   flash_attention   — ``pltpu.VMEM`` scratch + TPU dimension_semantics
#   decode_attention  — ``pltpu.PrefetchScalarGridSpec`` block-table gather
#   ring_allreduce    — ``pltpu.make_async_remote_copy`` remote DMA
# interpret column: every kernel body executes under the interpreter —
# except the remote-DMA ring, whose semantics need a real multi-device
# TPU ring (off-TPU the transport resolver picks ppermute/psum instead,
# see kernels/ring_allreduce.resolve_transport).
KERNEL_CAPS: Mapping[str, Mapping[str, str]] = {
    "quantize": {
        "tpu-mosaic": COMPILED, "gpu-triton": COMPILED,
        "interpret": INTERPRET, "jnp-ref": JNP,
    },
    "dequantize": {
        "tpu-mosaic": COMPILED, "gpu-triton": COMPILED,
        "interpret": INTERPRET, "jnp-ref": JNP,
    },
    "rmsnorm": {
        "tpu-mosaic": COMPILED, "gpu-triton": COMPILED,
        "interpret": INTERPRET, "jnp-ref": JNP,
    },
    "pier_update": {
        "tpu-mosaic": COMPILED, "gpu-triton": JNP,
        "interpret": INTERPRET, "jnp-ref": JNP,
    },
    "flash_attention": {
        "tpu-mosaic": COMPILED, "gpu-triton": JNP,
        "interpret": INTERPRET, "jnp-ref": JNP,
    },
    "decode_attention": {
        "tpu-mosaic": COMPILED, "gpu-triton": JNP,
        "interpret": INTERPRET, "jnp-ref": JNP,
    },
    "ring_allreduce": {
        "tpu-mosaic": COMPILED, "gpu-triton": JNP,
        "interpret": JNP, "jnp-ref": JNP,
    },
}


@dataclass(frozen=True)
class KernelBackend:
    """One resolved backend: a name and its column of the capability table."""

    name: str

    def lane(self, kernel: str) -> str:
        try:
            return KERNEL_CAPS[kernel][self.name]
        except KeyError:
            raise KeyError(
                f"unknown kernel {kernel!r} "
                f"(registered: {', '.join(sorted(KERNEL_CAPS))})") from None


BACKENDS: Mapping[str, KernelBackend] = {
    name: KernelBackend(name) for name in BACKEND_NAMES}

# Module-level cache (NOT functools.cache: an explicit reset must be able
# to drop an answer cached before jax_platform_name / distributed init).
_forced: Optional[str] = None
_resolved: Optional[KernelBackend] = None
_is_tpu: Optional[bool] = None


def _detect_platform() -> str:
    """The jax platform — the only place kernels touch device state.

    Called lazily at the first kernel dispatch (never at import time).
    The single monkeypatch seam for the fake-platform tests.
    """
    try:
        return jax.devices()[0].platform
    except Exception:
        return "cpu"


def default_backend_name() -> str:
    platform = _detect_platform()
    if platform == "tpu":
        return "tpu-mosaic"
    if platform in ("gpu", "cuda", "rocm"):
        return "gpu-triton"
    return "interpret"


def resolve_backend() -> KernelBackend:
    """The process-wide backend, resolved once and cached.

    Order: :func:`set_kernel_backend` override > ``REPRO_KERNEL_BACKEND``
    env var > platform auto-detect. :func:`reset_backend_cache` drops the
    cached answer so the next call re-resolves.
    """
    global _resolved
    if _resolved is None:
        name = (_forced
                or os.environ.get("REPRO_KERNEL_BACKEND", "").strip()
                or default_backend_name())
        if name not in BACKENDS:
            raise ValueError(
                f"unknown kernel backend {name!r} "
                f"(choices: {', '.join(BACKEND_NAMES)})")
        _resolved = BACKENDS[name]
    return _resolved


def set_kernel_backend(name: Optional[str]) -> None:
    """Force the backend process-wide (the launcher's ``--kernel-backend``).

    ``None``/``""``/``"auto"`` reverts to env-var/auto-detect resolution.
    Clears the cached resolution either way, so the change takes effect at
    the next kernel call.
    """
    global _forced
    if name in (None, "", "auto"):
        _forced = None
    elif name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r} "
            f"(choices: {', '.join(BACKEND_NAMES)})")
    else:
        _forced = name
    reset_backend_cache()


def reset_backend_cache() -> None:
    """Drop the cached backend resolution and platform answer.

    Required after anything that changes what ``jax.devices()`` reports —
    ``jax.config.update("jax_platform_name", ...)``, distributed init —
    and by tests that fake the platform. Does NOT clear an explicit
    :func:`set_kernel_backend` override (that is a user decision, not a
    cache).
    """
    global _resolved, _is_tpu
    _resolved = None
    _is_tpu = None


def on_tpu() -> bool:
    """Whether this process runs on real TPU devices (lazily cached)."""
    global _is_tpu
    if _is_tpu is None:
        _is_tpu = _detect_platform() == "tpu"
    return _is_tpu


def kernel_lane(kernel: str) -> str:
    """The resolved backend's lane for one kernel (capability table row)."""
    return resolve_backend().lane(kernel)


def resolve_kernel(kernel: str,
                   interpret: Optional[bool] = None) -> Tuple[str, bool]:
    """``(impl, interpret_flag)`` for one kernel entry point.

    ``impl`` is ``"pallas"`` (run the Pallas body with the returned
    ``interpret`` flag) or ``"jnp"`` (dispatch to the kernels/ref.py
    oracle; the flag is meaningless then). An explicit ``interpret`` bool
    keeps the legacy per-call override: always the Pallas body,
    interpreted or compiled as requested — the bitwise kernel-vs-oracle
    tests pin ``interpret=True`` regardless of the resolved backend.
    """
    if interpret is not None:
        return "pallas", bool(interpret)
    lane = kernel_lane(kernel)
    if lane == JNP:
        return "jnp", False
    return "pallas", lane == INTERPRET


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Deprecated pre-registry resolver (None -> interpret off-TPU).

    Kept only for backward compatibility; every kernel entry point now
    dispatches through :func:`resolve_kernel`, and no call site outside
    this module remains (asserted by tests/test_backend.py).
    """
    if interpret is None:
        return not on_tpu()
    return interpret
