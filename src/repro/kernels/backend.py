"""Backend detection shared by every Pallas kernel.

Kernels take ``interpret: Optional[bool] = None`` and resolve ``None`` via
:func:`default_interpret`: compiled (Mosaic) on a real TPU backend,
interpreter mode everywhere else. Lives in its own module (not ``ops.py``)
because the kernel modules cannot import ``ops`` without a cycle.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax


@functools.cache
def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` argument: None -> backend-aware default."""
    if interpret is None:
        return not on_tpu()
    return interpret
