"""Flash-attention Pallas TPU kernel (FlashAttention-2 analogue, paper §V).

TPU adaptation of the CUDA algorithm: instead of warps/shared-memory tiles,
the kernel tiles (block_q × head_dim) query panels and (block_kv × head_dim)
KV panels into VMEM with an online-softmax accumulator in VMEM scratch, and
drives the MXU with 128-aligned matmul panels. The KV axis is the innermost
*sequential* grid dimension, so the running (m, l, acc) state lives in VMEM
scratch across grid steps — the TPU-idiomatic replacement for the CUDA inner
loop (there is no warp-shuffle analogue; the online-softmax reduction is a
VREG reduction instead).

GQA is handled in the BlockSpec index maps (kv block index = h // group), so
KV panels are never replicated to the full head count in HBM.

Supports causal masking, sliding windows, and logit soft-capping. Causal
panels strictly above the diagonal are skipped with ``pl.when`` (no MXU work
issued), which on TPU halves the effective FLOPs exactly as FA-2's block
skipping does on SMs.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_kernel
from repro.kernels.ref import flash_attention_ref

# jax < 0.5 names this TPUCompilerParams; it was renamed to CompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,  # output tile
    m_scr, l_scr, acc_scr,  # VMEM scratch: (bq,1), (bq,1), (bq, hd)
    *,
    scale: float,
    block_q: int,
    block_kv: int,
    seq_len: int,
    causal: bool,
    window: int,
    softcap: float,
    num_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < seq_len  # exclude padded kv positions
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window

        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]  # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    if causal:
        # skip panels entirely above the causal diagonal
        last_q = qi * block_q + block_q - 1
        first_k = ki * block_kv

        @pl.when(last_q >= first_k)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Pallas flash attention. Returns (B, S, H, hd) in q.dtype.

    ``interpret=None`` dispatches through the KernelBackend registry —
    compiled Mosaic on tpu-mosaic (the old hardcoded ``interpret=True``
    default meant direct callers never compiled on real TPUs), the
    interpreter off-accelerator, the jnp oracle on gpu-triton/jnp-ref
    (VMEM scratch + dimension_semantics don't lower to Triton). An
    explicit bool forces the Pallas body (legacy override).
    """
    impl, interpret = resolve_kernel("flash_attention", interpret)
    if impl == "jnp":
        return _flash_attention_jnp(q, k, v, causal=causal, window=window,
                                    softcap=softcap)
    return _flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def _flash_attention_jnp(q, k, v, *, causal, window, softcap):
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "interpret"),
)
def _flash_attention_pallas(q, k, v, *, causal, window, softcap,
                            block_q, block_kv, interpret):
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    block_q = max(min(block_q, S), 8)
    block_kv = max(min(block_kv, S), 8)
    Sp = ((S + block_q - 1) // block_q) * block_q
    Sp = ((Sp + block_kv - 1) // block_kv) * block_kv

    # (B, H, S, hd) layout: head-major so a (block, hd) tile is contiguous
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if Sp != S:
        pad = ((0, 0), (0, 0), (0, Sp - S), (0, 0))
        qt, kt, vt = (jnp.pad(t, pad) for t in (qt, kt, vt))

    nq = Sp // block_q
    nk = Sp // block_kv
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        seq_len=S, causal=causal, window=window, softcap=softcap,
        num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :S], 1, 2)
