"""Blockwise Δθ quantize / dequantize Pallas kernels (DESIGN.md §6).

The compressed outer collective sends the cross-pod Δθ payload as int8 (or
int4-in-int8, modeling 2x packing) with one fp32 absmax scale per
``block`` contiguous elements:

    scale_b = max|x_b| / qmax          qmax = 2^(bits-1) - 1
    q_b     = clip(round(x_b / scale_b), -qmax, qmax)

Symmetric, zero-point-free: a zero block quantizes to zeros exactly (the
scale is 0 and the inverse is masked), so momentum-free leaves cost nothing
in error. Both kernels stream (rows, block) panels through VMEM — the op is
purely memory-bound, one pass is its roofline. ``block`` should be a
multiple of 128 (lane width) on a real TPU; the interpreter accepts any.

The pure-jnp oracles live in kernels/ref.py; the kernels execute the same
ops elementwise so interpret-mode output matches the oracle bit for bit.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_kernel
from repro.kernels.ref import dequantize_blockwise_ref, quantize_blockwise_ref

_ROWS = 8  # quant blocks (= scale rows) per grid step: fp32 sublane tile


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)  # (R, B)
    absmax = jnp.max(jnp.abs(x), axis=-1)  # (R,)
    # reciprocal-multiply, NOT division: XLA strength-reduces constant
    # divisions under jit but not eagerly, and the oracle must match bitwise
    scale = absmax * (1.0 / qmax)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv[:, None]), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # (R, B)
    o_ref[...] = q * s_ref[...][:, None]


def _pad_rows(nb: int) -> int:
    return ((nb + _ROWS - 1) // _ROWS) * _ROWS


def quantize_blockwise(
    x: jax.Array,  # flattened (N,) — any float dtype
    *,
    bits: int = 8,
    block: int = 256,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (q int8 (nblocks*block,), scales f32 (nblocks,)).

    The payload is padded to whole blocks; callers slice the dequantized
    result back to N. ``interpret=None`` dispatches through the
    KernelBackend registry (compiled/interpreted Pallas or the jnp
    oracle); an explicit bool forces the Pallas body (legacy override).
    """
    impl, interpret = resolve_kernel("quantize", interpret)
    if impl == "jnp":
        return _quantize_jnp(x, bits=bits, block=block)
    return _quantize_pallas(x, bits=bits, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def _quantize_jnp(x, *, bits, block):
    return quantize_blockwise_ref(x, bits=bits, block=block)


@functools.partial(
    jax.jit, static_argnames=("bits", "block", "interpret"))
def _quantize_pallas(x, *, bits, block, interpret):
    qmax = float(2 ** (bits - 1) - 1)
    (n,) = x.shape
    nb = (n + block - 1) // block
    if nb * block != n:
        x = jnp.pad(x, (0, nb * block - n))
    nbp = _pad_rows(nb)
    x2 = x.reshape(nb, block)
    if nbp != nb:
        x2 = jnp.pad(x2, ((0, nbp - nb), (0, 0)))
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(nbp // _ROWS,),
        in_specs=[pl.BlockSpec((_ROWS, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, block), jnp.int8),
            jax.ShapeDtypeStruct((nbp,), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q[:nb].reshape(nb * block), s[:nb]


def dequantize_blockwise(
    q: jax.Array,  # (nblocks*block,) int8
    scales: jax.Array,  # (nblocks,) f32
    *,
    block: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Inverse of :func:`quantize_blockwise`; returns fp32 (nblocks*block,)."""
    impl, interpret = resolve_kernel("dequantize", interpret)
    if impl == "jnp":
        return _dequantize_jnp(q, scales, block=block)
    return _dequantize_pallas(q, scales, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block",))
def _dequantize_jnp(q, scales, *, block):
    return dequantize_blockwise_ref(q, scales, block=block)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _dequantize_pallas(q, scales, *, block, interpret):
    (nq,) = q.shape
    nb = nq // block
    if nb * block != nq:
        raise ValueError(
            f"ragged quantized payload: {nq} values do not fill whole "
            f"blocks of {block} (quantize_blockwise pads to whole blocks; "
            f"pass its output unsliced)")
    nbp = _pad_rows(nb)
    q2 = q.reshape(nb, block)
    s = scales
    if nbp != nb:
        q2 = jnp.pad(q2, ((0, nbp - nb), (0, 0)))
        s = jnp.pad(s, (0, nbp - nb))
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nbp // _ROWS,),
        in_specs=[
            pl.BlockSpec((_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((_ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        interpret=interpret,
    )(q2, s)
    return out[:nb].reshape(nb * block)
