"""True int8 wire format: ring exchange of quantized Δθ (DESIGN.md §8).

The compressed outer collective of §6 models int8 *numerically* but (until
PR 4) exchanged the dequantized fp32 payload — the bytes-on-wire win was
accounting, not reality. This module moves the actual ``(int8 q, fp32
scales)`` pairs across the slow exchange axes and reduces them with
**per-source-scale sum semantics**:

    Δθ_avg = (1/E) · Σ_src dequantize(q_src, s_src)        src = 0 … E−1

The sum runs in canonical source order (the linearized mesh index over the
exchange axes), so every endpoint computes bit-identical results — a hard
requirement: the reduced payload is replicated across groups (shard_map
``out_specs=P()``), and an arrival-order sum would diverge per device.

Three transports, one reduction (all reduced by the shared
:func:`repro.kernels.ref.dequant_sum_sources`, so their numerics are
identical bit for bit):

- **ppermute ring** (CPU / tier-1 reference): a store-and-forward ring —
  E−1 neighbor hops, each carrying the packed wire buffer + scales,
  gathered into canonical source slots. Runs under ``vmap(axis_name=…)``
  (the single-device test harness) and modern-jax shard_map.
- **one-hot psum**: each endpoint deposits its payload at its linearized
  slot of a zero ``(E, ·)`` buffer and psums — exact (one non-zero
  contributor per slot) and the only gather jax 0.4.x partial-manual
  shard_map can lower, so the distributed steps select it there.
- **Pallas remote-DMA** (real TPU): :func:`ring_allgather_wire_tpu`
  forwards the wire buffers around the ring with
  ``pltpu.make_async_remote_copy`` (double-buffered slots, neighbor
  barrier — the guide's ring-collective pattern), then applies the same
  reduction, so the kernel only moves bytes and the numerics stay
  oracle-exact.

Wire layout: int8 values live in their int8 container; ``bits=4`` packs
two's-complement nibbles two-per-byte (:func:`pack_wire` /
:func:`unpack_wire`, exact round-trip), so the measured bytes match the
``bits/8 + 4/block`` model instead of silently shipping int8-wide int4.
:func:`measure_wire_bytes` reads the *actual* device-buffer sizes off a
real quantize+pack run — the measured (not modeled) bytes that
``benchmarks/overlap.py --json`` reports next to the analytic model.

**Reduce-scatter + all-gather wire path (DESIGN.md §14).** The all-reduce
above ships the *full* payload per device ((E−1)·P sent on the gather).
:func:`reduce_scatter_qs` / :func:`allgather_qs` split the payload into E
fixed-size per-endpoint slots (``wire_shard_blocks`` quant blocks each,
zero-padded tail, per-slot nibble packing) and move only shard-sized
buffers: endpoint e reduces slot e of all sources via the same
:func:`dequant_sum_sources` oracle, re-quantizes its reduced shard with a
second error-feedback residual, and all-gathers the (q2, s2) pair —
2·(E−1)·P/E sent per device (0.5× the all-reduce wire path at E=4).
Reconstruction is per-slot dequant + concat (:func:`dequant_concat_sources`
— no summation, bit-identical on every endpoint). The same three
transports serve both legs; the scatter leg adds
:func:`ring_scatter_wire` (stride-k ppermute, true (E−1)/E traffic),
:func:`onehot_scatter_wire` (psum correctness lane), and
:func:`shard_scatter_wire_tpu` (remote-DMA with a full entry barrier).
"""

from __future__ import annotations

import functools
import itertools
from typing import Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import COMPILED, kernel_lane, on_tpu
from repro.kernels.ref import (dequant_concat_sources,  # noqa: F401
                               dequant_sum_sources, pack_wire,
                               shard_slot_wire, unpack_wire,
                               wire_shard_blocks)

# jax < 0.5 names this TPUCompilerParams; it was renamed to CompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

# Per-pallas_call wire slice on the TPU path. All refs live in VMEM
# (Mosaic cannot index ANY-space refs directly), so one call holds
# (E + 3) × chunk bytes there: E canonical output slots + the input +
# two comm slots. 512 KiB keeps that under ~10 MiB up to E = 16.
_WIRE_CHUNK_BYTES = 1 << 19


# The wire packing (pack_wire/unpack_wire) and THE reduction
# (dequant_sum_sources — canonical-order per-source-scale sum) live in
# kernels/ref.py so the oracle, the simulator, and this transport all run
# the *identical* subgraph; this module re-exports them and owns only the
# transports (how the stacked sources are produced).

# ---------------------------------------------------------------------------
# reference transports (CPU / tier-1 / non-TPU)
# ---------------------------------------------------------------------------


def _check_axis_sizes(names, axis_sizes):
    for ax in names:
        if ax not in (axis_sizes or {}):
            raise ValueError(
                f"exchange axis {ax!r} missing from ReduceCtx.axis_sizes "
                f"(have {sorted(axis_sizes or {})}); the wire exchange "
                f"needs static ring sizes")


def _axis_idx(axis_name: str, axis_coords) -> jax.Array:
    """The caller's coordinate along one exchange axis.

    Prefer data-threaded coordinates (``ReduceCtx.axis_coords`` — an
    ``arange`` sharded over the axis, sliced per shard): jax 0.4.x lowers
    ``lax.axis_index`` inside partial-manual shard_map to a PartitionId
    instruction its SPMD partitioner rejects. Fall back to
    ``lax.axis_index`` (vmap harnesses, modern jax) when no coordinate
    was threaded.
    """
    if axis_coords and axis_name in axis_coords:
        return jnp.asarray(axis_coords[axis_name], jnp.int32)
    return jax.lax.axis_index(axis_name)


def _ring_gather(x: jax.Array, axis_name: str, size: int, idx) -> jax.Array:
    """All-gather ``x`` into canonical axis-index slots via E−1 ring hops.

    Each hop forwards the buffer to the right neighbor (``ppermute`` —
    on the wire this is exactly one payload per link per step); after hop
    ``k`` a device holds source ``(idx − k − 1) mod E``. Works inside
    modern-jax ``shard_map`` and under ``vmap(axis_name=...)`` (the
    single-device test harness); jax 0.4.x partial-manual shard_map
    cannot lower ppermute (XLA CHECK) — the distributed steps use
    :func:`onehot_gather_wire` there instead.
    """
    out = jnp.zeros((size, *x.shape), x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, x, idx, 0)
    buf = x
    perm = [(i, (i + 1) % size) for i in range(size)]
    for k in range(size - 1):
        buf = jax.lax.ppermute(buf, axis_name, perm)
        src = (idx - k - 1) % size
        out = jax.lax.dynamic_update_index_in_dim(out, buf, src, 0)
    return out


def ring_gather_wire(w: jax.Array, s: jax.Array,
                     axis_names: Sequence[str],
                     axis_sizes: Mapping[str, int],
                     axis_coords=None) -> Tuple[jax.Array, jax.Array]:
    """ppermute transport: gather every source's (wire bytes, scales).

    Multiple exchange axes compose as nested rings (right-to-left), so the
    flattened leading axis is row-major over ``axis_names`` — the same
    linearization the (G,)-stacked simulator uses for its group index.
    Returns ``((E, nw) wire, (E, nb) scales)`` with E = Π sizes.
    """
    names = tuple(axis_names)
    _check_axis_sizes(names, axis_sizes)
    wg, sg = w[None], s[None]
    for ax in reversed(names):
        idx = _axis_idx(ax, axis_coords)
        wg = _ring_gather(wg, ax, axis_sizes[ax], idx)
        sg = _ring_gather(sg, ax, axis_sizes[ax], idx)
    return (wg.reshape(-1, w.shape[0]), sg.reshape(-1, s.shape[0]))


def _linear_exchange_idx(axis_names, axis_sizes, axis_coords):
    """(E, linearized row-major index) over the exchange axes."""
    E, idx = 1, jnp.int32(0)
    for ax in axis_names:
        E *= int(axis_sizes[ax])
        idx = idx * int(axis_sizes[ax]) + _axis_idx(ax, axis_coords)
    return E, idx


def _ring_scatter(slots: jax.Array, axis_name: str, size: int,
                  idx) -> jax.Array:
    """Direct shard exchange: (E, ·) per-slot buffers -> (E, ·) stack of
    *my* slot as held by every source, in canonical source order.

    At offset ``k`` every device sends slot ``(idx + k) % E`` straight to
    its owner (``ppermute`` with the stride-k permutation — one slot per
    link per step), so the receiver at distance k deposits the arriving
    buffer — the sender's copy of *the receiver's* slot — into the
    sender's canonical row. Per-device traffic over E−1 offsets is
    ``(E−1)/E`` of the payload: the reduce-scatter byte win, not a
    gather of everything.
    """
    out = jnp.zeros((size, *slots.shape[1:]), slots.dtype)
    own = jax.lax.dynamic_index_in_dim(slots, idx, 0, keepdims=False)
    out = jax.lax.dynamic_update_index_in_dim(out, own, idx, 0)
    for k in range(1, size):
        perm = [(i, (i + k) % size) for i in range(size)]
        buf = jax.lax.dynamic_index_in_dim(slots, (idx + k) % size, 0,
                                           keepdims=False)
        buf = jax.lax.ppermute(buf, axis_name, perm)
        out = jax.lax.dynamic_update_index_in_dim(out, buf,
                                                  (idx - k) % size, 0)
    return out


def ring_scatter_wire(w_slots: jax.Array, s_slots: jax.Array,
                      axis_names: Sequence[str],
                      axis_sizes: Mapping[str, int],
                      axis_coords=None) -> Tuple[jax.Array, jax.Array]:
    """ppermute reduce-scatter transport: my shard slot from every source.

    ``w_slots``/``s_slots``: (E, ·) per-slot packed wire buffers
    (:func:`repro.kernels.ref.shard_slot_wire`). A single exchange axis
    runs the direct stride-k shard exchange ((E−1)/E·payload per
    device); composed axes fall back to the nested-ring full gather +
    slice (correct, but gather-sized traffic — the multi-axis rs case
    has no single ring to stride over).
    """
    names = tuple(axis_names)
    _check_axis_sizes(names, axis_sizes)
    E, idx = _linear_exchange_idx(names, axis_sizes, axis_coords)
    if len(names) == 1:
        wg = _ring_scatter(w_slots, names[0], E, idx)
        sg = _ring_scatter(s_slots, names[0], E, idx)
        return wg, sg
    wg_all, sg_all = ring_gather_wire(
        w_slots.reshape(-1), s_slots.reshape(-1), names, axis_sizes,
        axis_coords)
    wg = jax.lax.dynamic_index_in_dim(
        wg_all.reshape(E, *w_slots.shape), idx, 1, keepdims=False)
    sg = jax.lax.dynamic_index_in_dim(
        sg_all.reshape(E, *s_slots.shape), idx, 1, keepdims=False)
    return wg, sg


def onehot_scatter_wire(w_slots: jax.Array, s_slots: jax.Array,
                        axis_names: Sequence[str],
                        axis_sizes: Mapping[str, int],
                        axis_coords=None) -> Tuple[jax.Array, jax.Array]:
    """psum reduce-scatter transport (jax 0.4.x partial-manual fallback).

    Deposits the per-slot stack at the canonical source row of a zero
    (E, E, ·) cube and psums — every endpoint then slices the column of
    its own slot index. Exact (one contributor per cell) and lowerable
    where ppermute CHECK-fails; the byte win of a true reduce-scatter
    lives in the ring/dma transports — this is the correctness lane.
    """
    names = tuple(axis_names)
    _check_axis_sizes(names, axis_sizes)
    E, idx = _linear_exchange_idx(names, axis_sizes, axis_coords)

    def scatter(slots):
        buf = jnp.zeros((E, *slots.shape), slots.dtype)
        buf = jax.lax.dynamic_update_index_in_dim(buf, slots, idx, 0)
        cube = jax.lax.psum(buf, names)  # (E_src, E_slot, ·)
        return jax.lax.dynamic_index_in_dim(cube, idx, 1, keepdims=False)

    return scatter(w_slots), scatter(s_slots)


def onehot_gather_wire(w: jax.Array, s: jax.Array,
                       axis_names: Sequence[str],
                       axis_sizes: Mapping[str, int],
                       axis_coords=None) -> Tuple[jax.Array, jax.Array]:
    """psum transport: scatter into the canonical slot, sum the slots.

    Every endpoint deposits its payload at its linearized index of an
    all-zero ``(E, ...)`` buffer and psums over the exchange axes — each
    slot has exactly one non-zero contributor, so the gather is exact for
    the int values and the (non-negative) fp32 scales in any reduction
    order. This is the transport jax 0.4.x partial-manual shard_map can
    actually lower (psum works where ppermute CHECK-fails); the wire
    realism lives in the TPU remote-DMA path either way.
    """
    names = tuple(axis_names)
    _check_axis_sizes(names, axis_sizes)
    E, idx = 1, jnp.int32(0)
    for ax in names:
        E *= int(axis_sizes[ax])
        idx = idx * int(axis_sizes[ax]) + _axis_idx(ax, axis_coords)

    def gather(x):
        buf = jnp.zeros((E, *x.shape), x.dtype)
        buf = jax.lax.dynamic_update_index_in_dim(buf, x, idx, 0)
        return jax.lax.psum(buf, names)

    return gather(w), gather(s)


# ---------------------------------------------------------------------------
# Pallas remote-DMA transport (real TPU rings only)
# ---------------------------------------------------------------------------

# Barrier-semaphore ids for the DMA rings, unique among concurrently-live
# collectives in a traced program (ids are assigned at trace time; the
# modulus keeps them inside Mosaic's small-id space — a collision needs
# ~1024 in-flight collectives, far beyond any real leaf count).
_collective_ids = itertools.count()


def _next_collective_id() -> int:
    return next(_collective_ids) % 1024


def _ring_allgather_kernel(x_ref, out_ref, comm_buf, send_sem, recv_sem, *,
                           num_devices: int, axis_name: str):
    """Store-and-forward ring all-gather of one buffer (guide pattern).

    Every device forwards the slot it just received to its right neighbor;
    after E−1 hops ``out_ref`` holds all sources in canonical slots. The
    neighbor barrier keeps a fast device from issuing into a slot its
    neighbor has not drained yet.
    """
    my = jax.lax.axis_index(axis_name)
    left = jax.lax.rem(my + num_devices - 1, num_devices)
    right = jax.lax.rem(my + 1, num_devices)

    out_ref[my] = x_ref[...]
    comm_buf[0] = x_ref[...]

    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=left)
    pltpu.semaphore_signal(barrier, inc=1, device_id=right)
    pltpu.semaphore_wait(barrier, 2)

    for step in range(num_devices - 1):
        slot = step % 2
        nxt = (step + 1) % 2
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[slot],
            dst_ref=comm_buf.at[nxt],
            send_sem=send_sem.at[slot],
            recv_sem=recv_sem.at[nxt],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        src = jax.lax.rem(my + num_devices - step - 1, num_devices)
        out_ref[src] = comm_buf[nxt]


def _ring_allgather_tpu_1d(x: jax.Array, axis_name: str,
                           size: int, collective_id: int) -> jax.Array:
    """(n,) buffer -> (size, n) canonical gather over one mesh axis."""
    (n,) = x.shape
    return pl.pallas_call(
        functools.partial(_ring_allgather_kernel, num_devices=size,
                          axis_name=axis_name),
        out_shape=jax.ShapeDtypeStruct((size, n), x.dtype),
        # whole-array VMEM refs: Mosaic can index these directly, unlike
        # ANY-space refs; _WIRE_CHUNK_BYTES bounds the footprint
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2, n), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=_CompilerParams(collective_id=collective_id),
    )(x)


def ring_allgather_wire_tpu(w: jax.Array, s: jax.Array, axis_name: str,
                            size: int) -> Tuple[jax.Array, jax.Array]:
    """TPU remote-DMA transport: gather wire bytes + scales ring-wise.

    The wire buffer is sliced into ≤ ``_WIRE_CHUNK_BYTES`` panels so the
    double-buffered comm slots fit VMEM regardless of leaf size; scales
    ride as one (small) extra panel. The reduction itself stays in
    :func:`dequant_sum_sources` — this function only moves bytes.
    """
    (nw,) = w.shape
    chunk = max(_WIRE_CHUNK_BYTES // max(w.dtype.itemsize, 1), 1)
    parts = []
    # distinct collective_id per pallas_call, allocated process-wide (not
    # per ring_allgather_wire_tpu call): chunk rings of one leaf AND the
    # rings of different leaves in one outer computation are all
    # data-independent, and any two concurrently-scheduled collectives
    # sharing an id would alias one barrier semaphore and desynchronize
    for lo in range(0, nw, chunk):
        parts.append(_ring_allgather_tpu_1d(
            w[lo:lo + chunk], axis_name, size,
            collective_id=_next_collective_id()))
    wg = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    sg = _ring_allgather_tpu_1d(s, axis_name, size,
                                collective_id=_next_collective_id())
    return wg, sg


def _shard_scatter_kernel(slots_ref, out_ref, send_buf, recv_buf,
                          send_sem, recv_sem, *, num_devices: int,
                          axis_name: str):
    """Remote-DMA shard exchange: slot ``e`` of every device -> device e.

    At offset ``k`` every device stages its slot ``(my + k) % E`` and
    DMAs it straight to the owner (the stride-k permutation of the ring
    — still a permutation, so the SPMD ``rdma.wait()`` semantics of the
    guide's ring pattern hold: the matching incoming descriptor uses the
    same step-parity semaphore slots on every device). Per-device bytes
    over the E−1 offsets are (E−1)/E of the payload — the reduce-scatter
    win on the real fabric. The opening barrier is *global* (unlike the
    neighbor barrier of the all-gather kernel): sends target arbitrary
    ring distances, so every peer must be inside the kernel before the
    first copy is issued.
    """
    my = jax.lax.axis_index(axis_name)

    own = pl.load(slots_ref, (pl.ds(my, 1), slice(None)))
    pl.store(out_ref, (pl.ds(my, 1), slice(None)), own)

    barrier = pltpu.get_barrier_semaphore()
    for off in range(1, num_devices):
        pltpu.semaphore_signal(
            barrier, inc=1,
            device_id=jax.lax.rem(my + off, num_devices))
    pltpu.semaphore_wait(barrier, num_devices - 1)

    for k in range(1, num_devices):
        dst = jax.lax.rem(my + k, num_devices)
        src = jax.lax.rem(my + num_devices - k, num_devices)
        slot = (k - 1) % 2
        send_buf[slot] = pl.load(slots_ref,
                                 (pl.ds(dst, 1), slice(None)))[0]
        rdma = pltpu.make_async_remote_copy(
            src_ref=send_buf.at[slot],
            dst_ref=recv_buf.at[slot],
            send_sem=send_sem.at[slot],
            recv_sem=recv_sem.at[slot],
            device_id=(dst,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        pl.store(out_ref, (pl.ds(src, 1), slice(None)),
                 recv_buf[slot][None])


def _shard_scatter_tpu_1d(slots: jax.Array, axis_name: str, size: int,
                          collective_id: int) -> jax.Array:
    """(E, n) per-slot buffers -> (E, n) canonical stack of my slot."""
    _, n = slots.shape
    return pl.pallas_call(
        functools.partial(_shard_scatter_kernel, num_devices=size,
                          axis_name=axis_name),
        out_shape=jax.ShapeDtypeStruct((size, n), slots.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2, n), slots.dtype),
            pltpu.VMEM((2, n), slots.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=_CompilerParams(collective_id=collective_id),
    )(slots)


def shard_scatter_wire_tpu(w_slots: jax.Array, s_slots: jax.Array,
                           axis_name: str,
                           size: int) -> Tuple[jax.Array, jax.Array]:
    """TPU remote-DMA reduce-scatter transport (chunked like the ring).

    Slot-sized panels are sliced to ≤ ``_WIRE_CHUNK_BYTES`` so the
    staging buffers fit VMEM; scales ride as one extra panel. The
    reduction stays in :func:`dequant_sum_sources` — bytes only here.
    """
    nw = w_slots.shape[1]
    chunk = max(_WIRE_CHUNK_BYTES // max(w_slots.dtype.itemsize, 1), 1)
    parts = []
    for lo in range(0, nw, chunk):
        parts.append(_shard_scatter_tpu_1d(
            w_slots[:, lo:lo + chunk], axis_name, size,
            collective_id=_next_collective_id()))
    wg = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    sg = _shard_scatter_tpu_1d(s_slots, axis_name, size,
                               collective_id=_next_collective_id())
    return wg, sg


# ---------------------------------------------------------------------------
# public entry: quantized ring all-reduce
# ---------------------------------------------------------------------------


def resolve_transport(*, axis_names: Sequence[str],
                      use_pallas: bool = True) -> str:
    """Backend-aware wire-transport resolution (the ``"auto"`` rule).

    The remote-DMA ring is TPU-only twice over: the resolved kernel
    backend must serve ``ring_allreduce`` compiled (its capability-table
    lane — interpret/jnp-ref force the collective transports even on TPU
    hardware) AND the process must actually run on TPU devices (a forced
    ``tpu-mosaic`` backend on CPU still falls back) — and it only
    composes over a single exchange axis. Everything else resolves to the
    collective transports: the ppermute ring where shard_map can lower it
    (modern jax), one-hot psum on jax 0.4.x partial-manual shard_map.

    ``use_pallas=True`` (the default) answers "best transport this
    backend could use"; strategies pass their actual ``ReduceCtx``
    setting at dispatch time.
    """
    from repro import compat

    names = tuple(axis_names)
    if (use_pallas and len(names) == 1 and on_tpu()
            and kernel_lane("ring_allreduce") == COMPILED):
        return "dma"
    return "ring" if compat.HAS_NEW_SHARD_MAP else "psum"


def ring_allreduce_quantized(q: jax.Array, s: jax.Array, *,
                             axis_names: Sequence[str],
                             axis_sizes: Mapping[str, int],
                             bits: int, block: int,
                             use_pallas: bool = False,
                             axis_coords=None,
                             transport: str = "auto",
                             weights=None) -> jax.Array:
    """All-reduce the actual (q, scales) pairs over the exchange axes.

    ``q``: (nb·block,) int8 values, ``s``: (nb,) fp32 scales — one
    endpoint's quantized payload. Returns the fp32 (nb·block,) mean of all
    endpoints' dequantized payloads, accumulated in canonical source order
    (bit-identical on every endpoint, whichever transport produced the
    source stack). Must run inside ``shard_map`` (or
    ``vmap(axis_name=...)``) spanning ``axis_names``.

    ``weights``: optional (E,) fp32 participation weights in the same
    canonical source order as the gathered stack (row-major over
    ``axis_names``); forwarded to :func:`dequant_sum_sources` for the
    elastic-membership weighted mean (DESIGN.md §11). Every endpoint must
    pass the identical vector — it is replicated, not per-shard.

    ``transport``: ``"dma"`` (Pallas remote-DMA ring, real TPU only),
    ``"ring"`` (ppermute hops), ``"psum"`` (one-hot scatter + psum), or
    ``"auto"`` — resolved backend-aware by :func:`resolve_transport`.
    """
    names = tuple(axis_names)
    w = pack_wire(q, bits)
    if transport == "auto":
        transport = resolve_transport(axis_names=names,
                                      use_pallas=use_pallas)
    if transport == "dma":
        _check_axis_sizes(names[:1], axis_sizes)
        wg, sg = ring_allgather_wire_tpu(
            w, s, names[0], axis_sizes[names[0]])
    elif transport == "ring":
        wg, sg = ring_gather_wire(w, s, names, axis_sizes, axis_coords)
    elif transport == "psum":
        wg, sg = onehot_gather_wire(w, s, names, axis_sizes, axis_coords)
    else:
        raise ValueError(f"unknown wire transport {transport!r}")
    return dequant_sum_sources(wg, sg, bits=bits, block=block,
                               weights=weights)


def reduce_scatter_qs(q: jax.Array, s: jax.Array, *,
                      axis_names: Sequence[str],
                      axis_sizes: Mapping[str, int],
                      bits: int, block: int,
                      use_pallas: bool = False,
                      axis_coords=None,
                      transport: str = "auto",
                      weights=None) -> jax.Array:
    """Quantized reduce-scatter: each endpoint gets its reduced 1/E shard.

    ``q``: (nb·block,) int8 values, ``s``: (nb,) fp32 scales — one
    endpoint's quantized payload. The payload is split into E fixed-size
    slots of ``wire_shard_blocks(nb, E)`` quant blocks (zero-padded at the
    tail; zero blocks quantize to zero scales and dequantize to exact
    zeros, so padding is bit-transparent), each slot packed independently
    so int4 nibbles never straddle slot boundaries. Endpoint ``e``
    receives slot ``e`` of every source and reduces through the shared
    :func:`dequant_sum_sources` oracle — returning the fp32
    (sb·block,) mean of its own shard, bit-identical to rows of
    :func:`repro.kernels.ref.reduce_scatter_qs_ref`.

    Per-device wire traffic on the ring/dma transports is
    (E−1)/E·payload — the reduce-scatter win. The psum transport is the
    jax 0.4.x partial-manual correctness lane (gather-sized traffic).
    """
    names = tuple(axis_names)
    E = 1
    for ax in names:
        E *= int(axis_sizes[ax])
    w_slots, s_slots = shard_slot_wire(q, s, bits=bits, block=block,
                                       endpoints=E)
    if transport == "auto":
        transport = resolve_transport(axis_names=names,
                                      use_pallas=use_pallas)
    if transport == "dma":
        _check_axis_sizes(names[:1], axis_sizes)
        wg, sg = shard_scatter_wire_tpu(
            w_slots, s_slots, names[0], axis_sizes[names[0]])
    elif transport == "ring":
        wg, sg = ring_scatter_wire(w_slots, s_slots, names, axis_sizes,
                                   axis_coords)
    elif transport == "psum":
        wg, sg = onehot_scatter_wire(w_slots, s_slots, names, axis_sizes,
                                     axis_coords)
    else:
        raise ValueError(f"unknown wire transport {transport!r}")
    return dequant_sum_sources(wg, sg, bits=bits, block=block,
                               weights=weights)


def allgather_qs(q2: jax.Array, s2: jax.Array, *,
                 axis_names: Sequence[str],
                 axis_sizes: Mapping[str, int],
                 bits: int, block: int,
                 use_pallas: bool = False,
                 axis_coords=None,
                 transport: str = "auto") -> jax.Array:
    """Quantized all-gather: reconstruct the full payload from shards.

    ``q2``: (sb·block,) int8 re-quantized reduced shard, ``s2``: (sb,)
    fp32 scales — endpoint ``e`` holds shard ``e``. Ships the packed
    (w2, s2) pair over the same three transports as the all-reduce wire
    path and concatenates per-slot dequantizations in canonical source
    order via :func:`dequant_concat_sources` — every endpoint
    reconstructs the identical (E·sb·block,) fp32 payload (concatenation,
    not summation: no FMA-order hazard, bit-identical everywhere).
    """
    names = tuple(axis_names)
    w2 = pack_wire(q2, bits)
    if transport == "auto":
        transport = resolve_transport(axis_names=names,
                                      use_pallas=use_pallas)
    if transport == "dma":
        _check_axis_sizes(names[:1], axis_sizes)
        wg, sg = ring_allgather_wire_tpu(
            w2, s2, names[0], axis_sizes[names[0]])
    elif transport == "ring":
        wg, sg = ring_gather_wire(w2, s2, names, axis_sizes, axis_coords)
    elif transport == "psum":
        wg, sg = onehot_gather_wire(w2, s2, names, axis_sizes, axis_coords)
    else:
        raise ValueError(f"unknown wire transport {transport!r}")
    return dequant_concat_sources(wg, sg, bits=bits, block=block)


# ---------------------------------------------------------------------------
# measured bytes-on-wire (benchmarks/overlap.py --json)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _measure_wire_sample(sample: int, bits: int, block: int):
    """(value_bytes, scale_bytes) of a real quantize+pack of ``sample``
    elements — cached: the sweep and the sync_delay='auto' startup path
    ask for the same (sample, bits, block) repeatedly, and the underlying
    jax work is identical each time."""
    from repro.kernels.ref import quantize_blockwise_ref

    x = jnp.zeros((sample,), jnp.float32)
    if bits >= 32:
        return int(x.nbytes), 0  # fp32 ships uncompressed, no scales
    q, s = quantize_blockwise_ref(x, bits=bits, block=block)
    return int(pack_wire(q, bits).nbytes), int(s.nbytes)


def measure_wire_bytes(n: int, *, bits: int = 8, block: int = 256,
                       sample_cap: int = 1 << 22) -> dict:
    """Measured wire bytes for an n-element payload: run the real
    quantizer + packer and read ``.nbytes`` off the actual buffers.

    Payloads above ``sample_cap`` elements are measured on a cap-sized
    sample and scaled (the per-element layout — block padding, scale rows,
    nibble packing — is what measurement captures; it is size-invariant
    beyond one block row). Returns per-payload totals and the measured
    bytes-per-element, for comparison against the ``bits/8 + 4/block``
    model.
    """
    sample = int(min(n, sample_cap))
    value_bytes, scale_bytes = _measure_wire_sample(sample, bits, block)
    per_elem = (value_bytes + scale_bytes) / max(sample, 1)
    total = per_elem * n
    return {
        "measured_sample_elems": sample,
        "measured_value_bytes": value_bytes,
        "measured_scale_bytes": scale_bytes,
        "measured_payload_bytes_per_param": per_elem,
        "measured_payload_bytes": total,
    }


def measured_cross_domain_bytes(n: int, *, endpoints: int, bits: int = 8,
                                block: int = 256) -> float:
    """Measured total bytes crossing the slow domain per sync, using the
    same ring-traffic convention as the analytic model (2·P·(E−1)) but
    with the *measured* per-payload bytes."""
    per = measure_wire_bytes(n, bits=bits, block=block)
    return 2.0 * per["measured_payload_bytes"] * (max(endpoints, 1) - 1)


@functools.lru_cache(maxsize=32)
def _measure_slot_sample(sample: int, endpoints: int, bits: int,
                         block: int):
    """(slot_value_bytes, slot_scale_bytes) of one real rs/ag slot for a
    ``sample``-element payload: run the actual quantize + per-slot pack
    and read ``.nbytes`` off the slot buffers (captures block padding,
    slot zero-padding, and per-slot nibble packing exactly)."""
    from repro.kernels.ref import quantize_blockwise_ref

    x = jnp.zeros((sample,), jnp.float32)
    q, s = quantize_blockwise_ref(x, bits=bits, block=block)
    w_slots, s_slots = shard_slot_wire(q, s, bits=bits, block=block,
                                      endpoints=endpoints)
    return int(w_slots[0].nbytes), int(s_slots[0].nbytes)


def measured_rs_ag_bytes(n: int, *, endpoints: int, bits: int = 8,
                         block: int = 256,
                         sample_cap: int = 1 << 22) -> dict:
    """Measured per-device wire bytes for the rs/ag exchange.

    Convention: bytes *sent* per device per sync. Each device sends
    (E−1) quantized payload slots on the reduce-scatter leg and its one
    re-quantized (q2, s2) slot to (E−1) peers on the all-gather leg —
    2·(E−1)·slot_bytes total, vs (E−1)·payload_bytes for the
    gather-based all-reduce wire path (ratio 2/E: 0.5× at E=4). Slot
    sizes come from real buffers (see :func:`_measure_slot_sample`);
    payloads above ``sample_cap`` are measured on a sample and scaled.
    """
    E = max(int(endpoints), 1)
    sample = int(min(n, sample_cap))
    value_bytes, scale_bytes = _measure_slot_sample(sample, E, bits, block)
    scale = n / max(sample, 1)
    slot_bytes = (value_bytes + scale_bytes) * scale
    per_leg = (E - 1) * slot_bytes
    return {
        "measured_slot_bytes": slot_bytes,
        "measured_rs_bytes_per_device": per_leg,
        "measured_ag_bytes_per_device": per_leg,
        "measured_rs_ag_bytes_per_device": 2.0 * per_leg,
        "measured_rs_ag_bytes_total": 2.0 * per_leg * E,
    }
