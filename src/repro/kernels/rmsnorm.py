"""RMSNorm Pallas TPU kernel: fused mean-of-squares + scale in one VMEM pass.

Row-blocked: each grid step normalizes a (block_rows, D) panel. The reduction
runs in fp32 VREGs; the output is cast back to the input dtype. Replaces the
three-op XLA pattern (square-reduce / rsqrt-broadcast / multiply) that makes
two HBM round trips over the activation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_kernel
from repro.kernels.ref import rmsnorm_ref


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (
        x * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,  # (..., D)
    scale: jax.Array,  # (D,)
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused RMSNorm. ``interpret=None`` dispatches through the
    KernelBackend registry (the body is plain-BlockSpec, so it compiles on
    both tpu-mosaic and gpu-triton); an explicit bool forces the Pallas
    body (legacy override)."""
    impl, interpret = resolve_kernel("rmsnorm", interpret)
    if impl == "jnp":
        return _rmsnorm_jnp(x, scale, eps=eps)
    return _rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps",))
def _rmsnorm_jnp(x, scale, *, eps):
    return rmsnorm_ref(x, scale, eps=eps)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def _rmsnorm_pallas(x, scale, *, eps, block_rows, interpret):
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    block_rows = max(min(block_rows, rows), 1)
    rp = ((rows + block_rows - 1) // block_rows) * block_rows
    if rp != rows:
        x2 = jnp.pad(x2, ((0, rp - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
