"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *the* reference semantics; the model's default CPU path uses them
directly, and every kernel test sweeps shapes/dtypes asserting the Pallas
(interpret=True) output matches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0
):
    """GQA attention oracle. q: (B,S,H,hd); k/v: (B,S,Hkv,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= pos[:, None] - pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def pier_update_ref(anchor, momentum, delta, *, mu, lr, formulation="nesterov_torch"):
    """Fused outer-update oracle (Alg. 2 lines 20-21), fp32 math.

    Returns (new_params, new_momentum).
    """
    mf = momentum.astype(jnp.float32)
    af = anchor.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    m_new = mu * mf + df
    if formulation == "nesterov_torch":
        step = mu * m_new + df
    elif formulation == "nesterov_classic":
        step = mu * mf + df
    elif formulation == "sgd":
        step = m_new
    else:
        raise ValueError(formulation)
    return af + lr * step, m_new


def aligned_block_count(n: int, block: int, align: int = 1) -> int:
    """Blocks covering ``n`` elems, rounded up to a multiple of ``align``.

    The sharded outer exchange (DESIGN.md §10) pads flat payloads to
    ``aligned_block_count(n, block, shards) * block`` so each auto-axis
    shard holds whole quantization blocks: blockwise absmax then computes
    shard-locally under a sharding constraint, with no cross-shard block
    straddling. ``align=1`` is the quantizer's own ceil(n / block).
    """
    if block < 1 or align < 1:
        raise ValueError(f"block={block}, align={align} must be >= 1")
    nb = (n + block - 1) // block
    return ((nb + align - 1) // align) * align


def quantize_blockwise_ref(x, *, bits: int = 8, block: int = 256):
    """Blockwise symmetric absmax quantization oracle (DESIGN.md §6).

    x: flat (N,) float -> (q int8 (nblocks*block,), scales f32 (nblocks,)).
    The payload is padded to whole blocks (zero pad -> zero scale/values).
    """
    qmax = float(2 ** (bits - 1) - 1)
    (n,) = x.shape
    nb = (n + block - 1) // block
    xf = jnp.pad(x.astype(jnp.float32), (0, nb * block - n))
    xb = xf.reshape(nb, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    # reciprocal-multiply to match the kernel bitwise under jit (XLA
    # strength-reduces constant divisions)
    scale = absmax * (1.0 / qmax)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(xb * inv[:, None]), -qmax, qmax)
    return q.astype(jnp.int8).reshape(nb * block), scale


def dequantize_blockwise_ref(q, scales, *, block: int = 256):
    """Inverse oracle: (nblocks*block,) int8 + (nblocks,) f32 -> f32."""
    nb = q.shape[0] // block
    if nb * block != q.shape[0]:
        raise ValueError(
            f"ragged quantized payload: {q.shape[0]} values do not fill "
            f"whole blocks of {block}")
    qb = q.reshape(nb, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(nb * block)


def pack_wire(q, bits: int):
    """int8 values -> the byte stream that actually crosses the wire.

    ``bits >= 8`` is the identity (int8 is its own wire container);
    ``bits=4`` packs two's-complement nibbles two-per-byte (odd lengths
    zero-padded). Exact round-trip with :func:`unpack_wire`.
    """
    if bits >= 8:
        return q
    (nq,) = q.shape
    if nq % 2:
        q = jnp.pad(q, (0, 1))
    u = q.astype(jnp.uint8) & 0xF
    return (u[0::2] | (u[1::2] << 4)).astype(jnp.uint8)


def unpack_wire(w, bits: int, nq: int):
    """Inverse of :func:`pack_wire`: wire bytes -> (nq,) int8 values."""
    if bits >= 8:
        return w
    lo = (w & 0xF).astype(jnp.int8)
    hi = ((w >> 4) & 0xF).astype(jnp.int8)
    q = jnp.stack([lo, hi], axis=-1).reshape(-1)[:nq]
    # sign-extend the nibble: int8 shifts are arithmetic
    return (q << 4) >> 4


def dequant_sum_sources(wg, sg, *, bits: int, block: int, weights=None):
    """(E, nw) wire bytes + (E, nb) scales -> fp32 (nq,) payload mean.

    THE per-source-scale sum (DESIGN.md §8): dequantize each source's
    wire payload and accumulate in canonical source order (row 0 first),
    then multiply by ``1/E``. This one function IS the reduction — the
    distributed ring (kernels/ring_allreduce.py), the simulator's
    ``Int8Wire.sim_reduce``, and the test oracle all call it, however
    their source stacks were produced (remote-DMA gather, ppermute ring,
    ``jnp.stack``).

    ``weights``: optional (E,) f32 participation weights for elastic
    membership (DESIGN.md §11) — an absent source carries weight 0 and
    the normalization becomes ``1/Σw`` instead of ``1/E`` (all-zero
    weights yield 0, not NaN: the caller decides whether an empty round
    is legal). The weighted path is bit-identical to the unweighted one
    at all-ones weights: scaling each payload by ``w_j == 1.0`` *before*
    the loop is IEEE-exact, and the traced ``1.0/Σw`` division at
    ``Σw == E`` strength-reduces to the same reciprocal multiply as the
    ``1/E`` constant below.

    The accumulation deliberately materializes the dequantized partials
    and adds them inside a ``fori_loop``: an unrolled ``acc + q*s`` chain
    gets FMA-contracted by XLA differently depending on the surrounding
    producers (even across an ``optimization_barrier``), which breaks the
    bit-identity between transports at 1 ulp. A loop body only ever sees
    a dynamic slice of the materialized stack — there is no multiply for
    the add to contract with, so every path rounds identically (cf. the
    reciprocal-multiply note on :func:`quantize_blockwise_ref`). The
    per-source weights multiply the materialized stack *outside* the
    loop for the same reason.
    """
    E, nb = sg.shape
    nq = nb * block
    payloads = jnp.stack([
        dequantize_blockwise_ref(unpack_wire(wg[j], bits, nq), sg[j],
                                 block=block)
        for j in range(E)])
    if weights is not None:
        w = jnp.asarray(weights, jnp.float32).reshape(E)
        payloads = payloads * w[:, None]

    def body(j, acc):
        return acc + jax.lax.dynamic_index_in_dim(
            payloads, j, 0, keepdims=False)

    # start from a zero accumulator (0 + x is exact) so even E == 2 keeps
    # a trip count > 1 — XLA unrolls single-trip loops, which would hand
    # the add back to the fuser
    acc = jax.lax.fori_loop(0, E, body, jnp.zeros_like(payloads[0]))
    if weights is None:
        return acc * jnp.float32(1.0 / E)
    sw = jnp.sum(w)
    inv = jnp.where(sw > 0, jnp.float32(1.0) / sw, jnp.float32(0.0))
    return acc * inv


def ring_allreduce_qs_ref(q, scales, *, block: int = 256, bits: int = 8,
                          weights=None):
    """Per-source-scale sum oracle of the int8 wire ring (DESIGN.md §8).

    ``q``: (E, nblocks*block) int8 values, ``scales``: (E, nblocks) f32 —
    one row per ring endpoint. Round-trips each row through the actual
    wire packing (a bit-exact identity on the values) and reduces with
    :func:`dequant_sum_sources` — exactly what the distributed ring
    exchange computes on every endpoint, bit for bit. ``weights``
    forwards the elastic-membership mask (see there).
    """
    E = q.shape[0]
    wg = jnp.stack([pack_wire(q[j], bits) for j in range(E)])
    return dequant_sum_sources(wg, scales, bits=bits, block=block,
                               weights=weights)


# ---------------------------------------------------------------------------
# quantized reduce-scatter + all-gather (DESIGN.md §14)
# ---------------------------------------------------------------------------


def wire_shard_blocks(nb: int, endpoints: int) -> int:
    """Quant blocks per reduce-scatter shard slot: ``ceil(nb / E)``.

    The rs/ag wire path partitions the ``nb`` quantization blocks of a
    payload into ``E`` fixed-size slots of this many blocks each; when E
    does not divide nb the trailing slot(s) carry zero-padded blocks
    (zero scale -> dequantize to exactly 0), so every endpoint's slot has
    the same static shape — ragged last shards cost padding, never a
    shape mismatch.
    """
    if endpoints < 1:
        raise ValueError(f"endpoints must be >= 1, got {endpoints}")
    return -(-nb // endpoints)


def shard_slot_wire(q, scales, *, bits: int, block: int, endpoints: int):
    """One endpoint's (q, scales) -> per-slot packed wire buffers.

    ``q``: (nb*block,) int8, ``scales``: (nb,) f32. Pads to
    ``E * wire_shard_blocks(nb, E)`` blocks and packs each slot's values
    *independently* (``pack_wire`` per slot), so int4 nibble boundaries
    never straddle a slot boundary — slot ``e`` of the wire stream is a
    self-contained byte buffer whatever the shard length's parity.
    Returns ``((E, nw_slot) wire bytes, (E, sb) scales)``.
    """
    nb = scales.shape[0]
    sb = wire_shard_blocks(nb, endpoints)
    qp = jnp.pad(q, (0, (endpoints * sb - nb) * block))
    sp = jnp.pad(scales, (0, endpoints * sb - nb))
    q_slots = qp.reshape(endpoints, sb * block)
    w_slots = jnp.stack(
        [pack_wire(q_slots[e], bits) for e in range(endpoints)])
    return w_slots, sp.reshape(endpoints, sb)


def reduce_scatter_qs_ref(q, scales, *, block: int = 256, bits: int = 8,
                          weights=None):
    """Reduce-scatter oracle: every endpoint's reduced shard, stacked.

    ``q``: (E, nb*block) int8 values, ``scales``: (E, nb) f32 — one row
    per endpoint (source). Row ``e`` of the (E, sb*block) fp32 result is
    what endpoint ``e`` computes in the distributed exchange: the
    canonical-order per-source-scale sum (:func:`dequant_sum_sources` —
    THE reduction, shared with the all-reduce wire path) applied to slot
    ``e`` of every source's per-slot packed wire stream. ``weights``
    forwards the elastic-membership mask.
    """
    E = q.shape[0]
    slots = [shard_slot_wire(q[j], scales[j], bits=bits, block=block,
                             endpoints=E) for j in range(E)]
    rows = []
    for e in range(E):
        wg = jnp.stack([slots[j][0][e] for j in range(E)])
        sg = jnp.stack([slots[j][1][e] for j in range(E)])
        rows.append(dequant_sum_sources(wg, sg, bits=bits, block=block,
                                        weights=weights))
    return jnp.stack(rows)


def dequant_concat_sources(wg, sg, *, bits: int, block: int):
    """All-gather reconstruction: (E, nw_slot) wire + (E, sb) scales ->
    (E*sb*block,) fp32 payload.

    The gather leg of the rs/ag exchange: every endpoint dequantizes the
    *identical* re-quantized wire bytes per slot and concatenates in slot
    order, so the reconstructed payload is bit-identical on every
    endpoint (no summation — one contributor per slot). Shared by the
    distributed :func:`repro.kernels.ring_allreduce.allgather_qs`, the
    simulator, and the oracle below.
    """
    E, sb = sg.shape
    nq = sb * block
    return jnp.concatenate([
        dequantize_blockwise_ref(unpack_wire(wg[j], bits, nq), sg[j],
                                 block=block)
        for j in range(E)])


def rs_ag_qs_ref(q, scales, *, block: int = 256, bits: int = 8,
                 residual2=None, weights=None):
    """End-to-end rs/ag oracle: reduce-scatter, re-quantize, all-gather.

    ``q``: (E, nb*block) int8, ``scales``: (E, nb) f32 per endpoint.
    ``residual2``: optional (E, sb*block) f32 — endpoint ``e``'s second
    error-feedback residual over *its own* reduced shard (``None`` =
    zeros). Endpoint ``e`` reduces shard ``e``
    (:func:`reduce_scatter_qs_ref`), adds its residual, re-quantizes
    (second quantization — the gather leg ships quantized bytes too),
    and the all-gather reconstructs the full payload from the identical
    per-slot wire bytes on every endpoint
    (:func:`dequant_concat_sources`).

    Returns ``(payload (nb*block,), new_residual2 (E, sb*block))`` —
    the payload is cropped back from slot padding to the quantizer's own
    ``nb*block`` length, and the residual telescopes:
    ``reduced_shard + r2 = dequant(q2, s2) + new_r2`` exactly.
    """
    E, nbq = q.shape
    reduced = reduce_scatter_qs_ref(q, scales, block=block, bits=bits,
                                    weights=weights)
    if residual2 is None:
        residual2 = jnp.zeros_like(reduced)
    c2 = reduced + residual2
    q2s, s2s, w2s, deq = [], [], [], []
    for e in range(E):
        q2, s2 = quantize_blockwise_ref(c2[e], bits=bits, block=block)
        q2s.append(q2)
        s2s.append(s2)
        w2s.append(pack_wire(q2, bits))
        deq.append(dequantize_blockwise_ref(q2, s2, block=block))
    new_r2 = c2 - jnp.stack(deq)
    payload = dequant_concat_sources(jnp.stack(w2s), jnp.stack(s2s),
                                     bits=bits, block=block)
    return payload[:nbq], new_r2


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    """Row RMSNorm oracle. x: (..., D); scale: (D,)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
