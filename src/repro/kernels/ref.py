"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *the* reference semantics; the model's default CPU path uses them
directly, and every kernel test sweeps shapes/dtypes asserting the Pallas
(interpret=True) output matches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0
):
    """GQA attention oracle. q: (B,S,H,hd); k/v: (B,S,Hkv,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= pos[:, None] - pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def pier_update_ref(anchor, momentum, delta, *, mu, lr, formulation="nesterov_torch"):
    """Fused outer-update oracle (Alg. 2 lines 20-21), fp32 math.

    Returns (new_params, new_momentum).
    """
    mf = momentum.astype(jnp.float32)
    af = anchor.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    m_new = mu * mf + df
    if formulation == "nesterov_torch":
        step = mu * m_new + df
    elif formulation == "nesterov_classic":
        step = mu * mf + df
    elif formulation == "sgd":
        step = m_new
    else:
        raise ValueError(formulation)
    return af + lr * step, m_new


def quantize_blockwise_ref(x, *, bits: int = 8, block: int = 256):
    """Blockwise symmetric absmax quantization oracle (DESIGN.md §6).

    x: flat (N,) float -> (q int8 (nblocks*block,), scales f32 (nblocks,)).
    The payload is padded to whole blocks (zero pad -> zero scale/values).
    """
    qmax = float(2 ** (bits - 1) - 1)
    (n,) = x.shape
    nb = (n + block - 1) // block
    xf = jnp.pad(x.astype(jnp.float32), (0, nb * block - n))
    xb = xf.reshape(nb, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    # reciprocal-multiply to match the kernel bitwise under jit (XLA
    # strength-reduces constant divisions)
    scale = absmax * (1.0 / qmax)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(xb * inv[:, None]), -qmax, qmax)
    return q.astype(jnp.int8).reshape(nb * block), scale


def dequantize_blockwise_ref(q, scales, *, block: int = 256):
    """Inverse oracle: (nblocks*block,) int8 + (nblocks,) f32 -> f32."""
    nb = q.shape[0] // block
    qb = q.reshape(nb, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(nb * block)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    """Row RMSNorm oracle. x: (..., D); scale: (D,)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
