"""Paged decode-attention Pallas TPU kernel (serving, DESIGN.md §12).

Single-query attention for continuous-batching decode: each sequence's K/V
lives in fixed-size *blocks* scattered through a shared pool, addressed by a
per-sequence block table. The prefill-shaped ``flash_attention`` kernel
cannot serve this access pattern — its KV BlockSpecs assume one contiguous
(B, S, Hkv, hd) buffer per sequence — so decode gets its own kernel whose
KV index map *is* the block-table gather.

Layout (one attention layer):

    q             (B, H, hd)          one new query token per sequence
    k_pool/v_pool (N, bs, Hkv, hd)    the shared block pool
    block_tables  (B, T) int32        logical block j of sequence b lives in
                                      physical block ``block_tables[b, j]``
                                      (< 0 = unallocated — never touched)
    context_lens  (B,) int32          tokens written for sequence b,
                                      *including* the query's own K/V slot

The grid is (B, Hkv, T) with the block axis innermost-sequential; the
block-table gather happens in the KV BlockSpec index maps via scalar
prefetch (``PrefetchScalarGridSpec``), so each (bs, hd) KV panel is DMA'd
straight from its pool block — the PagedAttention access pattern expressed
the TPU way. An online-softmax accumulator (m, l, acc) lives in VMEM
scratch across the sequential block steps, exactly like the prefill
kernel's inner loop; blocks at or beyond ``context_lens[b]`` are skipped
with ``pl.when`` (no MXU work), and partially-filled tail blocks are
masked by position.

int8 KV (DESIGN.md §12): pools may be stored blockwise-quantized in the
``kernels/quantize.py`` wire format — int8 values plus one fp32 absmax
scale per (block-slot, kv-head) row of ``hd`` elements. The kernel then
takes the scale panels as two extra gathered inputs and dequantizes
in-VMEM (``q.astype(f32) * scale``) — elementwise-identical to
``_dequant_kernel`` — so HBM traffic for the cache drops ~4x vs fp32.

The pure-jnp oracle ``paged_decode_attention_ref`` executes the same ops
in the same order per (b, kv-head) pair, so interpret-mode kernel output
matches it bit for bit (asserted in tests/test_serving.py). GQA/MQA share
the gather: q is reshaped (B, Hkv, G, hd) and each grid step attends one
kv head's G query heads; mha is the G == 1 case.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_kernel

# jax < 0.5 names this TPUCompilerParams; it was renamed to CompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _decode_kernel(
    # scalar prefetch
    bt_ref,  # (B, T) int32 block tables
    cl_ref,  # (B,) int32 context lengths
    # VMEM tiles
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, bs, 1, hd) — gathered pool block for this kv head
    v_ref,
    *rest,  # [k_scale (1, bs, 1), v_scale (1, bs, 1)] when quantized, then
    # o_ref, m_scr (G, 1), l_scr (G, 1), acc_scr (G, hd)
    scale: float,
    block_size: int,
    window: int,
    softcap: float,
    num_blocks: int,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cl = cl_ref[b]

    @pl.when(j * block_size < cl)
    def _compute():
        G = q_ref.shape[2]
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # elementwise-identical to quantize._dequant_kernel
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bs)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_size), 1)
        mask = pos < cl  # tail-block slots beyond the context
        if window > 0:
            # query position is cl - 1; same predicate as the dense path's
            # (q_pos - k_pos) < window
            mask &= pos >= cl - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]  # (G, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (G, bs)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(j == num_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,  # (B, H, hd)
    k_pool: jax.Array,  # (N, bs, Hkv, hd) — fp or int8 (with scales)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, T) int32, < 0 = unallocated
    context_lens: jax.Array,  # (B,) int32
    k_scales: Optional[jax.Array] = None,  # (N, bs, Hkv) f32 when int8
    v_scales: Optional[jax.Array] = None,
    *,
    window: int = 0,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Paged single-query attention. Returns (B, H, hd) in q.dtype.

    Sequences with ``context_lens[b] == 0`` (empty decode slots) produce
    zeros. ``interpret=None`` dispatches through the KernelBackend
    registry: compiled Mosaic on tpu-mosaic, the interpreter
    off-accelerator, the jnp oracle on gpu-triton (scalar-prefetch grids
    don't lower to Triton) and jnp-ref. An explicit bool forces the
    Pallas body (legacy override — the bitwise tests pin
    ``interpret=True``).
    """
    impl, interpret = resolve_kernel("decode_attention", interpret)
    if impl == "jnp":
        return paged_decode_attention_ref(
            q, k_pool, v_pool, block_tables, context_lens,
            k_scales, v_scales, window=window, softcap=softcap)
    return _paged_decode_pallas(
        q, k_pool, v_pool, block_tables, context_lens, k_scales, v_scales,
        window=window, softcap=softcap, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "interpret"),
)
def _paged_decode_pallas(q, k_pool, v_pool, block_tables, context_lens,
                         k_scales=None, v_scales=None, *,
                         window: int, softcap: float, interpret: bool):
    B, H, hd = q.shape
    N, bs, Hkv, _ = k_pool.shape
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    T = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scales is not None

    q4 = q.reshape(B, Hkv, G, hd)
    block_tables = block_tables.astype(jnp.int32)
    context_lens = context_lens.astype(jnp.int32)

    def q_map(b, h, j, bt, cl):
        return (b, h, 0, 0)

    def kv_map(b, h, j, bt, cl):
        # out-of-range logical blocks clamp to physical block 0; their
        # compute is skipped (j * bs >= cl) so the gathered data is unused
        return (jnp.maximum(bt[b, j], 0), 0, h, 0)

    def scale_map(b, h, j, bt, cl):
        return (jnp.maximum(bt[b, j], 0), 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, G, hd), q_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
    ]
    operands = [q4, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1), scale_map),
            pl.BlockSpec((1, bs, 1), scale_map),
        ]
        operands += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, scale=scale, block_size=bs, window=window,
        softcap=softcap, num_blocks=T, quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, context_lens, *operands)
    return out.reshape(B, H, hd)


# ---------------------------------------------------------------------------
# pure-jnp oracle — same loop-body graph, per (b, kv-head) pair
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("window", "softcap"))
def _ref_pair(q, kblks, vblks, ksblks, vsblks, cl, *,
              window: int, softcap: float):
    """One (b, kv-head) pair: q (G, hd) against gathered blocks (T, bs, hd).

    Structurally mirrors the interpret-mode kernel program: an *unrolled*
    python loop over blocks (interpret mode unrolls the grid into the
    traced computation) whose per-block compute sits behind a ``lax.cond``
    on the same ``j * bs < cl`` predicate ``pl.when`` lowers to. Matching
    the program structure — not just the math — is what makes the outputs
    bitwise equal: XLA's fusion/FMA-contraction choices are
    producer-dependent (cf. the PR 4 note in kernels/ref.py), so a rolled
    scan or an eager loop drifts by ~1e-7 once the body grows a mask or a
    dequant multiply.
    """
    G, hd = q.shape
    T, bs = kblks.shape[0], kblks.shape[1]
    scale = jnp.float32(1.0 / math.sqrt(hd))
    qf = q.astype(jnp.float32)
    carry = (jnp.full((G, 1), NEG_INF, jnp.float32),
             jnp.zeros((G, 1), jnp.float32),
             jnp.zeros((G, hd), jnp.float32))
    for j in range(T):
        def compute(c, j=j):
            m, l, acc = c
            k = kblks[j].astype(jnp.float32)
            v = vblks[j].astype(jnp.float32)
            if ksblks is not None:
                # elementwise-identical to quantize._dequant_kernel
                k = k * ksblks[j][:, None]
                v = v * vsblks[j][:, None]
            s = jax.lax.dot_general(
                qf, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (G, bs), 1)
            mask = pos < cl
            if window > 0:
                mask &= pos >= cl - window
            s = jnp.where(mask, s, NEG_INF)
            m_cur = jnp.max(s, axis=1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=1, keepdims=True)
            acc_new = acc * alpha + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new)

        carry = jax.lax.cond(j * bs < cl, compute, lambda c: c, carry)
    m, l, acc = carry
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Oracle for :func:`paged_decode_attention` (bitwise in interpret mode).

    Python loop over (b, kv head) pairs; each pair runs :func:`_ref_pair`'s
    jitted online-softmax scan over that sequence's gathered blocks.
    """
    B, H, hd = q.shape
    Hkv = k_pool.shape[2]
    G = H // Hkv
    q4 = q.reshape(B, Hkv, G, hd)
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    cls = context_lens.astype(jnp.int32)

    rows = []
    for b in range(B):
        kb = k_pool[bt[b]]  # (T, bs, Hkv, hd)
        vb = v_pool[bt[b]]
        ksb = k_scales[bt[b]] if k_scales is not None else None
        vsb = v_scales[bt[b]] if v_scales is not None else None
        heads = []
        for h in range(Hkv):
            heads.append(_ref_pair(
                q4[b, h], kb[:, :, h], vb[:, :, h],
                ksb[:, :, h] if ksb is not None else None,
                vsb[:, :, h] if vsb is not None else None,
                cls[b], window=window, softcap=softcap))
        rows.append(jnp.stack(heads))
    return jnp.stack(rows).reshape(B, H, hd)


def paged_decode_supported(num_heads: int, num_kv_heads: int,
                           head_dim: int) -> Tuple[bool, str]:
    """Whether the paged kernel covers this head layout (and why not)."""
    if num_kv_heads <= 0 or num_heads % num_kv_heads != 0:
        return False, f"H={num_heads} not a multiple of Hkv={num_kv_heads}"
    if head_dim > 256:
        return False, f"head_dim {head_dim} > 256"
    return True, ""
