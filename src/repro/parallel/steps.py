"""Distributed step functions: the Pier runtime on a real mesh.

Layout invariants (see DESIGN.md §3):

- **Manual axes** ``(pod, data_outer)``: Pier's relaxed axes. Params and
  AdamW state carry a leading group axis ``G = num_pods * data_outer``
  sharded over them — each group owns a (possibly divergent) model replica,
  stored sharded over its own ``data_inner × model`` slice.
- **Auto axes** ``(data_inner, model)``: GSPMD inserts all in-group
  communication (FSDP param all-gathers, gradient reduce-scatters over the
  in-group batch, TP collectives, MoE all-to-all) from sharding constraints.

Step functions:

- ``inner_step``   — Alg. 2 lines 5-8: group-local AdamW. Provably free of
  (pod, data_outer) collectives (asserted by tests on the lowered HLO).
- ``warmup_step``  — lazy-start/AdamW baseline: + global grad pmean.
- ``accumulate_step`` — Alg. 1 lines 4-7: outer-momentum accumulation (the
  eager fused path; donates the old outer state).
- ``accumulate_dispatch_step`` — the same accumulation as the dispatch half
  of a delayed warmup event (DESIGN.md §9): non-donating, so the
  pre-dispatch outer state stays live while the pending result is in
  flight; the apply half is a host-side install
  (``core.outer.warmup_apply`` — the warmup stale-delta correction is
  identically zero).
- ``outer_step``   — Alg. 2 lines 10-21: global Δθ pmean + Nesterov (eager,
  sync_delay=0 path).
- ``dispatch_step`` / ``apply_step`` — the same update split for delayed
  sync (sync_delay>0): dispatch launches the global Δθ pmean + Nesterov math
  without blocking the host, apply installs the target ``d`` steps later with
  the stale-delta correction (see core/outer.py and DESIGN.md).
- ``chunk_dispatch_steps`` / ``chunk_apply_steps`` — chunked dispatch and
  per-chunk apply (strategy plans with > 1 span, DESIGN.md §7): the Δθ
  tree is split into contiguous leaf spans, each reduced by its own XLA
  computation carrying its own per-chunk :class:`ChunkDispatch`, so early
  chunks' collectives run while later chunks are still being quantized —
  and early chunks *apply* (with their partial stale-delta correction)
  while later chunks' collectives are still in flight.
- ``serve_step`` / ``prefill_step`` — inference (plain GSPMD, no groups).

The outer collective itself is a pluggable :class:`OuterSyncStrategy`
(DESIGN.md §7, ``repro/sync/``): the strategy owns the per-leaf reduce
(flat fp32 pmean — the seed path, bit for bit — or hierarchical two-stage
and/or blockwise-quantized with an error-feedback residual carried
group-locally in ``OuterState.residual``) and the chunking plan; this
module only builds the jitted shard_map scaffolding around it. Every
jitted step in a :class:`StepBundle` is keyed off ONE strategy's plan —
a mid-run strategy switch (DESIGN.md §9) builds a fresh bundle (the
re-jit boundary; the Trainer caches bundles per strategy so switching
back is compile-free) and retargets ``OuterState.residual`` through
``init_residual`` when the residual requirement changes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.core.outer import (OuterState, outer_apply, outer_init,
                              outer_reduce, outer_reduce_leaves,
                              outer_update, warmup_reduce)
from repro.launch import mesh as M
from repro.models import registry as R
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedules import lr_at
from repro.parallel import sharding as S
from repro.parallel.axes import pier_rules, use_rules
from repro.sync import (ChunkDispatch, OuterSyncStrategy, ReduceCtx,
                        SyncPlan, resolve_strategy)


class TrainState(NamedTuple):
    params: Any  # (G,)-stacked param tree
    opt: AdamWState  # (G,)-stacked


class DispatchState(NamedTuple):
    """An in-flight outer sync (sync_delay > 0): what apply needs later.

    ``target`` is the synchronized fp32 model produced from the global Δθ
    all-reduce; ``snapshot`` is each group's θ_dispatch, materialized as a
    fresh buffer because inner steps donate (and overwrite) the live params
    during the in-flight window.
    """

    target: Any  # fp32 param tree, identical across groups
    snapshot: Any  # (G,)-stacked param tree at dispatch time


@dataclass
class StepBundle:
    mesh: Mesh
    manual: Tuple[str, ...]
    num_groups: int
    strategy: OuterSyncStrategy
    plan: SyncPlan
    pspec: Any  # unstacked param specs
    stacked_pspec: Any
    state_shardings: Any
    outer_shardings: Any
    batch_sharding: Callable[[Any], Any]
    init_state: Callable
    init_outer: Callable
    inner_step: Callable
    warmup_step: Callable
    accumulate_step: Callable
    accumulate_dispatch_step: Callable
    outer_step: Callable
    dispatch_step: Callable
    apply_step: Callable
    eval_step: Callable
    # chunked dispatch / per-chunk apply (plan.num_chunks > 1): one jitted
    # dispatch computation per contiguous Δθ-leaf span, each returning its
    # own ChunkDispatch plus the span's updated outer leaves, and one
    # jitted apply per span installing that chunk's target. None when the
    # plan is a single fused span.
    chunk_dispatch_steps: Optional[Tuple[Callable, ...]] = None
    chunk_apply_steps: Optional[Tuple[Callable, ...]] = None
    # host-side: fold the per-chunk outer leaves back into one OuterState
    # (num_syncs advances exactly once per sync, regardless of chunks).
    stitch_outer: Optional[Callable] = None
    # residual retarget for mid-run strategy switches: materialize the
    # zero error-feedback residual (with this bundle's shardings) when the
    # incoming OuterState has none. None when the plan needs no residual.
    init_residual: Optional[Callable] = None
    # Elastic-membership variants (DESIGN.md §11), built only when
    # ``tc.membership`` is set — the fixed-membership graphs above stay
    # byte-for-byte unchanged otherwise. Weights/live masks are TRACED
    # (G,) arguments, so a mask change never re-jits.
    #   elastic_outer_step(state, outer, mu, olr, weights, live)
    #   elastic_dispatch_step(state, outer, mu, olr, weights)
    #   elastic_apply_step(state, dispatch, live)
    #   bootstrap_group(state, outer, g, donor_params) — reset group g's
    #     params to ``donor_params`` (anchor or checkpoint slice), fresh
    #     inner-opt state, zero residual; the rejoin bootstrap.
    elastic_outer_step: Optional[Callable] = None
    elastic_dispatch_step: Optional[Callable] = None
    elastic_apply_step: Optional[Callable] = None
    bootstrap_group: Optional[Callable] = None


def _param_shapes(mc: ModelConfig, scan_layers: bool = False):
    return jax.eval_shape(
        lambda k: R.init_params(k, mc, scan_layers=scan_layers),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def _stack(tree, g: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (g, *x.shape)), tree)


def build_train_steps(
    mc: ModelConfig, tc: TrainConfig, pc: ParallelConfig, mesh: Mesh,
    strategy: Optional[OuterSyncStrategy] = None,
) -> StepBundle:
    strategy = strategy if strategy is not None else resolve_strategy(tc)
    manual = M.manual_axes(mesh)
    sizes = M.axis_sizes(mesh)
    G = 1
    for a in manual:
        G *= sizes[a]

    rules = pier_rules(
        have_pod="pod" in sizes, fsdp=pc.fsdp,
        shard_experts=pc.shard_experts, inside_manual=True,
        axis_sizes=sizes)

    # ---- sharding specs -------------------------------------------------
    pshapes = _param_shapes(mc, pc.scan_layers)
    pspec = S.param_specs(pshapes, mesh, pc)
    stacked_pspec = S.stack_spec(pspec, manual)
    opt_shapes = jax.eval_shape(lambda: adamw_init(pshapes, tc))
    opt_spec = AdamWState(
        count=P(manual),
        mu=S.param_specs(opt_shapes.mu, mesh, pc),
        nu=S.param_specs(opt_shapes.nu, mesh, pc))
    stacked_opt_spec = AdamWState(
        count=P(manual),
        mu=S.stack_spec(opt_spec.mu, manual),
        nu=S.stack_spec(opt_spec.nu, manual))
    state_spec = TrainState(params=stacked_pspec, opt=stacked_opt_spec)
    state_shardings = S.shardings(state_spec, mesh)
    plan = strategy.plan(pshapes, tc, mesh)
    compress = plan.needs_residual
    # The rs-ag wire path (DESIGN.md §14) carries a second error-feedback
    # residual over the re-quantized reduced shard; like the first it is
    # group-local (nonzero only on each group's own 1/E slot), so both
    # are (G,)-stacked.
    compress2 = getattr(plan, "needs_residual2", False)
    # The error-feedback residual is group-local (each group quantizes its
    # own payload), so unlike momentum/anchor it is (G,)-stacked.
    outer_spec = OuterState(
        momentum=S.param_specs(pshapes, mesh, pc),
        anchor=S.param_specs(pshapes, mesh, pc),
        num_syncs=P(),
        residual=(S.stack_spec(S.param_specs(pshapes, mesh, pc), manual)
                  if compress else None),
        residual2=(S.stack_spec(S.param_specs(pshapes, mesh, pc), manual)
                   if compress2 else None))
    outer_shardings = S.shardings(outer_spec, mesh)
    bspec = S.batch_spec(mesh)

    def batch_sharding(batch_shapes):
        return jax.tree.map(
            lambda x: NamedSharding(mesh, P(bspec[0], *([None] * (x.ndim - 1)))),
            batch_shapes)

    # ---- init ------------------------------------------------------------
    def init_state(rng) -> TrainState:
        def f(rng):
            params = R.init_params(rng, mc, scan_layers=pc.scan_layers)
            opt = adamw_init(params, tc)
            return TrainState(params=_stack(params, G), opt=AdamWState(
                count=jnp.zeros((G,), jnp.int32),
                mu=_stack(opt.mu, G), nu=_stack(opt.nu, G)))
        return jax.jit(f, out_shardings=state_shardings)(rng)

    def init_outer(state: TrainState) -> OuterState:
        def f(state):
            params = jax.tree.map(lambda x: x[0], state.params)
            return outer_init(params, tc, num_groups=G,
                              needs_residual=compress,
                              needs_residual2=compress2)
        return jax.jit(f, out_shardings=outer_shardings)(state)

    # ---- the shared inner/warmup body -------------------------------------
    def grads_and_loss(params, batch, step):
        nm = pc.num_microbatches

        def lfn(p, b):
            return R.loss_fn(p, mc, b, use_pallas=pc.use_pallas,
                             remat=pc.remat)

        if nm == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lfn, has_aux=True)(params, batch)
            return grads, loss
        micro = jax.tree.map(
            lambda x: x.reshape(nm, x.shape[0] // nm, *x.shape[1:]), batch)

        def mb_body(acc, b):
            g_acc, l_acc = acc
            (loss, _), grads = jax.value_and_grad(lfn, has_aux=True)(params, b)
            return (jax.tree.map(jnp.add, g_acc, grads), l_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc0 = (zeros, jnp.float32(0))
        if manual:
            # grads are varying over the manual (group) axes; the zero init
            # must carry the same varying-mesh-axes annotation for the scan
            acc0 = compat.pvary(acc0, tuple(manual))
        (gsum, lsum), _ = jax.lax.scan(mb_body, acc0, micro)
        inv = 1.0 / nm
        return jax.tree.map(lambda g: g * inv, gsum), lsum * inv

    def make_sgd_body(global_sync: bool):
        def body(state: TrainState, batch, step):
            with use_rules(rules):
                params = jax.tree.map(lambda x: x[0], state.params)
                opt = jax.tree.map(lambda x: x[0], state.opt)
                grads, loss = grads_and_loss(params, batch, step)
                if global_sync and manual:
                    grads = jax.lax.pmean(grads, manual)
                grads, gnorm = clip_by_global_norm(grads, tc.clip_grad)
                lr = lr_at(tc, step)
                new_params, new_opt = adamw_update(grads, opt, params, tc, lr)
                metrics = {
                    "loss": jax.lax.pmean(loss, manual) if manual else loss,
                    "grad_norm": (jax.lax.pmean(gnorm, manual)
                                  if manual else gnorm),
                    "lr": lr,
                }
                new_state = TrainState(
                    params=jax.tree.map(lambda x: x[None], new_params),
                    opt=jax.tree.map(lambda x: x[None], new_opt))
                return new_state, metrics
        return body

    def wrap_state_step(body):
        in_specs = (
            TrainState(
                params=jax.tree.map(lambda _: P(manual), state_spec.params,
                                    is_leaf=lambda s: isinstance(s, P)),
                opt=jax.tree.map(lambda _: P(manual), state_spec.opt,
                                 is_leaf=lambda s: isinstance(s, P))),
            P(manual),  # batch dim 0 (manual part; data_inner rides auto)
            P(),  # step
        )
        out_specs = (in_specs[0], P())

        def stepfn(state, batch, step):
            batch_specs = jax.tree.map(
                lambda x: P(manual, *([None] * (x.ndim - 1))), batch)
            f = compat.shard_map(
                body, mesh=mesh,
                in_specs=(in_specs[0], batch_specs, P()),
                out_specs=out_specs,
                axis_names=set(manual))
            return f(state, batch, step)

        return jax.jit(stepfn, donate_argnums=(0,))

    inner_step = wrap_state_step(make_sgd_body(global_sync=False))
    warmup_step = wrap_state_step(make_sgd_body(global_sync=True))

    # ---- outer events -----------------------------------------------------
    # Shared shard_map specs. The outer state is replicated across groups
    # except the error-feedback residual, which is group-local (stacked).
    _sspec = lambda: TrainState(
        params=jax.tree.map(lambda _: P(manual), state_spec.params,
                            is_leaf=lambda s: isinstance(s, P)),
        opt=jax.tree.map(lambda _: P(manual), state_spec.opt,
                         is_leaf=lambda s: isinstance(s, P)))

    def _ospec():
        rep = lambda t: jax.tree.map(lambda _: P(), t,
                                     is_leaf=lambda s: isinstance(s, P))
        return OuterState(
            momentum=rep(outer_spec.momentum),
            anchor=rep(outer_spec.anchor),
            num_syncs=P(),
            residual=(jax.tree.map(lambda _: P(manual), outer_spec.residual,
                                   is_leaf=lambda s: isinstance(s, P))
                      if compress else None),
            residual2=(jax.tree.map(lambda _: P(manual),
                                    outer_spec.residual2,
                                    is_leaf=lambda s: isinstance(s, P))
                       if compress2 else None))

    _dspec = lambda sspec: DispatchState(
        target=jax.tree.map(lambda _: P(), sspec.params,
                            is_leaf=lambda s: isinstance(s, P)),
        snapshot=sspec.params)

    fast_axes = tuple(a for a in manual if a != "pod")
    slow_axes = tuple(a for a in manual if a == "pod")
    auto_axes = tuple(a for a in mesh.axis_names if a not in manual)
    # The mesh-axis context threaded to the strategy's per-leaf reduce:
    # the exchange starts at the full manual set; the hierarchical
    # combinator narrows it to the slow axes after its fast-domain mean.
    # axis_sizes gives wire strategies (plan.wire_format != "fp32") their
    # static ring-endpoint counts — their hop loops unroll at trace time —
    # and sharded strategies their auto-axis shard count (block alignment).
    # The mesh rides along because constraints inside partial-manual
    # shard_map must be NamedShardings on jax 0.4.x (sync/base.py).
    reduce_ctx = ReduceCtx(manual=manual, fast_axes=fast_axes,
                           slow_axes=slow_axes, exchange_axes=manual,
                           use_pallas=pc.use_pallas,
                           axis_sizes={a: int(sizes[a])
                                       for a in mesh.axis_names},
                           auto_axes=auto_axes, mesh=mesh)
    # Per-leaf PartitionSpecs over the auto axes, in Δθ leaf order —
    # threaded to sharded strategies through ``ReduceCtx.leaf_spec``.
    pspec_flat = jax.tree_util.tree_leaves(
        pspec, is_leaf=lambda s: isinstance(s, P))
    sharded_state = bool(getattr(strategy, "sharded_state", False))

    # Wire strategies also need each shard's coordinate along the manual
    # axes (the canonical ring-slot index). jax 0.4.x cannot lower
    # lax.axis_index inside partial-manual shard_map, so the coordinates
    # enter as data: an arange sharded over each axis, sliced per shard.
    def _coord_inputs():
        return {a: jnp.arange(sizes[a], dtype=jnp.int32) for a in manual}

    def _coord_spec():
        return {a: P(a) for a in manual}

    def _local_ctx(coords):
        return reduce_ctx.with_coords({a: c[0] for a, c in coords.items()})

    def _global_pmean(tree):
        """Flat or two-stage pmean over the manual axes (same mean)."""
        if not manual:
            return tree
        if strategy.two_stage:
            if fast_axes:
                tree = jax.lax.pmean(tree, fast_axes)
            if slow_axes:
                tree = jax.lax.pmean(tree, slow_axes)
            return tree
        return jax.lax.pmean(tree, manual)

    def _reduce_delta_leaf(d, r, ctx=reduce_ctx, spec=None):
        """One Δθ leaf -> (globally averaged payload, new residual | None).

        Delegates to the strategy: flat fp32 pmean is the seed collective
        bit for bit; hierarchical / quantized strategies stage and
        compress the payload (DESIGN.md §6/§7); the int8-wire strategy
        ring-exchanges the packed payload itself (DESIGN.md §8), using
        the shard coordinates carried on ``ctx``; sharded strategies pin
        the leaf to ``spec`` (its auto-axis PartitionSpec) so only the
        per-device shard is compressed and exchanged (DESIGN.md §10).
        """
        return strategy.reduce_leaf(d, r, tc, ctx.with_leaf_spec(spec))

    def _reduced_delta(params, outer, ctx=reduce_ctx):
        """(delta_avg tree, new residual tree | None) for one group.

        Under the rs-ag wire path (``compress2``) each leaf's residual
        travels as an opaque ``(r1, r2)`` pair and ``new_res`` comes back
        as the ``(tree_r1, tree_r2)`` pair ``_residual_kw`` unpacks."""
        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32),
            params, outer.anchor)
        res = (jax.tree.map(lambda x: x[0], outer.residual)
               if compress else None)
        flat_d, treedef = jax.tree_util.tree_flatten(delta)
        flat_r = (treedef.flatten_up_to(res) if compress
                  else [None] * len(flat_d))
        if compress2:
            res2 = jax.tree.map(lambda x: x[0], outer.residual2)
            flat_r2 = treedef.flatten_up_to(res2)
            flat_r = [(r1, r2) for r1, r2 in zip(flat_r, flat_r2)]
        out = [_reduce_delta_leaf(d, r, ctx, spec)
               for d, r, spec in zip(flat_d, flat_r, pspec_flat)]
        unf = jax.tree_util.tree_unflatten
        delta_avg = unf(treedef, [p for p, _ in out])
        if compress2:
            new_res = (
                unf(treedef, [jnp.expand_dims(r[0], 0) for _, r in out]),
                unf(treedef, [jnp.expand_dims(r[1], 0) for _, r in out]))
        else:
            new_res = (unf(treedef, [jnp.expand_dims(r, 0) for _, r in out])
                       if compress else None)
        return delta_avg, new_res

    def _residual_kw(new_res):
        if compress2:
            return {"residual": new_res[0], "residual2": new_res[1]}
        return {"residual": new_res} if compress else {}

    def accumulate_body(state, outer, mu):
        with use_rules(rules):
            params = jax.tree.map(lambda x: x[0], state.params)
            if manual:
                # During warmup all groups hold identical params (they run
                # globally synced AdamW), but the VMA checker cannot prove
                # it — pmean is the identity here and makes it explicit.
                params = _global_pmean(params)
            return warmup_reduce(outer, params, mu)

    def accumulate_fn(state, outer, mu):
        f = compat.shard_map(
            accumulate_body, mesh=mesh,
            in_specs=(_sspec(), _ospec(), P()),
            out_specs=_ospec(),
            axis_names=set(manual))
        return f(state, outer, mu)

    # Sharded strategies pin every outer-event output to the param_specs
    # layouts via jit out_shardings (in-body constraints guide GSPMD, the
    # out_shardings make the ~1/(TP×FSDP) outer-state scaling a guarantee
    # rather than a propagation outcome). Replicated strategies keep the
    # seed behavior: layouts left to GSPMD.
    _out_sh = (lambda sh: {"out_shardings": sh}) if sharded_state \
        else (lambda sh: {})
    dispatch_shardings = DispatchState(
        target=S.shardings(pspec, mesh),
        snapshot=S.shardings(stacked_pspec, mesh))

    accumulate_step = jax.jit(accumulate_fn, donate_argnums=(1,),
                              **_out_sh(outer_shardings))
    # the dispatch half of a delayed warmup event: identical math, but the
    # old outer state is NOT donated — it stays the live state while the
    # pending result is in flight (the apply half installs it host-side;
    # core.outer.warmup_apply documents why the correction is zero).
    accumulate_dispatch_step = jax.jit(accumulate_fn,
                                       **_out_sh(outer_shardings))

    def outer_body(state, outer, mu, olr, coords):
        with use_rules(rules):
            params = jax.tree.map(lambda x: x[0], state.params)
            delta, new_res = _reduced_delta(
                params, outer, _local_ctx(coords))  # THE collective
            new_params_f32, new_outer = outer_update(
                outer, delta, tc, mu=mu, lr=olr, use_pallas=pc.use_pallas,
                **_residual_kw(new_res))
            new_params = jax.tree.map(
                lambda f32, p: f32.astype(p.dtype)[None],
                new_params_f32, params)
            new_state = TrainState(params=new_params, opt=state.opt)
            return new_state, new_outer

    def outer_fn(state, outer, mu, olr):
        sspec, ospec = _sspec(), _ospec()
        f = compat.shard_map(
            outer_body, mesh=mesh,
            in_specs=(sspec, ospec, P(), P(), _coord_spec()),
            out_specs=(sspec, ospec),
            axis_names=set(manual))
        return f(state, outer, mu, olr, _coord_inputs())

    outer_step = jax.jit(outer_fn, donate_argnums=(0, 1),
                         **_out_sh((state_shardings, outer_shardings)))

    # ---- delayed outer sync (dispatch / apply) -----------------------------
    # dispatch launches THE global collective and the Nesterov math; the host
    # does not block on it (jax dispatch is async), so the all-reduce runs
    # concurrently with the next ``sync_delay`` inner steps. apply installs
    # the target with the stale-delta correction once the window closes.
    def dispatch_body(state, outer, mu, olr, coords):
        with use_rules(rules):
            params = jax.tree.map(lambda x: x[0], state.params)
            delta, new_res = _reduced_delta(
                params, outer, _local_ctx(coords))  # THE collective
            target_f32, new_outer = outer_reduce(
                outer, delta, tc, mu=mu, lr=olr, use_pallas=pc.use_pallas,
                **_residual_kw(new_res))
            dispatch = DispatchState(
                target=target_f32,
                snapshot=jax.tree.map(lambda x: x[None], params))
            return dispatch, new_outer

    def dispatch_fn(state, outer, mu, olr):
        sspec, ospec = _sspec(), _ospec()
        dspec = _dspec(sspec)
        f = compat.shard_map(
            dispatch_body, mesh=mesh,
            in_specs=(sspec, ospec, P(), P(), _coord_spec()),
            out_specs=(dspec, ospec),
            axis_names=set(manual))
        return f(state, outer, mu, olr, _coord_inputs())

    # NOTE: the train state is NOT donated — the snapshot output forces a
    # fresh copy of the params while inner steps keep donating the live ones.
    dispatch_step = jax.jit(dispatch_fn, donate_argnums=(1,),
                            **_out_sh((dispatch_shardings,
                                       outer_shardings)))

    # ---- chunked dispatch + per-chunk apply (plan.num_chunks > 1) ----------
    # The Δθ leaves are split into contiguous spans; each span's reduce AND
    # its slice of the Nesterov update is its own jitted computation, so the
    # host enqueues them back to back and chunk k's collective overlaps
    # chunk k+1's quantization/compute. Each chunk returns its own
    # ChunkDispatch (targets + snapshots for the span), so the later
    # per-chunk applies install early-arriving chunks while late chunks'
    # collectives are still in flight (partial stale-delta correction per
    # span). Per-leaf math is identical to the fused dispatch
    # (outer_reduce_leaves is shared), so chunking never changes numerics.
    chunk_dispatch_steps = None
    chunk_apply_steps = None
    stitch_outer = None
    if plan.num_chunks > 1:
        pflat_shapes, ptreedef = jax.tree_util.tree_flatten(pshapes)
        spans = plan.spans
        stacked_pspec_flat = jax.tree_util.tree_leaves(
            stacked_pspec, is_leaf=lambda s: isinstance(s, P))

        def _span_shardings(lo, hi):
            """Per-span out_shardings (sharded strategies): targets /
            momentum / anchor at the unstacked per-leaf specs, snapshots /
            residual at the (G,)-stacked ones."""
            ns = lambda spec: NamedSharding(mesh, spec)
            unstacked = tuple(ns(pspec_flat[j]) for j in range(lo, hi))
            stacked = tuple(ns(stacked_pspec_flat[j]) for j in range(lo, hi))
            return (ChunkDispatch(targets=unstacked, snapshots=stacked),
                    (unstacked, unstacked, stacked if compress else ()))

        def make_chunk_dispatch(lo, hi):
            def chunk_body(state, outer, mu, olr, coords):
                with use_rules(rules):
                    ctx = _local_ctx(coords)
                    params = jax.tree.map(lambda x: x[0], state.params)
                    p_flat = ptreedef.flatten_up_to(params)
                    a_flat = ptreedef.flatten_up_to(outer.anchor)
                    m_flat = ptreedef.flatten_up_to(outer.momentum)
                    r_flat = (ptreedef.flatten_up_to(jax.tree.map(
                        lambda x: x[0], outer.residual))
                        if compress else [None] * len(p_flat))
                    payload, new_res, snaps = [], [], []
                    for j in range(lo, hi):
                        d = (p_flat[j].astype(jnp.float32)
                             - a_flat[j].astype(jnp.float32))
                        da, nr = _reduce_delta_leaf(d, r_flat[j], ctx,
                                                    pspec_flat[j])
                        payload.append(da)
                        if compress:
                            new_res.append(jnp.expand_dims(nr, 0))
                        snaps.append(jnp.expand_dims(p_flat[j], 0))
                    targets, new_m, new_anchor = outer_reduce_leaves(
                        m_flat[lo:hi], a_flat[lo:hi], payload, tc,
                        mu=mu, lr=olr, use_pallas=pc.use_pallas)
                    chunk = ChunkDispatch(targets=tuple(targets),
                                          snapshots=tuple(snaps))
                    return chunk, (tuple(new_m), tuple(new_anchor),
                                   tuple(new_res))

            def chunk_fn(state, outer, mu, olr):
                n = hi - lo
                chunk_spec = ChunkDispatch(
                    targets=tuple(P() for _ in range(n)),
                    snapshots=tuple(P(manual) for _ in range(n)))
                leaves_spec = (tuple(P() for _ in range(n)),
                               tuple(P() for _ in range(n)),
                               (tuple(P(manual) for _ in range(n))
                                if compress else ()))
                f = compat.shard_map(
                    chunk_body, mesh=mesh,
                    in_specs=(_sspec(), _ospec(), P(), P(), _coord_spec()),
                    out_specs=(chunk_spec, leaves_spec),
                    axis_names=set(manual))
                return f(state, outer, mu, olr, _coord_inputs())

            # NOTE: neither state (snapshots force fresh buffers) nor outer
            # (read by every chunk computation) is donated here; the outer
            # copy is retired host-side by stitch_outer after the last chunk.
            return jax.jit(chunk_fn, **_out_sh(_span_shardings(lo, hi)))

        chunk_dispatch_steps = tuple(
            make_chunk_dispatch(lo, hi) for lo, hi in spans)

        def make_chunk_apply(lo, hi):
            def apply_chunk_body(state, chunk):
                with use_rules(rules):
                    params = jax.tree.map(lambda x: x[0], state.params)
                    p_flat = ptreedef.flatten_up_to(params)
                    span = tuple(p_flat[lo:hi])
                    snaps = tuple(s[0] for s in chunk.snapshots)
                    new_span = outer_apply(chunk.targets, snaps, span)
                    p_flat[lo:hi] = list(new_span)
                    new_params = jax.tree_util.tree_unflatten(
                        ptreedef, p_flat)
                    return TrainState(
                        params=jax.tree.map(lambda x: x[None], new_params),
                        opt=state.opt)

            def apply_chunk_fn(state, chunk):
                n = hi - lo
                sspec = _sspec()
                chunk_spec = ChunkDispatch(
                    targets=tuple(P() for _ in range(n)),
                    snapshots=tuple(P(manual) for _ in range(n)))
                f = compat.shard_map(
                    apply_chunk_body, mesh=mesh,
                    in_specs=(sspec, chunk_spec),
                    out_specs=sspec,
                    axis_names=set(manual))
                return f(state, chunk)

            return jax.jit(apply_chunk_fn, donate_argnums=(0, 1),
                           **_out_sh(state_shardings))

        chunk_apply_steps = tuple(
            make_chunk_apply(lo, hi) for lo, hi in spans)

        def stitch_outer(outer, chunk_leaves):
            """Fold per-chunk outer leaves into one OuterState (host-side).

            ``chunk_leaves`` holds each chunk's (momentum, anchor, residual)
            span tuples in span order; num_syncs advances exactly once per
            sync regardless of the chunk count.
            """
            m_leaves, a_leaves, r_leaves = [], [], []
            for nm, na, nr in chunk_leaves:
                m_leaves.extend(nm)
                a_leaves.extend(na)
                r_leaves.extend(nr)
            unf = jax.tree_util.tree_unflatten
            return OuterState(
                momentum=unf(ptreedef, m_leaves),
                anchor=unf(ptreedef, a_leaves),
                num_syncs=outer.num_syncs + 1,
                residual=unf(ptreedef, r_leaves) if compress else None)

    # ---- residual retarget (mid-run strategy switches, DESIGN.md §9) ------
    init_residual = None
    if compress:
        _res_shardings = S.shardings(outer_spec.residual, mesh)

        def init_residual(state):
            """Zero error-feedback residual, (G,)-stacked like outer_init's.

            Used when a strategy switch moves from a residual-free plan to
            a compressed one: momentum/anchor carry over, the residual
            starts at zero — exactly the first-sync semantics of
            ``compress_delta(residual=None)``, now materialized so the
            stacked shardings match this bundle's specs. The Trainer also
            reuses it to materialize ``residual2`` when a switch lands on
            the rs-ag wire path (same zero tree, same stacked shardings).
            """
            def f(state):
                params = jax.tree.map(lambda x: x[0], state.params)
                return jax.tree.map(
                    lambda p: jnp.zeros((G, *p.shape), jnp.float32), params)
            return jax.jit(f, out_shardings=_res_shardings)(state)

    def apply_body(state, dispatch):
        with use_rules(rules):
            params = jax.tree.map(lambda x: x[0], state.params)
            snap = jax.tree.map(lambda x: x[0], dispatch.snapshot)
            new_params = outer_apply(dispatch.target, snap, params)
            new_state = TrainState(
                params=jax.tree.map(lambda x: x[None], new_params),
                opt=state.opt)
            return new_state

    def apply_fn(state, dispatch):
        sspec = _sspec()
        dspec = _dspec(sspec)
        f = compat.shard_map(
            apply_body, mesh=mesh,
            in_specs=(sspec, dspec),
            out_specs=sspec,
            axis_names=set(manual))
        return f(state, dispatch)

    apply_step = jax.jit(apply_fn, donate_argnums=(0, 1),
                         **_out_sh(state_shardings))

    # ---- elastic membership (DESIGN.md §11) --------------------------------
    # Weighted variable-membership variants of the outer events, built ONLY
    # when tc.membership is set: the per-event (G,) participation weights
    # and apply-live mask enter as traced, replicated data (a mask change
    # never re-jits), each shard slices its own group's weight by its
    # linearized manual coordinate (the same data-threading pattern as
    # axis_coords), and the strategy reduces with ×1/Σw normalization —
    # bit-identical to the fixed path at all-ones weights.
    elastic_outer_step = None
    elastic_dispatch_step = None
    elastic_apply_step = None
    bootstrap_group = None
    if tc.membership is not None:
        if plan.num_chunks > 1:
            raise NotImplementedError(
                "elastic membership does not compose with chunked "
                "dispatch yet (per-chunk weighted applies are a recorded "
                "follow-up) — drop --comm-chunks or membership")

        def _linear_idx(coords):
            """Row-major linearized manual coordinate == the group index
            (and the canonical wire-source slot)."""
            idx = jnp.int32(0)
            for a in manual:
                idx = idx * jnp.int32(sizes[a]) + coords[a]
            return idx

        def _member_ctx(coords, weights):
            local = {a: c[0] for a, c in coords.items()}
            ctx = reduce_ctx.with_coords(local)
            if not manual:
                return ctx.with_membership(weights, weights[0])
            w = jax.lax.dynamic_index_in_dim(
                weights, _linear_idx(local), 0, keepdims=False)
            return ctx.with_membership(weights, w)

        def _live_here(live, coords):
            local = {a: c[0] for a, c in coords.items()}
            if not manual:
                return live[0]
            return jax.lax.dynamic_index_in_dim(
                live, _linear_idx(local), 0, keepdims=False)

        def elastic_outer_body(state, outer, mu, olr, coords, weights,
                               live):
            with use_rules(rules):
                params = jax.tree.map(lambda x: x[0], state.params)
                delta, new_res = _reduced_delta(
                    params, outer, _member_ctx(coords, weights))
                new_params_f32, new_outer = outer_update(
                    outer, delta, tc, mu=mu, lr=olr,
                    use_pallas=pc.use_pallas, **_residual_kw(new_res))
                lg = _live_here(live, coords)
                new_params = jax.tree.map(
                    lambda f32, p: jnp.where(
                        lg, f32.astype(p.dtype), p)[None],
                    new_params_f32, params)
                new_state = TrainState(params=new_params, opt=state.opt)
                return new_state, new_outer

        def elastic_outer_fn(state, outer, mu, olr, weights, live):
            sspec, ospec = _sspec(), _ospec()
            f = compat.shard_map(
                elastic_outer_body, mesh=mesh,
                in_specs=(sspec, ospec, P(), P(), _coord_spec(), P(), P()),
                out_specs=(sspec, ospec),
                axis_names=set(manual))
            return f(state, outer, mu, olr, _coord_inputs(), weights, live)

        elastic_outer_step = jax.jit(
            elastic_outer_fn, donate_argnums=(0, 1),
            **_out_sh((state_shardings, outer_shardings)))

        def elastic_dispatch_body(state, outer, mu, olr, coords, weights):
            with use_rules(rules):
                params = jax.tree.map(lambda x: x[0], state.params)
                delta, new_res = _reduced_delta(
                    params, outer, _member_ctx(coords, weights))
                target_f32, new_outer = outer_reduce(
                    outer, delta, tc, mu=mu, lr=olr,
                    use_pallas=pc.use_pallas, **_residual_kw(new_res))
                dispatch = DispatchState(
                    target=target_f32,
                    snapshot=jax.tree.map(lambda x: x[None], params))
                return dispatch, new_outer

        def elastic_dispatch_fn(state, outer, mu, olr, weights):
            sspec, ospec = _sspec(), _ospec()
            dspec = _dspec(sspec)
            f = compat.shard_map(
                elastic_dispatch_body, mesh=mesh,
                in_specs=(sspec, ospec, P(), P(), _coord_spec(), P()),
                out_specs=(dspec, ospec),
                axis_names=set(manual))
            return f(state, outer, mu, olr, _coord_inputs(), weights)

        elastic_dispatch_step = jax.jit(
            elastic_dispatch_fn, donate_argnums=(1,),
            **_out_sh((dispatch_shardings, outer_shardings)))

        def elastic_apply_body(state, dispatch, coords, live):
            with use_rules(rules):
                params = jax.tree.map(lambda x: x[0], state.params)
                snap = jax.tree.map(lambda x: x[0], dispatch.snapshot)
                applied = outer_apply(dispatch.target, snap, params)
                lg = _live_here(live, coords)
                new_params = jax.tree.map(
                    lambda n, o: jnp.where(lg, n, o), applied, params)
                return TrainState(
                    params=jax.tree.map(lambda x: x[None], new_params),
                    opt=state.opt)

        def elastic_apply_fn(state, dispatch, live):
            sspec = _sspec()
            dspec = _dspec(sspec)
            f = compat.shard_map(
                elastic_apply_body, mesh=mesh,
                in_specs=(sspec, dspec, _coord_spec(), P()),
                out_specs=sspec,
                axis_names=set(manual))
            return f(state, dispatch, _coord_inputs(), live)

        elastic_apply_step = jax.jit(
            elastic_apply_fn, donate_argnums=(0, 1),
            **_out_sh(state_shardings))

        def bootstrap_body(state, outer, g, donor, coords):
            with use_rules(rules):
                local = {a: c[0] for a, c in coords.items()}
                is_g = (_linear_idx(local) == g) if manual \
                    else jnp.bool_(True)
                new_params = jax.tree.map(
                    lambda p, dn: jnp.where(
                        is_g, dn.astype(p.dtype)[None], p),
                    state.params, donor)
                new_opt = AdamWState(
                    count=jnp.where(is_g, jnp.zeros_like(state.opt.count),
                                    state.opt.count),
                    mu=jax.tree.map(
                        lambda m: jnp.where(is_g, jnp.zeros_like(m), m),
                        state.opt.mu),
                    nu=jax.tree.map(
                        lambda n: jnp.where(is_g, jnp.zeros_like(n), n),
                        state.opt.nu))
                new_res = (jax.tree.map(
                    lambda r: jnp.where(is_g, jnp.zeros_like(r), r),
                    outer.residual) if compress else None)
                new_res2 = (jax.tree.map(
                    lambda r: jnp.where(is_g, jnp.zeros_like(r), r),
                    outer.residual2) if compress2 else None)
                new_outer = OuterState(
                    momentum=outer.momentum, anchor=outer.anchor,
                    num_syncs=outer.num_syncs, residual=new_res,
                    residual2=new_res2)
                return TrainState(params=new_params, opt=new_opt), new_outer

        def bootstrap_fn(state, outer, g, donor):
            sspec, ospec = _sspec(), _ospec()
            donor_spec = jax.tree.map(lambda _: P(), pspec,
                                      is_leaf=lambda s: isinstance(s, P))
            f = compat.shard_map(
                bootstrap_body, mesh=mesh,
                in_specs=(sspec, ospec, P(), donor_spec, _coord_spec()),
                out_specs=(sspec, ospec),
                axis_names=set(manual))
            return f(state, outer, g, donor, _coord_inputs())

        # outer is NOT donated: the anchor-donor call passes outer.anchor
        # as ``donor`` too, and a donated buffer cannot also be a live
        # argument (f(donate(a), a)); bootstraps are rare, the copy is fine
        bootstrap_group = jax.jit(
            bootstrap_fn, donate_argnums=(0,),
            **_out_sh((state_shardings, outer_shardings)))

    # ---- eval --------------------------------------------------------------
    def eval_body(state, batch):
        with use_rules(rules):
            params = jax.tree.map(lambda x: x[0], state.params)
            loss, _ = R.loss_fn(params, mc, batch, use_pallas=pc.use_pallas)
            return jax.lax.pmean(loss, manual) if manual else loss

    def eval_fn(state, batch):
        sspec = TrainState(
            params=jax.tree.map(lambda _: P(manual), state_spec.params,
                                is_leaf=lambda s: isinstance(s, P)),
            opt=jax.tree.map(lambda _: P(manual), state_spec.opt,
                             is_leaf=lambda s: isinstance(s, P)))
        batch_specs = jax.tree.map(
            lambda x: P(manual, *([None] * (x.ndim - 1))), batch)
        f = compat.shard_map(eval_body, mesh=mesh,
                          in_specs=(sspec, batch_specs), out_specs=P(),
                          axis_names=set(manual))
        return f(state, batch)

    eval_step = jax.jit(eval_fn)

    return StepBundle(
        mesh=mesh, manual=manual, num_groups=G,
        strategy=strategy, plan=plan,
        pspec=pspec, stacked_pspec=stacked_pspec,
        state_shardings=state_shardings, outer_shardings=outer_shardings,
        batch_sharding=batch_sharding,
        init_state=init_state, init_outer=init_outer,
        inner_step=inner_step, warmup_step=warmup_step,
        accumulate_step=accumulate_step,
        accumulate_dispatch_step=accumulate_dispatch_step,
        outer_step=outer_step,
        dispatch_step=dispatch_step, apply_step=apply_step,
        eval_step=eval_step,
        chunk_dispatch_steps=chunk_dispatch_steps,
        chunk_apply_steps=chunk_apply_steps,
        stitch_outer=stitch_outer,
        init_residual=init_residual,
        elastic_outer_step=elastic_outer_step,
        elastic_dispatch_step=elastic_dispatch_step,
        elastic_apply_step=elastic_apply_step,
        bootstrap_group=bootstrap_group)


# ===========================================================================
# Serving (no group structure: plain GSPMD over the whole mesh)
# ===========================================================================


@dataclass
class ServeBundle:
    mesh: Mesh
    pspec: Any
    param_shardings: Any
    state_shardings: Any
    serve_step: Callable
    prefill_step: Callable
    init_state: Callable


def build_serve_steps(
    mc: ModelConfig, pc: ParallelConfig, mesh: Mesh, *,
    batch: int, max_len: int,
) -> ServeBundle:
    rules = pier_rules(
        have_pod="pod" in mesh.axis_names, fsdp=pc.fsdp,
        shard_experts=pc.shard_experts, inside_manual=False,
        context_parallel_seq=pc.context_parallel,
        axis_sizes=M.axis_sizes(mesh))

    pshapes = _param_shapes(mc, pc.scan_layers)
    pspec = S.param_specs(pshapes, mesh, pc)
    param_shardings = S.shardings(pspec, mesh)

    state_shapes = jax.eval_shape(
        lambda: R.init_decode_state(mc, batch, max_len,
                                    scan_layers=pc.scan_layers))
    sspec = S.decode_state_specs(
        state_shapes, mesh, pc, context_parallel=pc.context_parallel)
    state_shardings = S.shardings(sspec, mesh)

    # NOTE: MoE "indexed" dispatch was evaluated for serving (§Perf pair 3)
    # and REGRESSES memory 5.7x for a 16% collective win — serving stays on
    # the flat dispatch; see experiments/perf/SUMMARY.md.
    def serve(params, state, tokens):
        with use_rules(rules):
            return R.decode_step(params, mc, state, tokens)

    def prefill(params, batch_in):
        with use_rules(rules):
            logits, state = R.prefill(params, mc, batch_in, max_len=max_len,
                                      use_pallas=pc.use_pallas)
            # serving semantics: only the next-token logits leave the step
            return logits[:, -1:], state

    def init_state():
        return jax.jit(
            lambda: R.init_decode_state(mc, batch, max_len,
                                        scan_layers=pc.scan_layers),
            out_shardings=state_shardings)()

    # Serving is plain GSPMD (no shard_map); constraints need the mesh in
    # scope during trace -> wrap the jitted callables in jax.set_mesh.
    def _with_mesh(fn):
        def call(*args, **kw):
            with compat.mesh_context(mesh):
                return fn(*args, **kw)
        call.lower = lambda *a, **k: _lower_with_mesh(fn, mesh, *a, **k)
        return call

    def _lower_with_mesh(fn, mesh, *a, **k):
        with compat.mesh_context(mesh):
            return fn.lower(*a, **k)

    serve_step = _with_mesh(jax.jit(serve, donate_argnums=(1,)))
    prefill_step = _with_mesh(jax.jit(prefill))

    return ServeBundle(
        mesh=mesh, pspec=pspec, param_shardings=param_shardings,
        state_shardings=state_shardings, serve_step=serve_step,
        prefill_step=prefill_step, init_state=init_state)


# ===========================================================================
# Paged serving (continuous batching over a shared KV block pool, §12)
# ===========================================================================


@dataclass
class PagedServeBundle:
    """Jitted steps for the paged decode path (``repro.serve``).

    ``decode_step(params, pools, tokens, positions, block_tables,
    context_lens)`` donates the pools; ``prefill_step(params, tokens,
    pools, block_table, last_index)`` re-jits per padded prompt length —
    prompts are padded to a block multiple, so the bucket count is
    ``max_prompt / block_size``, not ``max_prompt``.
    """

    mesh: Mesh
    pspec: Any
    param_shardings: Any
    decode_step: Callable
    prefill_step: Callable
    init_pools: Callable


def build_paged_serve_steps(
    mc: ModelConfig, pc: ParallelConfig, mesh: Mesh, *, pcfg,
) -> PagedServeBundle:
    from repro.serve import kv_cache as KC
    from repro.serve import paged_model as PM

    rules = pier_rules(
        have_pod="pod" in mesh.axis_names, fsdp=pc.fsdp,
        shard_experts=pc.shard_experts, inside_manual=False,
        context_parallel_seq=pc.context_parallel,
        axis_sizes=M.axis_sizes(mesh))

    pshapes = _param_shapes(mc, scan_layers=False)  # paged path is unstacked
    pspec = S.param_specs(pshapes, mesh, pc)
    param_shardings = S.shardings(pspec, mesh)

    def decode(params, pools, tokens, positions, block_tables, context_lens):
        with use_rules(rules):
            return PM.paged_decode_step(
                params, mc, pools, tokens, positions, block_tables,
                context_lens, pcfg=pcfg)

    def prefill(params, tokens, pools, block_table, last_index):
        with use_rules(rules):
            logits, pools = PM.paged_prefill(
                params, mc, tokens, pools, block_table,
                pcfg=pcfg, use_pallas=pc.use_pallas)
            # serving semantics: only the last real token's logits leave
            # the step (``last_index`` skips the block-padding tail)
            last = jax.lax.dynamic_index_in_dim(logits, last_index, axis=1)
            return last[:, 0], pools

    def _with_mesh(fn):
        def call(*args, **kw):
            with compat.mesh_context(mesh):
                return fn(*args, **kw)
        return call

    decode_step = _with_mesh(jax.jit(decode, donate_argnums=(1,)))
    prefill_step = _with_mesh(jax.jit(prefill, donate_argnums=(2,)))
    init_pools = _with_mesh(jax.jit(lambda: KC.init_pools(mc, pcfg)))

    return PagedServeBundle(
        mesh=mesh, pspec=pspec, param_shardings=param_shardings,
        decode_step=decode_step, prefill_step=prefill_step,
        init_pools=init_pools)
