from repro.parallel.axes import (  # noqa: F401
    LogicalAxisRules,
    current_rules,
    logical_constraint,
    use_rules,
)
