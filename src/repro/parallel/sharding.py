"""Per-parameter PartitionSpec rules (Megatron TP × in-group FSDP × EP).

Rules are name-based over the param tree path, with a divisibility guard:
a dimension is only sharded if its size divides the mesh-axis size (e.g.
GQA kv-head projections with 8 kv heads fall back to replicated on a
16-wide model axis — recorded as a roofline consideration, not an error).

The same spec applies to AdamW moments and the Pier outer state (they mirror
the param tree). Group-stacked trees (leading G axis) get the manual axes
prepended via :func:`stack_spec`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig

# logical axes used in the tables below
TP = "tp"
FSDP = "fsdp"
EXP = "experts"


def _physical(logical: Optional[str], *, fsdp: bool, experts: bool):
    if logical is None:
        return None
    if logical == TP:
        return "model"
    if logical == FSDP:
        return "data_inner" if fsdp else None
    if logical == EXP:
        return "model" if experts else None
    raise ValueError(logical)


def _param_logical_spec(path_keys, shape) -> Tuple[Optional[str], ...]:
    """Logical axes per dim for one parameter, from its tree path."""
    name = path_keys[-1] if path_keys else ""
    in_moe = "mlp" in path_keys and len(shape) == 3  # stacked expert weights
    in_mlstm_qkv = name in ("wq", "wk", "wv") and len(shape) == 3

    # Embedding tables are *gathered* (jnp.take); sharding a gathered table
    # over the in-group FSDP axis trips an XLA SPMD-partitioner CHECK inside
    # partial-manual shard_map (spmd_partitioner_util.cc:504), so they are
    # TP-sharded only. Documented in DESIGN.md §Hardware-adaptation.
    if name == "tokens":  # (V, D) embedding
        return (TP, None)
    if name == "positions":  # (P, D)
        return (None, None)
    if name == "lm_head":  # (D, V)
        return (FSDP, TP)
    if name in ("scale", "bias") or name.startswith("b_"):
        return (None,) * len(shape)
    if name in ("q_norm", "k_norm", "kv_norm", "out_norm", "lambda"):
        return (None,) * len(shape)

    # ---- attention ----
    if name == "wq" and len(shape) == 3 and not in_mlstm_qkv:
        return (FSDP, TP, None)
    if name in ("wk", "wv") and len(shape) == 3 and not in_mlstm_qkv:
        return (FSDP, TP, None)
    if name == "wo":  # (H, hd, D)
        return (TP, None, FSDP)

    # ---- MLA ----
    if name in ("w_dq", "w_dkv", "w_kr"):  # (D, r)
        return (FSDP, None)
    if name in ("w_uq",):  # (r, H, d)
        return (None, TP, None)
    if name in ("w_uk", "w_uv"):  # (r, H, d)
        return (None, TP, None)

    # ---- MoE ----
    if in_moe and name in ("w_gate", "w_up"):  # (E, D, F)
        return (EXP, FSDP, None)
    if in_moe and name == "w_down":  # (E, F, D)
        return (EXP, None, FSDP)
    if name == "router":  # (D, E)
        return (FSDP, None)

    # ---- dense MLP ----
    if name in ("w_gate", "w_up"):  # (D, F)
        return (FSDP, TP)
    if name == "w_down":  # (F, D)
        return (TP, FSDP)

    # ---- mLSTM / sLSTM / RG-LRU ----
    if in_mlstm_qkv:  # (H, dh, dh) block-diagonal
        return (TP, None, None)
    if name == "conv":  # (W, C)
        return (None, TP)
    if name in ("w_igate", "w_fgate"):  # (Di, H)
        return (TP, None)
    if name in ("w_i", "w_f", "w_z", "w_o"):  # sLSTM (D, D)
        return (FSDP, TP)
    if name.startswith("r_"):  # (H, dh, dh)
        return (TP, None, None)
    if name in ("w_x", "w_y"):  # RG-LRU (D, W)
        return (FSDP, TP)
    if name in ("w_a",):  # (W, W)
        return (None, TP)
    # mLSTM w_up (D, 2Di) / w_down (Di or W, D)
    if name == "w_up" and len(shape) == 2:
        return (FSDP, TP)
    if name == "w_down" and len(shape) == 2:
        return (TP, FSDP)

    return (None,) * len(shape)


def param_spec(
    path_keys,
    shape,
    mesh_sizes: Dict[str, int],
    pc: ParallelConfig,
) -> P:
    logical = _param_logical_spec(tuple(path_keys), tuple(shape))
    phys = []
    for dim, lg in zip(shape, logical):
        ax = _physical(lg, fsdp=pc.fsdp, experts=pc.shard_experts)
        if ax is None or ax not in mesh_sizes or dim % mesh_sizes[ax] != 0:
            phys.append(None)
        else:
            phys.append(ax)
    return P(*phys)


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "name", None)
        if k is None:
            k = str(getattr(p, "idx", ""))
        out.append(str(k))
    return tuple(out)


def param_specs(params_shape, mesh: Mesh, pc: ParallelConfig):
    """PartitionSpec pytree for a (non-stacked) param/state tree.

    Scan-stacked segments (path contains "scan") carry a leading layer-cycle
    dimension which is never sharded: the per-layer spec shifts right by one.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        keys = _path_keys(path)
        if "scan" in keys and leaf.ndim >= 1:
            inner = param_spec(keys, leaf.shape[1:], sizes, pc)
            return P(None, *tuple(inner))
        return param_spec(keys, leaf.shape, sizes, pc)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def stack_spec(spec_tree, manual: Tuple[str, ...]):
    """Prepend the group axes to every spec (for G-stacked trees)."""
    return jax.tree.map(
        lambda s: P(manual, *tuple(s)), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# activations / batch / decode-state specs
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data_outer", "data_inner", "data")
                 if a in mesh.axis_names)
    return P(axes)


def decode_state_specs(state_shape, mesh: Mesh, pc: ParallelConfig,
                       *, context_parallel: bool = False):
    """Sharding for the serving state: KV caches over (batch|seq, heads)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = tuple(a for a in ("pod", "data_outer", "data_inner", "data")
                  if a in mesh.axis_names)
    dsize = int(np.prod([sizes[a] for a in daxes])) if daxes else 1

    def spec(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        keys = _path_keys(path)
        shape = leaf.shape
        if "scan" in keys and len(shape) >= 1:
            # stacked layer-cycle dim first: spec for shape[1:], then shift
            inner = spec_inner(keys, shape[1:])
            return P(None, *tuple(inner))
        return spec_inner(keys, shape)

    def spec_inner(keys, shape):
        name = keys[-1]
        # batch-first arrays
        batch_ok = shape[0] % dsize == 0 if len(shape) else False
        b = daxes if batch_ok else None
        msize = sizes.get("model", 1)

        def seq_axis(seq_dim, heads_sharded):
            """Context-parallel fallbacks for the cache sequence dim:
            over the data axes when the batch can't shard (long_500k), and
            over the model axis when the kv heads can't (GQA kv < model)."""
            if context_parallel and not batch_ok and seq_dim % dsize == 0:
                return daxes
            if not heads_sharded and seq_dim % msize == 0:
                return "model"
            return None

        if "cross_kv" in keys and len(shape) == 4:  # (B, S_enc, Hkv, hd)
            h = "model" if shape[2] % msize == 0 else None
            return P(b, None, h, None)
        if name in ("k", "v"):  # (B, S, Hkv, hd)
            h = "model" if shape[2] % msize == 0 else None
            return P(b, seq_axis(shape[1], h is not None), h, None)
        if name in ("ckv", "krope"):  # (B, S, r)
            return P(b, seq_axis(shape[1], False), None)
        if name == "pos":  # (B, S)
            return P(b, seq_axis(shape[1], False))
        if name == "conv":  # (B, W-1, C)
            c = "model" if shape[-1] % sizes.get("model", 1) == 0 else None
            return P(b, None, c)
        if name == "hidden":  # (B, W)
            c = "model" if shape[-1] % sizes.get("model", 1) == 0 else None
            return P(b, c)
        # mLSTM/sLSTM cell tuples: (B,H,dh,dh) / (B,H,dh) / (B,H)
        if len(shape) >= 2:
            rest = [None] * (len(shape) - 1)
            if len(shape) >= 3 and shape[1] % sizes.get("model", 1) == 0 \
                    and name not in ("pos",):
                rest[0] = "model"
            return P(b, *rest)
        return P()

    return jax.tree_util.tree_map_with_path(spec, state_shape)
