"""Logical-axis sharding rules and the activation-constraint hook.

Model code never mentions mesh axes. It annotates activations with *logical*
axes ("batch", "seq", "embed", "heads", "ffn", "experts", "vocab") via
:func:`logical_constraint`. Step builders install a :class:`LogicalAxisRules`
mapping logical axes to (tuples of) physical mesh axes; outside any rules
context (e.g. single-device unit tests) the hook is a no-op.

This is the same pattern MaxText/T5X use, reduced to what the Pier mesh needs:

    batch   -> (data_outer, data_inner)       # manual + auto data axes
    fsdp    -> data_inner                     # in-group ZeRO-3 sharding
    tp      -> model                          # Megatron tensor parallel
    experts -> model                          # expert parallel (MoE)
    seq     -> data_inner (decode long-context)  # context-parallel KV cache
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class LogicalAxisRules:
    """Mapping from logical axis names to physical mesh axes."""

    rules: Dict[str, AxisVal] = field(default_factory=dict)
    # physical axis name -> size, for divisibility guards (a dim is only
    # constrained if the axis size divides it — XLA's SPMD partitioner
    # CHECK-fails on some non-divisible scatter/gather shardings)
    axis_sizes: Dict[str, int] = field(default_factory=dict)
    # When False (e.g. a mesh axis is absent), constraints are skipped.
    enabled: bool = True

    def _fits(self, axes: AxisVal, dim: int) -> bool:
        if not self.axis_sizes:
            return True
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        size = 1
        for n in names:
            size *= self.axis_sizes.get(n, 1)
        return dim % size == 0

    def resolve_for_shape(self, shape, logical_axes) -> P:
        out = []
        for dim, ax in zip(shape, logical_axes):
            phys = None if ax is None else self.rules.get(ax)
            if phys is not None and not self._fits(phys, dim):
                phys = None
            out.append(phys)
        return P(*out)

    def resolve(self, *logical_axes: Optional[str]) -> P:
        out = []
        for ax in logical_axes:
            out.append(None if ax is None else self.rules.get(ax))
        return P(*out)


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[LogicalAxisRules] = None


_STATE = _State()


def current_rules() -> Optional[LogicalAxisRules]:
    return _STATE.rules


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalAxisRules]):
    prev = _STATE.rules
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def logical_constraint(x, *logical_axes: Optional[str]):
    """Apply ``with_sharding_constraint`` if rules are installed; else no-op.

    ``logical_axes`` has one entry per dimension of ``x`` (None = replicated /
    unconstrained dimension).
    """
    rules = _STATE.rules
    if rules is None or not rules.enabled:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"logical_constraint got {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    spec = rules.resolve_for_shape(x.shape, logical_axes)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError, RuntimeError):
        # No mesh in scope (eager single-device execution) -> no-op.
        return x


# ---------------------------------------------------------------------------
# Standard rule sets for the Pier mesh
# ---------------------------------------------------------------------------


def pier_rules(
    *,
    have_pod: bool,
    fsdp: bool = True,
    shard_experts: bool = True,
    inside_manual: bool = True,
    context_parallel_seq: bool = False,
    axis_sizes: Optional[Dict[str, int]] = None,
) -> LogicalAxisRules:
    """Rules for code running *inside* the shard_map manual region.

    Inside the manual region only the auto axes (data_inner, model) are
    visible to GSPMD, so "batch" maps to data_inner only; the data_outer/pod
    factor of the batch was already consumed by the shard_map in_specs.
    """
    batch: AxisVal
    if inside_manual:
        batch = "data_inner"
    else:
        names = (("pod",) if have_pod else ()) + ("data_outer", "data_inner")
        batch = names
    return LogicalAxisRules(
        rules={
            "batch": batch,
            "fsdp": "data_inner" if fsdp else None,
            "tp": "model",
            "experts": "model" if shard_experts else None,
            "seq": "data_inner" if context_parallel_seq else None,
        },
        axis_sizes=axis_sizes or {},
    )
