"""Generate experiments/perf/SUMMARY.md from the §Perf hillclimb records."""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

from benchmarks.hardware import TPU_V5E


def row(rec):
    key = next(iter(rec["fit"]))
    f = rec["fit"][key]
    mem = (f["argument_bytes_per_device"] + f["temp_bytes_per_device"]
           + f["output_bytes_per_device"]) / 2**30
    corr = mem - f.get("cpu_convert_artifact_bytes", 0) / 2**30
    e = rec["extrapolated"]
    coll = sum(e["collective_bytes"].values())
    return {
        "variant": rec["variant"],
        "mem": mem, "mem_corr": corr,
        "fits": corr <= 16.0,
        "flops": e["flops"],
        "hbm": e["bytes_accessed"],
        "coll_gib": coll / 2**30,
        "compute_s": e["flops"] / TPU_V5E.peak_flops,
        "memory_s": e["bytes_accessed"] / TPU_V5E.hbm_bw,
        "collective_s": coll / TPU_V5E.intra_group_bw,
        "coll_by_kind": {k: v / 2**30
                         for k, v in e["collective_bytes"].items()},
        "outer_coll_gib": (sum(rec["fit"].get("outer", {}).get(
            "collective_bytes", {}).values()) / 2**30
            if "outer" in rec["fit"] else None),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/perf")
    args = ap.parse_args(argv)
    pairs = defaultdict(list)
    for p in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(p))
        pairs[(rec["arch"], rec["shape"])].append(row(rec))
    lines = ["# §Perf hillclimb records (auto-generated)", ""]
    for (arch, shape), rows in pairs.items():
        lines.append(f"## {arch} × {shape}")
        lines.append("")
        lines.append("| variant | mem GiB/dev (corr) | fits 16G | "
                     "compute (ms) | memory (ms) | collective (ms) | "
                     "coll GiB/dev | Δ vs baseline |")
        lines.append("|---|---|---|---|---|---|---|---|")
        base = next((r for r in rows if r["variant"] == "baseline"), rows[0])
        for r in sorted(rows, key=lambda r: (r["variant"] != "baseline",
                                             r["variant"])):
            dmem = (r["mem_corr"] - base["mem_corr"]) / max(base["mem_corr"],
                                                            1e-9) * 100
            dcoll = (r["coll_gib"] - base["coll_gib"]) / max(base["coll_gib"],
                                                             1e-9) * 100
            lines.append(
                f"| {r['variant']} | {r['mem']:.1f} ({r['mem_corr']:.1f}) "
                f"| {'yes' if r['fits'] else 'NO'} "
                f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
                f"| {r['collective_s']*1e3:.1f} | {r['coll_gib']:.1f} "
                f"| mem {dmem:+.0f}% / coll {dcoll:+.0f}% |")
        lines.append("")
    out = "\n".join(lines)
    with open(os.path.join(args.dir, "SUMMARY.md"), "w") as f:
        f.write(out + "\n")
    print(out)


if __name__ == "__main__":
    main()
