"""Delayed outer sync: modeled (and optionally measured) step-time savings.

The eager outer step serializes the training loop: every ``r`` inner steps
the host blocks for the cross-group Δθ all-reduce. With ``sync_delay = d``
the collective dispatched at sync step t overlaps the next ``d`` inner
steps; only the remainder ``max(0, t_comm − d·t_inner)`` is exposed.

Per sync period (r inner steps + one outer event):

    T_eager(r)      = r·t_inner + t_comm + t_update
    T_overlap(r, d) = r·t_inner + max(0, t_comm − d·t_inner) + t_update

where ``t_inner`` is the modeled inner-step time (compute/HBM roofline +
in-group gradient all-reduce, as in benchmarks/speedup_model.py), ``t_comm``
the ring all-reduce of the Δθ payload across the slow domain, and
``t_update`` one fused HBM pass over θ/M/Δθ (kernels/pier_update.py).

``t_comm`` itself now carries the compressed hierarchical collective's
bytes-on-wire model (DESIGN.md §6):

- quantization (``--bits`` < 32) shrinks the payload to
  ``bits/8 + 4/block`` bytes per element (int values + per-block fp32
  absmax scales);
- hierarchical reduce (``--hierarchical --pods P``) moves the full-width
  fp32 reduce onto the fast intra-pod domain and only exchanges the
  (compressed) payload across ``P`` pod endpoints;
- chunked dispatch (``--comm-chunks C``) pipelines the fused-update /
  quantize work against the exchange: the dispatch critical path drops
  from ``t_comm + t_update`` to ``max(t_comm, t_update) + min(...)/C``.

Reports, per chip × model scale: cross-domain bytes per sync and their
reduction vs the flat fp32 ring, the exposed-comm fraction, the step-time
reduction from overlap at several delays, d* — the smallest delay that
fully hides the collective (smaller bytes => smaller d*) — and the
per-phase split (DESIGN.md §9): ``warmup_comm_fraction`` /
``inner_comm_fraction`` (cross-domain comm share of the step time in
each phase, the warmup accumulate overlapped like any other dispatch
under the unified event engine) with the matching
``*_bytes_cross_per_step`` fields. ``--json`` writes the rows as a
machine-readable summary (CI artifact; the bench-models job consumes the
per-phase fields). ``--measure``
additionally wall-clocks the real host loop (Trainer) at sync_delay 0 vs d
on CPU devices as a smoke check of the dispatch/apply machinery (CPU has
no async collective engine, so the measured delta there is bookkeeping
overhead, not the modeled win).
"""

from __future__ import annotations

import argparse
import json
import os
import warnings
from typing import Dict, List, Optional

from benchmarks.hardware import CHIPS, Chip

PAPER_MODELS = {
    "gpt2-small": 125e6,
    "gpt2-medium": 345e6,
    "gpt2-xl": 1.5e9,
    "gpt2-7b": 7e9,
}
TOKENS_PER_STEP = 512 * 1024  # paper: global batch 512, seq 1024


def _allreduce_t(bytes_: float, n: int, bw: float) -> float:
    if n <= 1:
        return 0.0
    return 2 * bytes_ * (n - 1) / n / bw


def inner_step_time(n_params: float, n_devices: int, chip: Chip,
                    group_size: int) -> float:
    """Modeled seconds per inner step (compute + in-group grad sync)."""
    flops = 6 * n_params * TOKENS_PER_STEP / n_devices
    t_compute = flops / chip.peak_flops
    t_hbm = (20 * n_params / n_devices) / chip.hbm_bw
    grad_bytes = n_params * 4.0
    t_inner_comm = _allreduce_t(grad_bytes, min(group_size, n_devices),
                                chip.intra_group_bw)
    return max(t_compute, t_hbm) + t_inner_comm


def payload_bytes_per_param(bits: int = 32, block: int = 256) -> float:
    """Bytes per Δθ element on the slow domain: values + per-block scales.

    bits >= 32 means the uncompressed fp32 payload. int4 is 2x nibble
    packing of the int8-held values — since DESIGN.md §8 the Int8Wire
    strategy really packs the wire that way (pack_wire), and the
    measured_* fields report the actual buffer sizes next to this model.
    """
    if bits >= 32:
        return 4.0
    return bits / 8.0 + 4.0 / block


def cross_domain_bytes(n_params: float, *, n_groups: int, pods: int = 1,
                       bits: int = 32, block: int = 256,
                       hierarchical: bool = False) -> float:
    """Total bytes crossing the slow domain per sync.

    A ring all-reduce of a P-byte payload over E endpoints moves
    ``2·P·(E−1)`` bytes through the domain. Flat: E = n_groups at full
    payload width. Hierarchical: the fp32 reduce happens intra-pod (fast
    domain, not counted here) and only E = pods endpoints exchange the
    compressed payload.
    """
    per = n_params * payload_bytes_per_param(bits, block)
    e = max(pods if hierarchical else n_groups, 1)
    return 2.0 * per * (e - 1)


def rs_ag_bytes_per_device(n_params: float, *, endpoints: int,
                           bits: int = 8,
                           block: int = 256) -> Dict[str, float]:
    """Modeled bytes SENT per device per sync on the rs-ag wire path.

    Each endpoint ships E−1 of its E payload slots on the reduce-scatter
    leg and its one re-quantized reduced slot to the E−1 peers on the
    all-gather leg: ``(E−1)/E · P_wire`` per leg, ``2·(E−1)/E · P_wire``
    total — versus the gather-based wire exchange's ``(E−1) · P_wire``
    per device (``measured_cross_domain_bytes``), a 2/E ratio.
    """
    per = n_params * payload_bytes_per_param(bits, block)
    e = max(int(endpoints), 1)
    leg = per * (e - 1) / e
    return {
        "rs_bytes_per_device": leg,
        "ag_bytes_per_device": leg,
        "rs_ag_bytes_per_device": 2.0 * leg,
    }


def outer_comm_time(n_params: float, n_devices: int, chip: Chip,
                    group_size: int, *, bits: int = 32, block: int = 256,
                    hierarchical: bool = False, pods: int = 1,
                    sharded: bool = False) -> float:
    """Ring all-reduce of the Δθ payload across the slow domain.

    Hierarchical: full-precision psum over the fast intra-pod domain first
    (costed at intra_group_bw), then the compressed exchange over the pod
    endpoints (inter_group_bw). Sharded (DESIGN.md §10): each of the
    ``group_size`` device lanes exchanges only its 1/group_size shard of
    the payload — the lanes run in parallel, so the exchange time divides
    by the shard count while the total wire traffic stays the same.
    """
    n_groups = max(n_devices // group_size, 1)
    per_param = payload_bytes_per_param(bits, block)
    shards = max(group_size, 1) if sharded else 1
    lane = n_params * per_param / shards
    if hierarchical and pods > 1:
        groups_per_pod = max(n_groups // pods, 1)
        t_intra = _allreduce_t(n_params * 4.0 / shards, groups_per_pod,
                               chip.intra_group_bw)
        t_cross = _allreduce_t(lane, pods, chip.inter_group_bw)
        return t_intra + t_cross
    return _allreduce_t(lane, n_groups, chip.inter_group_bw)


def outer_update_time(n_params: float, chip: Chip) -> float:
    """One fused pass over θ/M/Δθ (read 3, write 2 fp32 streams)."""
    return 5 * n_params * 4.0 / chip.hbm_bw


def period_times(n_params: float, n_devices: int, chip: Chip, *,
                 sync_interval: int, sync_delay: int,
                 group_size: int = 4, bits: int = 32, block: int = 256,
                 hierarchical: bool = False, pods: int = 1,
                 comm_chunks: int = 1, sharded: bool = False,
                 rs_ag: bool = False) -> Dict[str, float]:
    t_inner = inner_step_time(n_params, n_devices, chip, group_size)
    t_comm = outer_comm_time(n_params, n_devices, chip, group_size,
                             bits=bits, block=block,
                             hierarchical=hierarchical, pods=pods,
                             sharded=sharded)
    t_upd = outer_update_time(n_params, chip)
    if comm_chunks > 1:
        # chunked dispatch pipelines quantize/update against the exchange
        t_dispatch = (max(t_comm, t_upd)
                      + min(t_comm, t_upd) / comm_chunks)
    else:
        t_dispatch = t_comm + t_upd
    exposed = max(0.0, t_comm - sync_delay * t_inner)
    eager = sync_interval * t_inner + t_dispatch
    overlap = sync_interval * t_inner + exposed + (t_dispatch - t_comm)
    dstar = 0 if t_inner <= 0 else int(-(-t_comm // t_inner))  # ceil
    n_groups = max(n_devices // group_size, 1)
    bytes_cross = cross_domain_bytes(
        n_params, n_groups=n_groups, pods=pods, bits=bits, block=block,
        hierarchical=hierarchical)
    bytes_flat = cross_domain_bytes(n_params, n_groups=n_groups)

    # Per-phase accounting (DESIGN.md §9). Warmup trains globally synced:
    # the gradient pmean crosses the group boundary every step (fp32, the
    # in-group leg is already inside t_inner), plus one params-pmean
    # accumulate per sync_interval — under the unified event engine that
    # accumulate overlaps the next sync_delay steps exactly like an outer
    # dispatch. Those steps are WARMUP steps (t_inner + the grad pmean
    # each), so the hiding budget per overlapped step is the full warmup
    # step time. Inner phase: only the (compressed) outer sync every
    # sync_interval, with the row's delay hiding it.
    t_grad_cross = _allreduce_t(n_params * 4.0, n_groups,
                                chip.inter_group_bw)
    acc_exposed = max(0.0, t_grad_cross
                      - sync_delay * (t_inner + t_grad_cross))
    warmup_comm_per_step = t_grad_cross + acc_exposed / sync_interval
    warmup_step = t_inner + warmup_comm_per_step
    inner_comm_per_step = exposed / sync_interval
    inner_step = t_inner + inner_comm_per_step
    grad_cross_bytes = 2.0 * n_params * 4.0 * (n_groups - 1)
    shards = max(group_size, 1) if sharded else 1
    rs_fields = {}
    if rs_ag:
        # each sharded lane exchanges its 1/shards of the payload over
        # the same n_groups endpoints; total-bytes fields above already
        # match (E endpoints × 2·(E−1)/E·P == the 2·P·(E−1) ring total)
        rs_fields = rs_ag_bytes_per_device(
            n_params / shards, endpoints=n_groups, bits=bits, block=block)
    return {
        **rs_fields,
        "t_inner": t_inner, "t_comm": t_comm, "t_update": t_upd,
        "eager": eager, "overlap": overlap,
        "reduction": 1.0 - overlap / eager,
        "exposed_frac": exposed / max(t_comm, 1e-30),
        "d_star": min(dstar, sync_interval - 1),
        "bytes_cross_per_sync": bytes_cross,
        # sharded exchange: each device lane carries 1/shards of the
        # payload (the total above is the sum over lanes)
        "shards": shards,
        "per_device_bytes_cross_per_sync": bytes_cross / shards,
        "bytes_flat_fp32": bytes_flat,
        "bytes_reduction": bytes_flat / max(bytes_cross, 1e-30),
        # per-phase comm fractions + bytes (consumed by the bench-models
        # CI job): cross-domain comm time / total step time in each phase
        "warmup_comm_fraction": warmup_comm_per_step / max(warmup_step,
                                                           1e-30),
        "inner_comm_fraction": inner_comm_per_step / max(inner_step,
                                                         1e-30),
        "warmup_bytes_cross_per_step":
            grad_cross_bytes + grad_cross_bytes / sync_interval,
        "inner_bytes_cross_per_step": bytes_cross / sync_interval,
    }


def measured_wire_fields(n_params: float, *, endpoints: int, bits: int,
                         block: int, shards: int = 1) -> Dict[str, float]:
    """Measured (not modeled) wire bytes: run the real quantizer + packer
    (``repro.kernels.ring_allreduce``) and read the actual buffer sizes,
    scaled onto the same ring-traffic convention as the analytic model.
    ``shards > 1`` (the sharded exchange) measures at the per-device shard
    size — each lane quantizes and exchanges only n/shards elements — and
    reports the per-device cross bytes next to the all-lanes total.
    Empty when the runtime package is not importable (benchmarks-only
    deployment) — the modeled fields are then all there is.
    """
    try:
        from repro.kernels.ring_allreduce import (
            measure_wire_bytes, measured_cross_domain_bytes)
    except ImportError:
        return {}
    shards = max(int(shards), 1)
    n_shard = -(-int(n_params) // shards)  # ceil
    m = measure_wire_bytes(n_shard, bits=bits, block=block)
    per_device_cross = measured_cross_domain_bytes(
        n_shard, endpoints=endpoints, bits=bits, block=block)
    return {
        "measured_payload_bytes_per_param":
            m["measured_payload_bytes_per_param"],
        "measured_bytes_cross_per_sync": per_device_cross * shards,
        "measured_per_device_bytes_cross_per_sync": per_device_cross,
        "measured_sample_elems": m["measured_sample_elems"],
    }


def measured_rs_ag_fields(n_params: float, *, endpoints: int, bits: int,
                          block: int, shards: int = 1) -> Dict[str, float]:
    """Measured rs-ag wire bytes: run the real quantizer + per-slot
    packer (``shard_slot_wire``) and read the actual slot buffer sizes,
    scaled onto the bytes-sent-per-device convention of
    :func:`rs_ag_bytes_per_device`. Empty when the runtime package is not
    importable, like :func:`measured_wire_fields`.
    """
    try:
        from repro.kernels.ring_allreduce import measured_rs_ag_bytes
    except ImportError:
        return {}
    shards = max(int(shards), 1)
    n_shard = -(-int(n_params) // shards)  # ceil
    return measured_rs_ag_bytes(n_shard, endpoints=endpoints, bits=bits,
                                block=block)


def backend_fields() -> Dict[str, str]:
    """Which kernel backend / lane / wire transport produced these rows.

    ``backend``: the resolved KernelBackend name; ``kernel_lane``: its
    lane for the quantizer (the kernel every compressed strategy runs);
    ``transport``: the wire transport the int8 ring exchange resolves to
    on this backend (``kernels/ring_allreduce.resolve_transport``). Empty
    when the runtime package is not importable (benchmarks-only
    deployment), like :func:`measured_wire_fields`.
    """
    try:
        from repro.kernels.backend import kernel_lane, resolve_backend
        from repro.kernels.ring_allreduce import resolve_transport
    except ImportError:
        return {}
    return {
        "backend": resolve_backend().name,
        "kernel_lane": kernel_lane("quantize"),
        "transport": resolve_transport(axis_names=("data_outer",)),
    }


def resolve_sync_delay(*, n_params: float, n_devices: int, group_size: int,
                       sync_interval: int, chip: Optional[str] = None,
                       bits: int = 32, block: int = 256,
                       hierarchical: bool = False,
                       pods: int = 1) -> Optional[int]:
    """d* for ``sync_delay="auto"`` — the smallest delay that fully hides
    the (possibly compressed, hierarchical) outer collective. ``None``
    when the model has no estimate (no chip hint, or — with a warning
    rather than a mid-run crash — an unknown one; callers fall back to
    eager, d*=0)."""
    if not chip:
        return None
    if chip not in CHIPS:
        warnings.warn(
            f"unknown chip {chip!r} for sync_delay resolution "
            f"(known: {', '.join(sorted(CHIPS))}); falling back to "
            f"eager (d*=0)", stacklevel=2)
        return None
    r = period_times(
        n_params, n_devices, CHIPS[chip],
        sync_interval=sync_interval, sync_delay=0, group_size=group_size,
        bits=bits, block=block, hierarchical=hierarchical, pods=pods)
    return int(r["d_star"])


def sweep(chip_name: str, *, n_devices: int, sync_interval: int,
          delays: List[int], group_size: int, bits: int = 32,
          block: int = 256, hierarchical: bool = False, pods: int = 1,
          comm_chunks: int = 1, sharded: bool = False,
          rs_ag: bool = False) -> List[Dict]:
    chip = CHIPS[chip_name]
    n_groups = max(n_devices // group_size, 1)
    rows = []
    lane = backend_fields()  # one resolution for the whole sweep
    for model, n in PAPER_MODELS.items():
        # measured (not modeled) wire bytes ride on the reporting rows
        # only — the analytic resolve_sync_delay path must stay free of
        # device work (it runs at training startup)
        measured = measured_wire_fields(
            n, endpoints=(pods if hierarchical else n_groups),
            bits=bits, block=block,
            shards=(group_size if sharded else 1))
        if rs_ag:
            measured = {**measured, **measured_rs_ag_fields(
                n, endpoints=n_groups, bits=bits, block=block,
                shards=(group_size if sharded else 1))}
        for d in delays:
            r = period_times(n, n_devices, chip, sync_interval=sync_interval,
                            sync_delay=d, group_size=group_size,
                            bits=bits, block=block,
                            hierarchical=hierarchical, pods=pods,
                            comm_chunks=comm_chunks, sharded=sharded,
                            rs_ag=rs_ag)
            rows.append({"chip": chip_name, "model": model, "delay": d,
                         **lane, **measured, **r})
    return rows


def measure_host_loop(delay: int, steps: int = 24) -> Dict[str, float]:
    """Wall-clock the real Trainer at sync_delay 0 vs ``delay`` (CPU smoke)."""
    import time


    from repro.config import ModelConfig, ParallelConfig, TrainConfig
    from repro.data.pipeline import synthetic_pipeline
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    mc = ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                     d_ff=128, vocab_size=128, dtype="float32",
                     norm="layernorm", activation="gelu",
                     positional="learned", max_position_embeddings=64)
    out = {}
    sync_interval = max(4, delay + 1)  # sync_delay must be < sync_interval
    for d in (0, delay):
        tc = TrainConfig(optimizer="pier", total_steps=steps,
                         global_batch_size=4, seq_len=16,
                         sync_interval=sync_interval,
                         sync_delay=d, warmup_frac=0.25, seed=0)
        pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
        mesh = M.small_mesh((1, 1, 1),
                            ("data_outer", "data_inner", "model"))
        trainer = Trainer(mc, tc, pc, mesh)
        pipeline = synthetic_pipeline(mesh, M.data_axes(mesh), mc, tc)
        try:
            trainer.run(4, pipeline, log_every=0)  # compile warmup
            t0 = time.perf_counter()
            trainer.run(steps - 4, pipeline, log_every=0)
            out[f"measured_s_per_step_d{d}"] = (
                (time.perf_counter() - t0) / (steps - 4))
        finally:
            pipeline.close()
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--chips", nargs="*", default=list(CHIPS),
                    choices=list(CHIPS))
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--sync-interval", type=int, default=50)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--delays", type=int, nargs="*", default=[1, 2, 4, 8])
    ap.add_argument("--bits", type=int, default=32,
                    help="outer payload bits (32 = uncompressed fp32)")
    ap.add_argument("--block", type=int, default=256,
                    help="elements per fp32 absmax scale")
    ap.add_argument("--hierarchical", action="store_true",
                    help="two-stage reduce: fp32 intra-pod, compressed "
                         "cross-pod")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--comm-chunks", type=int, default=1)
    ap.add_argument("--sharded", action="store_true",
                    help="sharded outer exchange: each device lane carries "
                         "1/group_size of the payload (DESIGN.md §10)")
    ap.add_argument("--compression", default="",
                    choices=["", "none", "quantize", "int8-wire", "rs-ag"],
                    help="pin the wire format for the strategy name and "
                         "the rs-ag byte fields (default: inferred from "
                         "--bits the legacy way). rs-ag adds the modeled "
                         "and measured reduce-scatter/all-gather bytes "
                         "per device to every row (DESIGN.md §14)")
    ap.add_argument("--json", default="",
                    help="write the sweep rows to this JSON file")
    ap.add_argument("--measure", action="store_true",
                    help="also wall-clock the CPU host loop (slow)")
    ap.add_argument("--kernel-backend", default="",
                    choices=["", "auto", "tpu-mosaic", "gpu-triton",
                             "interpret", "jnp-ref"],
                    help="force the kernel lowering lane for the measured "
                         "fields (default: REPRO_KERNEL_BACKEND env var or "
                         "platform auto-detect)")
    args = ap.parse_args(argv)
    if args.kernel_backend:
        try:
            from repro.kernels.backend import set_kernel_backend
            set_kernel_backend(args.kernel_backend)
        except ImportError:  # benchmarks-only deployment without src/
            pass

    rs_ag = args.compression == "rs-ag"
    all_rows = []
    print("chip,model,delay,t_inner_ms,t_comm_ms,exposed_frac,"
          "eager_ms_per_period,overlap_ms_per_period,step_time_reduction,"
          "d_star,bytes_cross_mb,bytes_reduction")
    for chip in args.chips:
        for row in sweep(chip, n_devices=args.devices,
                         sync_interval=args.sync_interval,
                         delays=args.delays, group_size=args.group_size,
                         bits=args.bits, block=args.block,
                         hierarchical=args.hierarchical, pods=args.pods,
                         comm_chunks=args.comm_chunks,
                         sharded=args.sharded, rs_ag=rs_ag):
            all_rows.append(row)
            print(f"{row['chip']},{row['model']},{row['delay']},"
                  f"{row['t_inner']*1e3:.3f},{row['t_comm']*1e3:.3f},"
                  f"{row['exposed_frac']:.3f},{row['eager']*1e3:.2f},"
                  f"{row['overlap']*1e3:.2f},{row['reduction']*100:.2f}%,"
                  f"{row['d_star']},"
                  f"{row['bytes_cross_per_sync']/2**20:.1f},"
                  f"{row['bytes_reduction']:.2f}x")
    if args.measure:
        m = measure_host_loop(delay=max(args.delays))
        for k, v in m.items():
            print(f"{k},{v*1e3:.2f}ms")
    if args.json:
        try:  # name the resolved outer-sync strategy in the summary
            from repro.sync import strategy_name
            strategy = strategy_name(
                bits=args.bits, block=args.block,
                hierarchical=args.hierarchical, chunks=args.comm_chunks,
                sharded=args.sharded,
                compression=args.compression or None)
        except ImportError:  # benchmarks-only deployment without src/
            strategy = None
        except ValueError:  # bits the runtime has no strategy for (the
            strategy = None  # bytes model itself allows any width)
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({
                "config": {
                    "devices": args.devices, "group_size": args.group_size,
                    "sync_interval": args.sync_interval, "bits": args.bits,
                    "block": args.block, "hierarchical": args.hierarchical,
                    "pods": args.pods, "comm_chunks": args.comm_chunks,
                    "sharded": args.sharded,
                    "compression": args.compression or None,
                    "strategy": strategy,
                    **backend_fields(),
                },
                "rows": all_rows,
            }, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
