"""Outer wire-path baseline: fp32 vs int8 vs int8-wire vs rs-ag at E=4.

Produces ``BENCH_outer_wire.json`` — one row per wire path on the same
16-device / group-size-4 topology (4 ring endpoints), each carrying the
modeled AND measured (real quantize+pack buffers) bytes — and asserts
the DESIGN.md §14 byte model on the way out:

- the measured reduce-scatter + all-gather bytes per device match the
  ``2·(E−1)/E`` model within 5%;
- rs-ag ships at most 0.6× the per-device bytes of the gather-based
  int8 wire all-reduce at E=4 (exactly 2/E = 0.5× by construction:
  every endpoint forwards one slot per leg instead of (E−1) full
  payload replicas).

CI (bench-models) runs this and diffs nothing: the committed JSON at
the repo root is the reviewable baseline; regenerate it with

    PYTHONPATH=src python -m benchmarks.outer_wire_bench
"""

from __future__ import annotations

import argparse
import json

from benchmarks.overlap import sweep

CONFIG = dict(n_devices=16, sync_interval=50, group_size=4, delays=[2])
WIRE_ROWS = {
    "fp32": dict(bits=32, block=256),
    "int8": dict(bits=8, block=256),
    "int8-wire": dict(bits=8, block=256),
    "rs-ag": dict(bits=8, block=256, rs_ag=True),
}
ROW_FIELDS = [
    "strategy", "model", "delay", "d_star", "bytes_reduction",
    "bytes_cross_per_sync", "per_device_bytes_cross_per_sync",
    "measured_bytes_cross_per_sync",
    "measured_per_device_bytes_cross_per_sync",
    "measured_payload_bytes_per_param",
    "rs_bytes_per_device", "ag_bytes_per_device", "rs_ag_bytes_per_device",
    "measured_rs_bytes_per_device", "measured_ag_bytes_per_device",
    "measured_rs_ag_bytes_per_device", "measured_rs_ag_bytes_total",
    "backend", "kernel_lane", "transport",
]


def _strategy_name(name: str, bits: int, block: int) -> str:
    from repro.sync import strategy_name

    compression = {"fp32": "none", "int8": "quantize"}.get(name, name)
    return strategy_name(bits=bits, block=block, compression=compression)


def collect(chip: str = "tpu-v5e", model: str = "gpt2-small") -> dict:
    rows = {}
    for name, kw in WIRE_ROWS.items():
        kw = dict(kw)
        rs_ag = kw.pop("rs_ag", False)
        strategy = _strategy_name(name, kw["bits"], kw["block"])
        for r in sweep(chip, rs_ag=rs_ag, **kw, **CONFIG):
            if r["model"] == model:
                row = {"strategy": strategy, **r}
                rows[name] = {k: row[k] for k in ROW_FIELDS if k in row}
    return {
        "config": {"chip": chip, "model": model,
                   "endpoints": CONFIG["n_devices"] // CONFIG["group_size"],
                   **CONFIG},
        "rows": rows,
    }


def check(summary: dict) -> None:
    rows = summary["rows"]
    rs = rows["rs-ag"]
    # measured rs/ag bytes (real quantize + pack + slot buffers) track
    # the 2·(E−1)/E analytic model within 5%
    ratio = rs["measured_rs_ag_bytes_per_device"] / rs["rs_ag_bytes_per_device"]
    assert abs(ratio - 1) < 0.05, (ratio, rs)
    # the existing *_per_device_bytes_cross_per_sync fields use the
    # ring-TOTAL convention 2·(E−1)·P; per-device bytes SENT by the
    # gather-based wire all-reduce is half that ((E−1)·P per leg pair)
    wire_sent = rows["int8-wire"]["measured_per_device_bytes_cross_per_sync"] / 2
    assert rs["measured_rs_ag_bytes_per_device"] <= 0.6 * wire_sent, (
        rs["measured_rs_ag_bytes_per_device"], wire_sent)
    # same total traffic as the bandwidth-optimal ring => same t_comm model
    assert abs(rs["measured_rs_ag_bytes_total"]
               / rs["bytes_cross_per_sync"] - 1) < 0.05
    print(f"rs-ag measured/model={ratio:.4f} "
          f"per-device {rs['measured_rs_ag_bytes_per_device']:.0f} "
          f"<= 0.6 x wire-sent {wire_sent:.0f} "
          f"({rs['measured_rs_ag_bytes_per_device'] / wire_sent:.3f}x)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_outer_wire.json")
    ap.add_argument("--chip", default="tpu-v5e")
    ap.add_argument("--model", default="gpt2-small")
    args = ap.parse_args(argv)
    summary = collect(chip=args.chip, model=args.model)
    check(summary)
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
