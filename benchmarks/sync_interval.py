"""Paper Table IV: convergence vs outer synchronization interval.

The paper's finding: validation loss is insensitive to H in {50..500}.
Here the proportional sweep (H in {5,10,20,50} at CPU scale, i.e. the same
H/T ratios) tests the same property.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.config import TrainConfig
from repro.core.simulate import SimulatedRun
from benchmarks.convergence import model_cfg


def run(size="tiny", steps=400, intervals=(5, 10, 20, 50), groups=4, seed=0,
        out_dir="experiments/sync_interval"):
    mc = model_cfg(size)
    rows = []
    for h in intervals:
        tc = TrainConfig(
            optimizer="pier", total_steps=steps, global_batch_size=32,
            seq_len=64, sync_interval=h, inner_lr=1e-3, inner_min_lr=1e-4,
            seed=seed)
        r = SimulatedRun(mc, tc, num_groups=groups, seed=seed)
        hist = r.run(steps, eval_every=max(steps // 10, 1))
        rows.append({"interval": h, "final_val_loss": hist["val_loss"][-1]})
        print(f"  H={h:3d} val={rows[-1]['final_val_loss']:.4f}", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"sync_interval_{size}.json"), "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny")
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args(argv)
    run(args.size, args.steps)


if __name__ == "__main__":
    main()
