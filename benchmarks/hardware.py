"""Hardware constants for the roofline / speedup models.

TPU v5e (the reproduction target, from the assignment):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

A100/GH200 parameters (the paper's clusters, for the speedup-projection
benchmark that mirrors Figs. 5-8):
    Perlmutter: 4xA100-40GB/node, NVLink3 intra-node (600 GB/s), Slingshot-11
    (4x25 GB/s NICs/node). Vista: GH200/node, NVLink-C2C, IB NDR 400 Gbps.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float  # /s (bf16)
    hbm_bw: float  # B/s
    intra_group_bw: float  # B/s per device, fast domain
    inter_group_bw: float  # B/s per device, slow/global domain
    hbm_bytes: float


TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    intra_group_bw=50e9,  # ICI per link
    inter_group_bw=25e9,  # pod-crossing / DCN effective per device
    hbm_bytes=16 * 2**30,
)

A100_PERLMUTTER = Chip(
    name="a100-perlmutter",
    peak_flops=312e12,  # bf16 dense
    hbm_bw=1555e9,
    intra_group_bw=300e9,  # NVLink3 effective per GPU
    inter_group_bw=12.5e9,  # Slingshot-11 per-GPU share (4x25GB/s / 4 GPUs / 2 dir)
    hbm_bytes=40 * 2**30,
)

GH200_VISTA = Chip(
    name="gh200-vista",
    peak_flops=989e12,
    hbm_bw=4000e9,
    intra_group_bw=450e9,  # NVLink-C2C
    inter_group_bw=25e9,  # IB NDR 400 Gbps / 2 dir
    hbm_bytes=96 * 2**30,
)

CHIPS = {c.name: c for c in (TPU_V5E, A100_PERLMUTTER, GH200_VISTA)}
