"""Paper Figs. 1 & 3 (+ Table II proxy): validation-loss comparison of
AdamW / DiLoCo / Pier at matched token budgets on the synthetic Markov LM.

The paper's claim to validate: DiLoCo (no lazy start, fixed outer lr)
degrades relative to AdamW; Pier (momentum warmup + decay + outer-LR
schedule) recovers AdamW-level validation loss. Scales are CPU-sized but the
*algorithmic* structure (group counts, sync interval, schedules) is exact.

``--sweep-compression`` runs the loss-vs-bytes trade-off instead: Pier on
the reduced GPT-2 config across ``outer_comm_bits × sync_delay`` (32 =
uncompressed fp32; 8/4 = blockwise-quantized Δθ with error feedback), each
cell annotated with the modeled cross-domain bytes per sync from
benchmarks/overlap.py — the table ROADMAP's compression sweep asks for.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.config import ModelConfig, TrainConfig
from repro.core.simulate import SimulatedRun


def model_cfg(size: str) -> ModelConfig:
    dims = {"tiny": (2, 128, 4, 256), "small": (4, 256, 4, 512),
            "medium": (6, 384, 6, 768)}
    L, D, H, F = dims[size]
    return ModelConfig(
        name=f"gpt2-bench-{size}", num_layers=L, d_model=D, num_heads=H,
        num_kv_heads=H, d_ff=F, vocab_size=512, norm="layernorm",
        activation="gelu", positional="learned",
        max_position_embeddings=256, dtype="float32")


def run(size="tiny", steps=400, groups=4, interval=10, seed=0,
        out_dir="experiments/convergence"):
    mc = model_cfg(size)
    results = {}
    curves = {}
    for opt in ("adamw", "diloco", "pier"):
        tc = TrainConfig(
            optimizer=opt, total_steps=steps, global_batch_size=32,
            seq_len=64, sync_interval=interval, inner_lr=1e-3,
            inner_min_lr=1e-4, seed=seed,
            lazy_start=(opt != "diloco"),
            momentum_warmup=(opt == "pier"))
        t0 = time.time()
        r = SimulatedRun(mc, tc, num_groups=(1 if opt == "adamw" else groups),
                         seed=seed)
        hist = r.run(steps, eval_every=max(steps // 20, 1))
        results[opt] = {
            "final_val_loss": hist["val_loss"][-1],
            "best_val_loss": min(hist["val_loss"]),
            "final_train_loss": hist["train_loss"][-1],
            "seconds": time.time() - t0,
        }
        curves[opt] = {"step": hist["val_step"], "val_loss": hist["val_loss"]}
        print(f"  {opt:8s} final_val={results[opt]['final_val_loss']:.4f} "
              f"({results[opt]['seconds']:.0f}s)", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    payload = {"size": size, "steps": steps, "groups": groups,
               "interval": interval, "results": results, "curves": curves}
    with open(os.path.join(out_dir, f"convergence_{size}_{steps}.json"),
              "w") as f:
        json.dump(payload, f, indent=2)
    return payload


def sweep_compression(arch="gpt2-small", steps=300, groups=4, interval=10,
                      delays=(0, 2), bits_list=(32, 8, 4), seed=0,
                      out_dir="experiments/convergence"):
    """Loss-vs-bytes trade-off: outer_comm_bits × sync_delay on the reduced
    GPT-2 config. Returns the rows (also printed as a table + JSON)."""
    from benchmarks.overlap import cross_domain_bytes
    from repro.configs import get_reduced_config

    mc = get_reduced_config(arch)
    n_params = mc.param_count()
    rows = []
    print(f"# compression sweep: {mc.name} ({n_params/1e6:.2f}M params), "
          f"{groups} groups, r={interval}, {steps} steps")
    print("bits,delay,final_val_loss,best_val_loss,bytes_cross_per_sync_mb,"
          "bytes_vs_fp32,seconds")
    for bits in bits_list:
        for d in delays:
            tc = TrainConfig(
                optimizer="pier", total_steps=steps, global_batch_size=32,
                seq_len=64, sync_interval=interval, sync_delay=d,
                inner_lr=1e-3, inner_min_lr=1e-4, seed=seed,
                outer_compression="none" if bits >= 32 else "quantize",
                outer_comm_bits=bits if bits < 32 else 8)
            t0 = time.time()
            r = SimulatedRun(mc, tc, num_groups=groups, seed=seed)
            hist = r.run(steps, eval_every=max(steps // 10, 1))
            r.flush()
            bytes_cross = cross_domain_bytes(
                n_params, n_groups=groups, bits=bits,
                block=tc.outer_comm_block)
            bytes_flat = cross_domain_bytes(n_params, n_groups=groups)
            row = {
                "bits": bits, "delay": d,
                "final_val_loss": hist["val_loss"][-1],
                "best_val_loss": min(hist["val_loss"]),
                "bytes_cross_per_sync": bytes_cross,
                "bytes_vs_fp32": bytes_flat / bytes_cross,
                "seconds": time.time() - t0,
            }
            rows.append(row)
            print(f"{bits},{d},{row['final_val_loss']:.4f},"
                  f"{row['best_val_loss']:.4f},{bytes_cross/2**20:.2f},"
                  f"{row['bytes_vs_fp32']:.2f}x,{row['seconds']:.0f}",
                  flush=True)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"compression_sweep_{arch}_{steps}.json")
    with open(path, "w") as f:
        json.dump({"arch": arch, "steps": steps, "groups": groups,
                   "interval": interval, "n_params": n_params,
                   "rows": rows}, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweep-compression", action="store_true",
                    help="run the outer_comm_bits × sync_delay "
                         "loss-vs-bytes sweep instead")
    ap.add_argument("--arch", default="gpt2-small",
                    help="reduced config for --sweep-compression")
    ap.add_argument("--delays", type=int, nargs="*", default=[0, 2])
    ap.add_argument("--bits", type=int, nargs="*", default=[32, 8, 4])
    args = ap.parse_args(argv)
    if args.sweep_compression:
        sweep_compression(args.arch, args.steps, args.groups, args.interval,
                          tuple(args.delays), tuple(args.bits), args.seed)
        return
    payload = run(args.size, args.steps, args.groups, args.interval,
                  args.seed)
    r = payload["results"]
    print(json.dumps({k: v["final_val_loss"] for k, v in r.items()},
                     indent=2))


if __name__ == "__main__":
    main()
