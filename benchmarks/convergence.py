"""Paper Figs. 1 & 3 (+ Table II proxy): validation-loss comparison of
AdamW / DiLoCo / Pier at matched token budgets on the synthetic Markov LM.

The paper's claim to validate: DiLoCo (no lazy start, fixed outer lr)
degrades relative to AdamW; Pier (momentum warmup + decay + outer-LR
schedule) recovers AdamW-level validation loss. Scales are CPU-sized but the
*algorithmic* structure (group counts, sync interval, schedules) is exact.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.config import ModelConfig, TrainConfig
from repro.core.simulate import SimulatedRun


def model_cfg(size: str) -> ModelConfig:
    dims = {"tiny": (2, 128, 4, 256), "small": (4, 256, 4, 512),
            "medium": (6, 384, 6, 768)}
    L, D, H, F = dims[size]
    return ModelConfig(
        name=f"gpt2-bench-{size}", num_layers=L, d_model=D, num_heads=H,
        num_kv_heads=H, d_ff=F, vocab_size=512, norm="layernorm",
        activation="gelu", positional="learned",
        max_position_embeddings=256, dtype="float32")


def run(size="tiny", steps=400, groups=4, interval=10, seed=0,
        out_dir="experiments/convergence"):
    mc = model_cfg(size)
    results = {}
    curves = {}
    for opt in ("adamw", "diloco", "pier"):
        tc = TrainConfig(
            optimizer=opt, total_steps=steps, global_batch_size=32,
            seq_len=64, sync_interval=interval, inner_lr=1e-3,
            inner_min_lr=1e-4, seed=seed,
            lazy_start=(opt != "diloco"),
            momentum_warmup=(opt == "pier"))
        t0 = time.time()
        r = SimulatedRun(mc, tc, num_groups=(1 if opt == "adamw" else groups),
                         seed=seed)
        hist = r.run(steps, eval_every=max(steps // 20, 1))
        results[opt] = {
            "final_val_loss": hist["val_loss"][-1],
            "best_val_loss": min(hist["val_loss"]),
            "final_train_loss": hist["train_loss"][-1],
            "seconds": time.time() - t0,
        }
        curves[opt] = {"step": hist["val_step"], "val_loss": hist["val_loss"]}
        print(f"  {opt:8s} final_val={results[opt]['final_val_loss']:.4f} "
              f"({results[opt]['seconds']:.0f}s)", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    payload = {"size": size, "steps": steps, "groups": groups,
               "interval": interval, "results": results, "curves": curves}
    with open(os.path.join(out_dir, f"convergence_{size}_{steps}.json"),
              "w") as f:
        json.dump(payload, f, indent=2)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny",
                    choices=["tiny", "small", "medium"])
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    payload = run(args.size, args.steps, args.groups, args.interval,
                  args.seed)
    r = payload["results"]
    print(json.dumps({k: v["final_val_loss"] for k, v in r.items()},
                     indent=2))


if __name__ == "__main__":
    main()
