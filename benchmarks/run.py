"""Benchmark entry point: one benchmark per paper table/figure.

``python -m benchmarks.run``            — quick profile (CI-sized)
``python -m benchmarks.run --full``     — longer convergence runs

Prints ``name,us_per_call,derived`` CSV lines per the harness convention;
full artifacts land under experiments/.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    steps = 800 if args.full else 150
    rows = []

    def timed(name, fn, derive):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        rows.append((name, us, derive(out)))
        return out

    # --- Fig. 1/3 + Table II proxy: convergence of AdamW / DiLoCo / Pier ---
    from benchmarks import convergence
    print("[convergence] (paper Figs. 1&3)", flush=True)
    conv = timed(
        "convergence_fig3",
        lambda: convergence.run(size="tiny", steps=steps, groups=4,
                                interval=10),
        lambda p: ";".join(
            f"{k}={v['final_val_loss']:.4f}" for k, v in p["results"].items()))

    # --- Fig. 4 / Table III: weak scaling over global batch ---
    from benchmarks import weak_scaling
    print("[weak_scaling] (paper Fig. 4 / Table III)", flush=True)
    token_budget = steps * 32 * 64
    timed("weak_scaling_tab3",
          lambda: weak_scaling.run(size="tiny", token_budget=token_budget,
                                   batches=(16, 32, 64)),
          lambda rows_: ";".join(
              f"b{r['global_batch']}={r['final_val_loss']:.4f}"
              for r in rows_))

    # --- Table IV: sync-interval sweep ---
    from benchmarks import sync_interval
    print("[sync_interval] (paper Table IV)", flush=True)
    timed("sync_interval_tab4",
          lambda: sync_interval.run(size="tiny", steps=steps,
                                    intervals=(5, 10, 25)),
          lambda rows_: ";".join(
              f"H{r['interval']}={r['final_val_loss']:.4f}" for r in rows_))

    # --- Figs. 5-8: runtime speedup projection ---
    from benchmarks import speedup_model
    print("[speedup_model] (paper Figs. 5-8)", flush=True)
    timed("speedup_fig5to8",
          lambda: speedup_model.main([]),
          lambda all_rows: "gpt2-xl_a100x256_speedup=%.2f" % (
              all_rows["gpt2-xl__a100-perlmutter"][-1]["speedup"]))

    # --- kernels ---
    from benchmarks import kernels_bench
    print("[kernels]", flush=True)
    for name, us, derived in kernels_bench.main(["--reps", "2"]):
        rows.append((name, us, derived))

    # --- §Roofline table from the dry-run records ---
    if not args.skip_roofline:
        import os
        from benchmarks import roofline
        if os.path.isdir("experiments/dryrun") and \
                len(os.listdir("experiments/dryrun")) > 0:
            print("[roofline] (from dry-run records)", flush=True)
            rl_rows = roofline.main(["--dryrun-dir", "experiments/dryrun",
                                     "--out", "experiments/roofline"])
            dominated = {}
            for r in rl_rows:
                if not r.skipped:
                    dominated[r.dominant] = dominated.get(r.dominant, 0) + 1
            rows.append(("roofline_table", 0.0,
                         ";".join(f"{k}={v}" for k, v in dominated.items())))
        else:
            print("[roofline] skipped (no dry-run records; run "
                  "python -m repro.launch.dryrun --all first)", flush=True)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
