"""Serving benchmark: static vs continuous batching on a mixed-length trace.

Runs the same synthetic request trace (mixed prompt lengths AND mixed
output lengths — the imbalance continuous batching exists to exploit)
through the ``repro.serve`` engine twice: once with ``continuous=False``
(static batching: a whole wave of ``max_slots`` requests must drain
before the next wave is admitted, so finished slots idle behind the
longest request) and once with continuous batching (slots are refilled
the moment they free). Reports tokens/s, p50/p99 per-token decode
latency, time-to-first-token, and KV-pool occupancy.

    PYTHONPATH=src python -m benchmarks.serve_bench \
        --json experiments/bench/serve_bench.json

CPU interpret-scale numbers: the point is the *ratio* between the two
policies under identical compiled steps, not absolute throughput.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.config import ParallelConfig
from repro.configs import get_reduced_config
from repro.launch import mesh as M
from repro.models import registry as R
from repro.parallel.steps import build_paged_serve_steps
from repro.serve import kv_cache as KC
from repro.serve.engine import EngineConfig, ServeEngine


def _make_trace(rng, n, *, prompt_lens, output_lens):
    return [(rng.integers(0, 512, size=int(rng.integers(*prompt_lens))),
             int(rng.integers(*output_lens))) for _ in range(n)]


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _run(policy, params, cfg, bundle, pcfg, trace, *, max_slots, table_width):
    engine = ServeEngine(params, cfg, bundle, pcfg, EngineConfig(
        max_slots=max_slots, continuous=(policy == "continuous"),
        max_blocks_per_seq=table_width))
    for prompt, n_out in trace:
        engine.submit(prompt, n_out)
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    per_token = []  # decode intervals (excludes TTFT)
    ttft = []
    for r in results:
        ttft.append(r.first_token_at - r.admitted_at)
        per_token.extend(np.diff(r.token_times).tolist())
    tokens = sum(len(r.tokens) for r in results)
    usable = pcfg.num_blocks - 1
    return {
        "policy": policy,
        "requests": len(results),
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / max(wall, 1e-9),
        "decode_steps": engine.stats["decode_steps"],
        "prefills": engine.stats["prefills"],
        "p50_token_latency_ms": _percentile(per_token, 50) * 1e3,
        "p99_token_latency_ms": _percentile(per_token, 99) * 1e3,
        "mean_ttft_ms": float(np.mean(ttft)) * 1e3,
        "peak_pool_occupancy": engine.stats["peak_blocks"] / usable,
        "pool_blocks": usable,
        "pool_bytes": KC.pool_nbytes(cfg, pcfg),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", help="write results to this path")
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch)
    mesh = M.small_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    params = jax.jit(lambda k: R.init_params(k, cfg))(jax.random.PRNGKey(0))

    rng = np.random.default_rng(args.seed)
    # mixed lengths: prompts 4..20 tokens, outputs 3..14 tokens
    trace = _make_trace(rng, args.requests,
                        prompt_lens=(4, 21), output_lens=(3, 15))
    bs = args.block_size
    worst = max(-(-len(p) // bs) * bs + n for p, n in trace)
    table_width = -(-worst // bs)
    pcfg = KC.PagedCacheConfig(
        num_blocks=table_width * args.max_slots + 1, block_size=bs,
        quantized=args.int8_kv)
    bundle = build_paged_serve_steps(cfg, pc, mesh, pcfg=pcfg)

    # untimed warmup: compile the decode step and every prefill length
    # bucket so both timed runs see warm caches (otherwise whichever
    # policy runs first eats the compiles and the ratio is meaningless)
    _run("continuous", params, cfg, bundle, pcfg, trace,
         max_slots=args.max_slots, table_width=table_width)

    rows = []
    for policy in ("static", "continuous"):
        row = _run(policy, params, cfg, bundle, pcfg, trace,
                   max_slots=args.max_slots, table_width=table_width)
        rows.append(row)
        print(f"{policy:>10}: {row['tokens_per_s']:.2f} tok/s "
              f"({row['tokens']} tokens, {row['decode_steps']} decode steps, "
              f"p50 {row['p50_token_latency_ms']:.1f} ms, "
              f"p99 {row['p99_token_latency_ms']:.1f} ms, "
              f"peak pool {row['peak_pool_occupancy']:.0%})")

    static, cont = rows
    speedup = cont["tokens_per_s"] / max(static["tokens_per_s"], 1e-9)
    print(f"continuous/static speedup: {speedup:.2f}x "
          f"(decode steps {static['decode_steps']} -> "
          f"{cont['decode_steps']})")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {
            "config": {
                "arch": cfg.name, "requests": args.requests,
                "max_slots": args.max_slots, "block_size": bs,
                "int8_kv": args.int8_kv, "seed": args.seed,
            },
            "rows": rows,
            "speedup": speedup,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
