"""§Roofline: derive the three-term roofline per (arch × shape) from the
dry-run records (single-pod mesh) and emit the analysis table.

    compute term    = HLO_FLOPs(dev)        / peak_FLOP/s
    memory term     = HLO_bytes(dev)        / HBM_bw
    collective term = collective_bytes(dev) / link_bw

For train shapes the collective term is Pier's *effective* per-step cost:
inner-step collectives (intra-group links) + outer-step collectives / H
(H = 50, the paper's default sync interval), reported next to the AdamW
baseline (warmup-step collectives every step). FLOPs/bytes come from the
depth-extrapolated cost compiles (exact; see dryrun.py docstring).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from benchmarks.hardware import TPU_V5E

H_DEFAULT = 50  # paper's default sync interval for amortizing the outer step
SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _canon_arch(a: str) -> str:
    return a.replace("qwen3-1-7b", "qwen3-1.7b").replace(
        "xlstm-1-3b", "xlstm-1.3b")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    skipped: Optional[str] = None
    flops_dev: float = 0.0
    hbm_bytes_dev: float = 0.0
    coll_bytes_dev: float = 0.0
    coll_bytes_baseline: float = 0.0  # AdamW (train only)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    collective_s_baseline: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    mem_gib_dev: float = 0.0
    mem_gib_corrected: float = 0.0
    fits_16g: bool = False
    note: str = ""


def _sum_coll(d: Dict[str, float]) -> float:
    return float(sum(d.values()))


def load_record(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def analyze_record(rec: dict, chip=TPU_V5E, h: int = H_DEFAULT) -> RooflineRow:
    arch = _canon_arch(rec["arch"])
    row = RooflineRow(arch=arch, shape=rec["shape"])
    if "skipped" in rec:
        row.skipped = rec["skipped"]
        return row
    cfg = rec["config"]
    fit = rec["fit"]
    key = "inner" if "inner" in fit else next(iter(fit))
    fr = fit[key]
    mem = fr["argument_bytes_per_device"] + fr["temp_bytes_per_device"] \
        + fr["output_bytes_per_device"]
    corr = fr.get("cpu_convert_artifact_bytes", 0)
    row.mem_gib_dev = mem / 2**30
    row.mem_gib_corrected = max(mem - corr, 0) / 2**30
    row.fits_16g = row.mem_gib_corrected <= 16.0

    ext = rec.get("extrapolated")
    if ext:
        row.flops_dev = ext["flops"]
        row.hbm_bytes_dev = ext["bytes_accessed"]
        coll = ext["collective_bytes"]
    else:
        row.flops_dev = fr["flops"]
        row.hbm_bytes_dev = fr["bytes_accessed"]
        coll = fr["collective_bytes"]
        row.note = "fit-compile cost (scan bodies undercounted)"
    row.coll_bytes_dev = _sum_coll(coll)

    if "outer" in fit:
        # Pier effective collectives = inner + outer/H; baseline = warmup
        outer_coll = _sum_coll(fit["outer"]["collective_bytes"])
        row.coll_bytes_dev += outer_coll / h
        if "warmup" in fit:
            # warmup per-layer collectives ~= inner's + grad allreduce; use
            # measured fit-compile values, scaled by the inner ext/fit ratio
            fit_inner = _sum_coll(fit["inner"]["collective_bytes"])
            scale = (row.coll_bytes_dev - outer_coll / h) / max(fit_inner, 1.0)
            row.coll_bytes_baseline = \
                _sum_coll(fit["warmup"]["collective_bytes"]) * max(scale, 1.0)

    row.compute_s = row.flops_dev / chip.peak_flops
    row.memory_s = row.hbm_bytes_dev / chip.hbm_bw
    row.collective_s = row.coll_bytes_dev / chip.intra_group_bw
    row.collective_s_baseline = (
        row.coll_bytes_baseline / chip.intra_group_bw
        if row.coll_bytes_baseline else 0.0)
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N_active*tokens (train) / 2*N_active*tokens (inference)
    tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = cfg["active_params"]
    mult = 6 if rec["shape"] == "train_4k" else 2
    row.model_flops = mult * n_active * tokens
    chips = 256
    total_hlo = row.flops_dev * chips
    row.useful_ratio = row.model_flops / total_hlo if total_hlo else 0.0
    return row


def load_rows(dryrun_dir: str, mesh: str = "single") -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        rows.append(analyze_record(load_record(path)))
    order = {s: i for i, s in enumerate(SHAPE_ORDER)}
    rows.sort(key=lambda r: (r.arch, order.get(r.shape, 9)))
    return rows


def to_markdown(rows: List[RooflineRow]) -> str:
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) "
           "| AdamW coll. (ms) | dominant | useful FLOP ratio | mem GiB/dev "
           "(corr.) | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.skipped:
            out.append(f"| {r.arch} | {r.shape} | — | — | — | — | skipped | "
                       f"— | — | {r.skipped[:60]} |")
            continue
        base = (f"{r.collective_s_baseline*1e3:.2f}"
                if r.collective_s_baseline else "—")
        out.append(
            f"| {r.arch} | {r.shape} | {r.compute_s*1e3:.2f} "
            f"| {r.memory_s*1e3:.2f} | {r.collective_s*1e3:.3f} | {base} "
            f"| **{r.dominant}** | {min(r.useful_ratio, 9.99):.2f} "
            f"| {r.mem_gib_dev:.1f} ({r.mem_gib_corrected:.1f}) "
            f"| {'yes' if r.fits_16g else 'NO'} |")
    return "\n".join(out)


def to_csv(rows: List[RooflineRow]) -> str:
    hdr = ("arch,shape,flops_dev,hbm_bytes_dev,coll_bytes_dev,"
           "coll_bytes_baseline,compute_s,memory_s,collective_s,"
           "collective_s_baseline,dominant,model_flops,useful_ratio,"
           "mem_gib_dev,mem_gib_corrected,fits_16g,skipped")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r.arch},{r.shape},{r.flops_dev:.4g},{r.hbm_bytes_dev:.4g},"
            f"{r.coll_bytes_dev:.4g},{r.coll_bytes_baseline:.4g},"
            f"{r.compute_s:.4g},{r.memory_s:.4g},{r.collective_s:.4g},"
            f"{r.collective_s_baseline:.4g},{r.dominant},"
            f"{r.model_flops:.4g},{r.useful_ratio:.4g},"
            f"{r.mem_gib_dev:.3f},{r.mem_gib_corrected:.3f},{r.fits_16g},"
            f"\"{r.skipped or ''}\"")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args(argv)
    rows = load_rows(args.dryrun_dir)
    os.makedirs(args.out, exist_ok=True)
    md = to_markdown(rows)
    with open(os.path.join(args.out, "roofline.md"), "w") as f:
        f.write(md + "\n")
    with open(os.path.join(args.out, "roofline.csv"), "w") as f:
        f.write(to_csv(rows) + "\n")
    print(md)
    return rows


if __name__ == "__main__":
    main()
