"""Paper Fig. 4 / Table III: global-batch-size boundary under weak scaling.

Fixed token budget: doubling the global batch halves the step count. The
paper finds convergence degrades beyond batch 512 (8 groups); here the same
sweep runs at CPU scale — the *shape* of the degradation (monotone val-loss
increase with batch at fixed tokens) is the claim under test.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.config import TrainConfig
from repro.core.simulate import SimulatedRun
from benchmarks.convergence import model_cfg


def run(size="tiny", token_budget=400 * 32 * 64, batches=(16, 32, 64, 128),
        interval=10, seed=0, out_dir="experiments/weak_scaling"):
    mc = model_cfg(size)
    rows = []
    for gb in batches:
        steps = max(token_budget // (gb * 64), 20)
        groups = max(gb // 8, 1)  # one group per 8 sequences (weak scaling)
        tc = TrainConfig(
            optimizer="pier", total_steps=steps, global_batch_size=gb,
            seq_len=64, sync_interval=interval, inner_lr=1e-3,
            inner_min_lr=1e-4, seed=seed)
        r = SimulatedRun(mc, tc, num_groups=groups, seed=seed)
        hist = r.run(steps, eval_every=max(steps // 10, 1))
        rows.append({"global_batch": gb, "groups": groups, "steps": steps,
                     "final_val_loss": hist["val_loss"][-1]})
        print(f"  batch={gb:4d} groups={groups} steps={steps} "
              f"val={rows[-1]['final_val_loss']:.4f}", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"weak_scaling_{size}.json"), "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny")
    ap.add_argument("--budget", type=int, default=400 * 32 * 64)
    args = ap.parse_args(argv)
    run(args.size, args.budget)


if __name__ == "__main__":
    main()
