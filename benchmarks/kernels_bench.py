"""Kernel microbenchmarks: wall time of the interpret-mode kernels vs the
jnp references on CPU (correctness-path timing; TPU timings come from the
roofline model, not this host) plus the analytic FLOP/byte counts that the
kernels claim per call."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.pier_update import pier_update
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels import ref as REF


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)
    key = jax.random.PRNGKey(0)
    rows = []

    B, S, H, Hkv, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    flops = 4 * B * H * S * S * hd / 2  # causal
    t_k = _time(flash_attention, q, k, v, reps=args.reps)
    t_r = _time(REF.flash_attention_ref, q, k, v, reps=args.reps)
    rows.append(("flash_attention_interp", t_k, f"ref_us={t_r:.0f};flops={flops:.3g}"))

    n = 1 << 20
    a = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,))
    d = jax.random.normal(ks[2], (n,)) * 0.01
    mu = jnp.float32(0.9)
    lr = jnp.float32(1.0)
    t_k = _time(pier_update, a, m, d, mu, lr, reps=args.reps)
    t_r = _time(lambda *x: REF.pier_update_ref(*x[:3], mu=0.9, lr=1.0),
                a, m, d, reps=args.reps)
    hbm_bytes = 5 * n * 4  # 3 reads + 2 writes fused
    rows.append(("pier_update_interp", t_k,
                 f"ref_us={t_r:.0f};hbm_bytes={hbm_bytes:.3g}"))

    x = jax.random.normal(key, (512, 1024))
    s = jnp.ones((1024,))
    t_k = _time(rmsnorm, x, s, reps=args.reps)
    t_r = _time(REF.rmsnorm_ref, x, s, reps=args.reps)
    rows.append(("rmsnorm_interp", t_k, f"ref_us={t_r:.0f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
