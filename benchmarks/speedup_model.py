"""Paper Figs. 5-8: projected runtime speedup of Pier vs AdamW across scales.

No wall-clock on CPU, so the projection is an analytic step-time model fed
by *measured* per-device collective bytes from the dry-run records:

    t_step(AdamW) = max(t_compute, t_hbm) + grad_bytes / bw_global
    t_step(Pier)  = max(t_compute, t_hbm) + grad_bytes / bw_intra
                    + (2 * grad_bytes / bw_global) / H       # outer Δθ sync

where grad_bytes is the gradient all-reduce volume (≈ model bytes / shards)
taken from the dry-run HLO, and the bandwidth split models the cluster's
hierarchy (NVLink-vs-IB on the paper's machines, intra-slice ICI vs
pod-crossing DCN on v5e). Reports speedup S = t_AdamW / t_Pier per scale —
the quantity in the paper's Figs. 5-8 — for all three chip models.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from benchmarks.hardware import CHIPS, Chip

# GPT-2 model sizes from the paper (params), used for its figures
PAPER_MODELS = {
    "gpt2-small": 125e6,
    "gpt2-medium": 345e6,
    "gpt2-xl": 1.5e9,
    "gpt2-7b": 7e9,
}
TOKENS_PER_STEP = 512 * 1024  # paper: global batch 512, seq 1024


def step_time(
    n_params: float,
    n_gpus: int,
    chip: Chip,
    *,
    optimizer: str,
    h: int = 50,
    group_size: int = 4,
    opt_bytes_per_param: float = 4.0,  # fp32 grads all-reduced
    outer_bits: int = 32,  # compressed Δθ payload (overlap.py bytes model)
    outer_block: int = 256,
    hierarchical: bool = False,
    pods: int = 1,
) -> float:
    """Modeled seconds per training step."""
    from benchmarks.overlap import outer_comm_time

    tokens = TOKENS_PER_STEP
    flops = 6 * n_params * tokens / n_gpus
    t_compute = flops / chip.peak_flops
    t_hbm = (20 * n_params / n_gpus) / chip.hbm_bw  # params+grads+opt traffic
    t_math = max(t_compute, t_hbm)

    grad_bytes = n_params * opt_bytes_per_param
    # ring all-reduce: 2 * bytes * (n-1)/n per device
    def allreduce_t(bytes_, n, bw):
        if n <= 1:
            return 0.0
        return 2 * bytes_ * (n - 1) / n / bw

    if optimizer == "adamw":
        t_comm = allreduce_t(grad_bytes, n_gpus, chip.inter_group_bw)
    else:  # pier / diloco
        t_inner = allreduce_t(grad_bytes, min(group_size, n_gpus),
                              chip.intra_group_bw)
        t_outer = outer_comm_time(
            n_params, n_gpus, chip, group_size,
            bits=outer_bits, block=outer_block,
            hierarchical=hierarchical, pods=pods) / h
        t_comm = t_inner + t_outer
    return t_math + t_comm


def sweep(model: str, chip_name: str, scales: List[int], h: int,
          group_size: int, *, outer_bits: int = 32,
          hierarchical: bool = False, pods: int = 1) -> List[Dict]:
    chip = CHIPS[chip_name]
    n = PAPER_MODELS[model]
    rows = []
    for g in scales:
        ta = step_time(n, g, chip, optimizer="adamw")
        tp = step_time(n, g, chip, optimizer="pier", h=h,
                       group_size=group_size, outer_bits=outer_bits,
                       hierarchical=hierarchical, pods=pods)
        base = step_time(n, scales[0], chip, optimizer="adamw")
        rows.append({
            "gpus": g,
            "t_adamw_ms": ta * 1e3,
            "t_pier_ms": tp * 1e3,
            "speedup": ta / tp,
            "scaling_eff_adamw": base * scales[0] / (ta * g),
            "scaling_eff_pier": base * scales[0] / (tp * g),
        })
    return rows


def measured_grad_bytes(dryrun_dir: str, arch: str) -> Optional[float]:
    """Per-device warmup-step all-reduce bytes from the dry-run (if present)."""
    path = os.path.join(dryrun_dir, f"{arch}__train_4k__single.json")
    if not os.path.exists(path):
        return None
    rec = json.load(open(path))
    warm = rec.get("fit", {}).get("warmup")
    if not warm:
        return None
    return warm["collective_bytes"].get("all-reduce", 0.0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--h", type=int, default=50)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--outer-bits", type=int, default=32,
                    help="compressed outer Δθ payload bits (32 = fp32)")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--out", default="experiments/speedup")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    all_rows = {}
    # Fig. 5/6 analogue: Perlmutter A100 scaling
    for model, scales in [("gpt2-small", [8, 16, 32, 64]),
                          ("gpt2-medium", [32, 64, 128]),
                          ("gpt2-xl", [64, 128, 256]),
                          ("gpt2-7b", [32, 64, 128])]:
        for chipn in ("a100-perlmutter", "gh200-vista", "tpu-v5e"):
            rows = sweep(model, chipn, scales, args.h, args.group_size,
                         outer_bits=args.outer_bits,
                         hierarchical=args.hierarchical, pods=args.pods)
            all_rows[f"{model}__{chipn}"] = rows
    with open(os.path.join(args.out, "speedup_model.json"), "w") as f:
        json.dump(all_rows, f, indent=2)
    # headline numbers mirroring the paper's claims
    print("model,chip,gpus,speedup,eff_adamw,eff_pier")
    for key, rows in all_rows.items():
        model, chipn = key.split("__")
        r = rows[-1]
        print(f"{model},{chipn},{r['gpus']},{r['speedup']:.2f},"
              f"{r['scaling_eff_adamw']:.2f},{r['scaling_eff_pier']:.2f}")
    return all_rows


if __name__ == "__main__":
    main()
