"""Serving example: batched prefill + greedy decode with a sharded KV cache.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/serve_decode.py --arch qwen3-1.7b

Uses the reduced config of any assigned architecture (--arch), including the
SSM/hybrid families (recurrent decode state instead of a KV cache) and
whisper (encoder-decoder with a stubbed audio frontend).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import ParallelConfig  # noqa: E402
from repro.configs import get_reduced_config, list_architectures  # noqa: E402
from repro.launch import mesh as M  # noqa: E402
from repro.models import registry as R  # noqa: E402
from repro.parallel.steps import build_serve_steps  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=list_architectures())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    mc = get_reduced_config(args.arch)
    n = jax.device_count()
    mesh = M.small_mesh((n, 1), ("data", "model"))
    pc = ParallelConfig(data_axis_size=n, model_axis_size=1, data_outer=1)
    max_len = args.prompt_len + args.tokens
    bundle = build_serve_steps(mc, pc, mesh, batch=args.batch,
                               max_len=max_len)

    key = jax.random.PRNGKey(0)
    params = jax.jit(lambda k: R.init_params(k, mc),
                     out_shardings=bundle.param_shardings)(key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                mc.vocab_size)
    batch_in = {"tokens": prompt}
    if mc.is_encoder_decoder:
        # stubbed audio frontend: precomputed frame embeddings
        batch_in["frames"] = jax.random.normal(
            key, (args.batch, mc.encoder_seq_len, mc.d_model), jnp.float32)

    t0 = time.time()
    logits, state = bundle.prefill_step(params, batch_in)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [next_tok]
    t1 = time.time()
    for _ in range(args.tokens - 1):
        logits, state = bundle.serve_step(params, state, next_tok)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t2 = time.time()
    out = np.concatenate([np.asarray(t) for t in generated], axis=1)
    kind = ("recurrent state" if mc.sub_quadratic
            else ("latent cache" if mc.attention_kind == "mla" else "KV cache"))
    print(f"arch={mc.name} decode-state={kind}")
    print(f"prefill {t1 - t0:.2f}s | decode "
          f"{(t2 - t1) / max(args.tokens - 1, 1) * 1e3:.0f} ms/token "
          f"(batch={args.batch}, CPU interpret-scale)")
    print("greedy tokens[0]:", out[0].tolist())


if __name__ == "__main__":
    main()
