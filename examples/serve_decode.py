"""Serving example: the continuous-batching engine on mixed-length prompts.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/serve_decode.py --arch qwen3-1.7b

Paged-supported architectures (gqa-family KV caches) run through the real
``repro.serve`` subsystem — paged KV pool, Pallas decode attention,
requests of different lengths joining mid-flight; the SSM/hybrid families
(recurrent decode state) and whisper (encoder-decoder, stubbed audio
frontend) use the dense fallback inside the same
:func:`repro.serve.generate` helper the launcher uses.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import ParallelConfig  # noqa: E402
from repro.configs import get_reduced_config, list_architectures  # noqa: E402
from repro.launch import mesh as M  # noqa: E402
from repro.models import registry as R  # noqa: E402
from repro.serve import generate, paged_supported  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=list_architectures())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    mc = get_reduced_config(args.arch)
    n = jax.device_count()
    mesh = M.small_mesh((n, 1), ("data", "model"))
    pc = ParallelConfig(data_axis_size=n, model_axis_size=1, data_outer=1)

    key_params, key_prompt = jax.random.split(jax.random.PRNGKey(0))
    params = jax.jit(lambda k: R.init_params(k, mc))(key_params)
    prompts = np.asarray(jax.random.randint(
        key_prompt, (args.batch, args.prompt_len), 0, mc.vocab_size))
    frames = None
    if mc.is_encoder_decoder:
        # stubbed audio frontend: precomputed frame embeddings
        frames = jax.random.normal(
            key_prompt, (args.batch, mc.encoder_seq_len, mc.d_model),
            jnp.float32)

    t0 = time.time()
    out, info = generate(params, mc, pc, mesh, prompts, args.tokens,
                         frames=frames)
    dt = time.time() - t0

    ok, why = paged_supported(mc)
    kind = ("paged KV pool" if info["path"] == "paged" else
            ("recurrent state" if mc.sub_quadratic
             else ("latent cache" if mc.attention_kind == "mla"
                   else "dense KV cache")))
    print(f"arch={mc.name} path={info['path']} decode-state={kind}")
    if info["path"] == "paged":
        eng = info["engine"]
        print(f"engine: {eng.stats['decode_steps']} decode steps, "
              f"{eng.stats['prefills']} prefills, "
              f"{eng.stats['tokens_out']} tokens "
              f"({eng.stats['tokens_out'] / max(dt, 1e-9):.1f} tok/s, "
              f"CPU interpret-scale)")
    else:
        print(f"dense path: {why or 'encoder-decoder frames'} "
              f"({out.size / max(dt, 1e-9):.1f} tok/s, CPU interpret-scale)")
    print("greedy tokens[0]:", out[0].tolist())


if __name__ == "__main__":
    main()
