"""End-to-end driver: pretrain a ~100M-param GPT-2-small on the synthetic
corpus for a few hundred steps with all three optimizers and compare.

    PYTHONPATH=src python examples/pretrain_pier_vs_baselines.py \
        [--steps 300] [--model-scale small]

This is the example-scale version of the paper's Figs. 1/3 experiment: the
same token budget for AdamW (fully synchronized), DiLoCo (8 groups, fixed
outer lr), and Pier (momentum warmup + decay + outer LR schedule).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import ModelConfig, TrainConfig  # noqa: E402
from repro.core.simulate import SimulatedRun  # noqa: E402


def model(scale: str) -> ModelConfig:
    if scale == "small":  # true GPT-2 small: ~124M params
        return ModelConfig(
            name="gpt2-small", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=12, d_ff=3072, vocab_size=50_304, norm="layernorm",
            activation="gelu", positional="learned",
            max_position_embeddings=1024, dtype="float32")
    return ModelConfig(  # "mini": fast on CPU
        name="gpt2-mini", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=1024, vocab_size=2048, norm="layernorm",
        activation="gelu", positional="learned",
        max_position_embeddings=256, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--interval", type=int, default=10)
    ap.add_argument("--model-scale", default="mini",
                    choices=["mini", "small"])
    args = ap.parse_args()
    mc = model(args.model_scale)

    finals = {}
    for opt in ("adamw", "diloco", "pier"):
        tc = TrainConfig(
            optimizer=opt, total_steps=args.steps, global_batch_size=32,
            seq_len=64 if args.model_scale == "mini" else 128,
            sync_interval=args.interval, inner_lr=1e-3, inner_min_lr=1e-4,
            lazy_start=(opt != "diloco"), momentum_warmup=(opt == "pier"))
        groups = 1 if opt == "adamw" else args.groups
        run = SimulatedRun(mc, tc, num_groups=groups, seed=0)
        print(f"\n=== {opt} ({groups} group(s), H={args.interval}, "
              f"outer sync: {run.strategy.name}) ===")
        hist = run.run(args.steps, eval_every=max(args.steps // 6, 1))
        for s, v in zip(hist["val_step"], hist["val_loss"]):
            print(f"  step {s + 1:4d}  val_loss {v:.4f}")
        finals[opt] = hist["val_loss"][-1]

    print("\n=== final validation loss ===")
    for opt, v in finals.items():
        print(f"  {opt:8s} {v:.4f}")
    gap_diloco = finals["diloco"] - finals["adamw"]
    gap_pier = finals["pier"] - finals["adamw"]
    print(f"\nGap vs AdamW:  DiLoCo {gap_diloco:+.4f}   Pier {gap_pier:+.4f}")
    print("(paper claim: Pier ~= AdamW, better than DiLoCo)")


if __name__ == "__main__":
    main()
