"""MoE + Pier example: train a small DeepSeek-style MoE (MLA + routed
experts) with expert-parallel sharding and the Pier optimizer.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/moe_expert_parallel.py

Demonstrates the composition the paper's §IV-C is about, extended to EP:
inner AdamW communication (gradient reduction + expert all-to-all) stays on
the group's mesh slice; only the periodic Δθ all-reduce crosses groups —
including for the expert weights, which dominate Δθ volume.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.config import ParallelConfig, TrainConfig  # noqa: E402
from repro.configs import get_reduced_config  # noqa: E402
from repro.data.pipeline import synthetic_pipeline  # noqa: E402
from repro.launch import mesh as M  # noqa: E402
from repro.launch.train import Trainer  # noqa: E402


def main():
    n = jax.device_count()
    if n >= 8:
        shape = (2, 2, 2)
    elif n >= 4:
        shape = (2, 1, 2)
    else:
        shape = (1, 1, 1)
    mc = get_reduced_config("deepseek-v2-236b").replace(
        dtype="float32", num_experts=4, num_experts_per_tok=2)
    tc = TrainConfig(
        optimizer="pier", total_steps=80, global_batch_size=8, seq_len=64,
        sync_interval=8, warmup_frac=0.25, inner_lr=1e-3, inner_min_lr=1e-4)
    pc = ParallelConfig(
        data_axis_size=shape[0] * shape[1], model_axis_size=shape[2],
        data_outer=shape[0], shard_experts=True)
    mesh = M.small_mesh(shape, ("data_outer", "data_inner", "model"))
    print(f"mesh={shape}: {pc.num_groups} Pier group(s); experts sharded "
          f"over the model axis ({mc.num_experts} experts)")

    trainer = Trainer(mc, tc, pc, mesh)
    pipeline = synthetic_pipeline(mesh, M.data_axes(mesh), mc, tc)
    try:
        trainer.run(tc.total_steps, pipeline, log_every=8)
    finally:
        pipeline.close()
    print("done:", trainer.step, "steps (MoE + MLA + EP + Pier)")


if __name__ == "__main__":
    main()
