"""Quickstart: train a small model with the Pier optimizer on host devices.

    PYTHONPATH=src python examples/quickstart.py

Runs the full Pier schedule — lazy-start AdamW with momentum warmup, the
switch to group-local inner steps, μ-decay, the outer Nesterov syncs — on a
tiny GPT-2-style model over however many CPU devices are available, and
prints the loss curve. Set XLA_FLAGS=--xla_force_host_platform_device_count=8
to exercise real multi-group sharding.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.config import (ModelConfig, OuterCommConfig,  # noqa: E402
                          ParallelConfig, TrainConfig)
from repro.data.pipeline import synthetic_pipeline  # noqa: E402
from repro.launch import mesh as M  # noqa: E402
from repro.launch.train import Trainer  # noqa: E402


def main():
    n_dev = jax.device_count()
    groups = 2 if n_dev >= 2 else 1
    mesh_shape = (groups, max(n_dev // groups, 1), 1)
    print(f"devices={n_dev} mesh={mesh_shape} (data_outer=groups, "
          f"data_inner, model)")

    mc = ModelConfig(
        name="quickstart-12M", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=1024, vocab_size=2048, norm="layernorm",
        activation="gelu", positional="learned",
        max_position_embeddings=256, dtype="float32")
    tc = TrainConfig(
        optimizer="pier", total_steps=120, global_batch_size=16, seq_len=128,
        sync_interval=10, warmup_frac=0.25, inner_lr=1e-3, inner_min_lr=1e-4,
        # the outer collective is a pluggable strategy (DESIGN.md §7);
        # all-defaults = flat fp32 pmean of Δθ. Try e.g.
        # OuterCommConfig(compression="quantize", hierarchical=True,
        # chunks=2) for the compressed hierarchical chunked collective.
        outer_comm=OuterCommConfig())
    pc = ParallelConfig(
        data_axis_size=mesh_shape[0] * mesh_shape[1],
        model_axis_size=mesh_shape[2], data_outer=groups)
    mesh = M.small_mesh(mesh_shape, ("data_outer", "data_inner", "model"))

    trainer = Trainer(mc, tc, pc, mesh)
    print(f"outer-sync strategy: {trainer.strategy.name}")
    pipeline = synthetic_pipeline(mesh, M.data_axes(mesh), mc, tc)
    try:
        trainer.run(tc.total_steps, pipeline, log_every=10)
    finally:
        pipeline.close()
    print(f"\nPier run complete: {trainer.step} steps, "
          f"{trainer.sched.num_outer_steps()} outer syncs, "
          f"global-comm fraction "
          f"{trainer.sched.global_comm_fraction():.3f} "
          f"(AdamW baseline: 1.0)")


if __name__ == "__main__":
    main()
