"""AdamW / clipping / LR schedule unit tests against hand-rolled references."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import TrainConfig
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.schedules import lr_at


def _numpy_adamw(p, g, m, v, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    p = p - lr * (mh / (np.sqrt(vh) + eps) + wd * p)
    return p, m, v


def test_adamw_matches_reference_formula():
    tc = TrainConfig(adam_beta1=0.9, adam_beta2=0.95, adam_eps=1e-8,
                     weight_decay=0.1)
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(4, 3)).astype(np.float32)
    params = {"w_up": jnp.asarray(p0)}  # decayed param name
    state = adamw_init(params, tc)
    pn, mn, vn = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for t in range(1, 6):
        g = rng.normal(size=(4, 3)).astype(np.float32)
        params, state = adamw_update({"w_up": jnp.asarray(g)}, state, params,
                                     tc, jnp.float32(1e-2))
        pn, mn, vn = _numpy_adamw(pn, g, mn, vn, t, 1e-2, 0.9, 0.95, 1e-8, 0.1)
        np.testing.assert_allclose(np.asarray(params["w_up"]), pn,
                                   rtol=2e-5, atol=2e-6)
    assert int(state.count) == 5


def test_adamw_no_decay_for_norm_params():
    tc = TrainConfig(weight_decay=100.0)  # huge decay to make it obvious
    params = {"scale": jnp.ones((8,)), "w_up": jnp.ones((8,))}
    state = adamw_init(params, tc)
    zero_g = {"scale": jnp.zeros((8,)), "w_up": jnp.zeros((8,))}
    new, _ = adamw_update(zero_g, state, params, tc, jnp.float32(0.1))
    # zero grad: decayed param shrinks, norm scale untouched
    assert float(jnp.abs(new["scale"] - 1.0).max()) < 1e-7
    assert float(new["w_up"][0]) < 0.0  # 1 - 0.1*100*1


def test_adamw_state_dtype():
    tc = TrainConfig(opt_state_dtype="bfloat16")
    params = {"w_up": jnp.ones((4,), jnp.float32)}
    state = adamw_init(params, tc)
    assert state.mu["w_up"].dtype == jnp.bfloat16
    new_p, new_s = adamw_update({"w_up": jnp.ones((4,))}, state, params, tc,
                                jnp.float32(0.1))
    assert new_s.nu["w_up"].dtype == jnp.bfloat16
    assert new_p["w_up"].dtype == jnp.float32


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((6,), 4.0)}
    norm = float(global_norm(tree))
    assert abs(norm - np.sqrt(10 * 9 + 6 * 16)) < 1e-4
    clipped, pre = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(pre) - norm) < 1e-5
    # under the limit -> untouched
    same, _ = clip_by_global_norm(tree, norm * 2)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0, rtol=1e-6)


@given(st.integers(min_value=0, max_value=99_999))
@settings(max_examples=40, deadline=None)
def test_lr_schedule_bounds(step):
    tc = TrainConfig(total_steps=100_000, inner_lr=4e-4, inner_min_lr=4e-5,
                     lr_warmup_frac=0.02)
    lr = float(lr_at(tc, jnp.asarray(step)))
    assert 0.0 < lr <= 4e-4 + 1e-9


def test_cosine_schedule_shape():
    tc = TrainConfig(total_steps=1000, inner_lr=1e-3, inner_min_lr=1e-4,
                     lr_warmup_frac=0.02)
    warm_end = float(lr_at(tc, jnp.asarray(19)))
    assert abs(warm_end - 1e-3) < 5e-5  # reaches peak at warmup end
    assert float(lr_at(tc, jnp.asarray(999))) < 1.1e-4  # decays to floor
    # monotone decay after warmup
    vals = [float(lr_at(tc, jnp.asarray(s))) for s in range(20, 1000, 97)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_wsd_schedule():
    tc = TrainConfig(total_steps=1000, inner_lr=1e-3, inner_min_lr=1e-4,
                     lr_schedule="wsd", wsd_decay_frac=0.1)
    assert abs(float(lr_at(tc, jnp.asarray(500))) - 1e-3) < 1e-9  # stable
    assert float(lr_at(tc, jnp.asarray(999))) < 2e-4  # decay tail
