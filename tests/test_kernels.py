"""Pallas kernel sweeps: shapes × dtypes × features vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.kernels.flash_attention import flash_attention
from repro.kernels.pier_update import pier_update
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels import ref as REF


SHAPES = [
    # B, S, H, Hkv, hd
    (2, 128, 4, 4, 64),   # MHA
    (1, 256, 8, 2, 64),   # GQA 4:1
    (2, 96, 4, 1, 32),    # MQA, ragged seq
    (1, 64, 2, 2, 128),   # wide head
]


@pytest.mark.parametrize("B,S,H,Hkv,hd", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, H, Hkv, hd, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    ref = REF.flash_attention_ref(q, k, v, causal=True)
    tol = 2.5e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("window", [16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_window_softcap(window, causal, rng):
    B, S, H, Hkv, hd = 1, 128, 4, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=20.0, block_q=32, block_kv=32)
    ref = REF.flash_attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=20.0)
    assert float(jnp.abs(out - ref).max()) < 3e-5


@pytest.mark.parametrize("bq,bkv", [(32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_shape_invariance(bq, bkv, rng):
    """BlockSpec tiling must not change the math."""
    B, S, H, Hkv, hd = 1, 160, 4, 4, 32  # S not a block multiple
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv)
    ref = REF.flash_attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(out - ref).max()) < 3e-5


@given(n=st.integers(1, 5000), mu=st.floats(0.0, 0.999),
       lr=st.floats(0.0, 2.0), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_pier_update_kernel_matches_ref(n, mu, lr, seed):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    a = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,))
    d = jax.random.normal(ks[2], (n,)) * 0.1
    for form in ("nesterov_torch", "nesterov_classic", "sgd"):
        p1, m1 = pier_update(a, m, d, jnp.float32(mu), jnp.float32(lr),
                             formulation=form, block=256)
        pr, mr = REF.pier_update_ref(a, m, d, mu=mu, lr=lr, formulation=form)
        np.testing.assert_allclose(np.asarray(p1), np.asarray(pr),
                                   rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(mr),
                                   rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 256), (1, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(shape, dtype, rng):
    x = jax.random.normal(rng, shape, dtype)
    scale = jax.random.normal(jax.random.PRNGKey(5), (shape[-1],))
    out = rmsnorm(x, scale, block_rows=2)
    ref = REF.rmsnorm_ref(x, scale)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


def test_model_forward_with_pallas_matches_ref(rng):
    """End-to-end: use_pallas=True flips attention to the kernel."""
    from repro.configs import get_reduced_config
    from repro.models import registry as R

    cfg = get_reduced_config("granite-8b").replace(
        num_layers=2, dtype="float32")
    params = R.init_params(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)}
    ref_logits, _ = R.forward(params, cfg, batch, use_pallas=False)
    pal_logits, _ = R.forward(params, cfg, batch, use_pallas=True)
    assert float(jnp.abs(ref_logits - pal_logits).max()) < 1e-3
