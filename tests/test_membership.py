"""Elastic membership (DESIGN.md §11): churn scripting, the weighted
variable-membership reduction, and the simulator's elastic event loop.

The contract under test:

- **Spec grammar + state machine**: ``ChurnSchedule.parse`` round-trips
  the launcher grammar and rejects malformed entries;
  ``MembershipController`` replays drop/rejoin/straggle scripts into
  per-event records — stragglers stay in the apply cohort while within
  ``max_staleness`` missed events, are evicted beyond it, and bootstrap
  on re-entry; ``min_live`` violations fail at construction.
- **Exact weighting**: the weighted reducers are *bit-identical* to the
  fixed 1/E mean at all-ones weights (the acceptance bar for keeping
  the elastic graphs always-on under ``tc.membership``), and a masked
  reduction equals the plain mean over the surviving subset exactly —
  for the fp32 stack mean and the int8 wire-sum core alike.
- **Simulator**: full membership through the elastic graphs reproduces
  the fixed path bit for bit for FlatFP32, Quantized, and Int8Wire;
  scripted churn bootstraps rejoining groups from the anchor (or a
  checkpoint donor) and converges within 5% of the full-membership loss.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MembershipConfig, OuterCommConfig, TrainConfig
from repro.core.pier import PierSchedule
from repro.core.simulate import SimulatedRun
from repro.checkpoint import CheckpointManager
from repro.kernels.ref import dequant_sum_sources, quantize_blockwise_ref
from repro.sync import (ChurnEvent, ChurnSchedule, MembershipController,
                        weighted_stack_mean)
from test_delayed_sync import MC, _tc

BLOCK = 64


def _mtc(**kw):
    base = dict(optimizer="pier", warmup_frac=0.25, sync_interval=5,
                membership=MembershipConfig(max_staleness=1))
    base.update(kw)
    return _tc(**base)  # total_steps=40 -> warmup 10, outer events at
    #                      14/19/24/29/34/39 (ordinals 0..5)


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------


def test_churn_spec_roundtrip():
    s = ChurnSchedule.parse(" drop:1@3, rejoin:1@6 ,straggle:0@4+2 ")
    assert s.events == (ChurnEvent("drop", 1, 3),
                        ChurnEvent("rejoin", 1, 6),
                        ChurnEvent("straggle", 0, 4, late=2))
    assert s.max_event() == 6
    assert s.for_group(1) == (ChurnEvent("drop", 1, 3),
                              ChurnEvent("rejoin", 1, 6))
    assert ChurnSchedule.parse("").events == ()


@pytest.mark.parametrize("bad", [
    "flake:0@1",          # unknown kind
    "drop:0@1+2",         # +late only means something for straggle
    "straggle:0@1",       # straggle needs a lateness
    "rejoin:0@0",         # rejoin must name event >= 1 (bootstraps at k-1)
    "drop:0",             # missing @event
    "drop:a@1",           # non-numeric group
])
def test_churn_spec_rejects(bad):
    with pytest.raises(ValueError):
        ChurnSchedule.parse(bad)


# ---------------------------------------------------------------------------
# controller state machine
# ---------------------------------------------------------------------------


def test_controller_drop_rejoin_straggle_timeline():
    ctrl = MembershipController(
        4, cfg=MembershipConfig(max_staleness=1),
        schedule=ChurnSchedule.parse("drop:1@3,rejoin:1@6,straggle:0@4+2"))
    assert ctrl.elastic
    assert ctrl.at(0).full and ctrl.at(2).full
    # dropped: weight 0 and out of the apply cohort immediately
    assert ctrl.at(3).weights == (1.0, 0.0, 1.0, 1.0)
    assert ctrl.at(3).apply_live == (True, False, True, True)
    assert ctrl.at(4).apply_live == (True, False, True, True)
    # straggling group 0: deltas for events 4,5 discarded but it stays in
    # the apply cohort while within the staleness bound (evicted only
    # after missing more than max_staleness=1 events)
    assert ctrl.at(4).weights == (0.0, 0.0, 1.0, 1.0)
    assert ctrl.at(4).apply_live[0] is True
    assert ctrl.at(5).weights[0] == 0.0
    assert ctrl.at(5).apply_live[0] is True
    # right after event 5's apply: group 1's scripted rejoin bootstraps,
    # and so does group 0 (2 missed events > max_staleness -> evicted,
    # its straggle window ends at 6) — both participate at event 6
    assert ctrl.at(5).bootstrap_after_apply == (0, 1)
    assert ctrl.at(6).full
    # past the horizon: steady state, no one-shot bootstraps
    assert ctrl.at(7).full and ctrl.at(7).bootstrap_after_apply == ()


def test_controller_straggler_eviction_and_reentry():
    ctrl = MembershipController(
        2, cfg=MembershipConfig(max_staleness=1),
        schedule=ChurnSchedule.parse("straggle:1@2+3"))
    # misses events 2,3,4; evicted once missed > max_staleness — the
    # eviction computed after event 3 takes effect at event 4's mask
    assert ctrl.at(2).apply_live == (True, True)   # 1 missed: tolerated
    assert ctrl.at(3).apply_live == (True, True)   # eviction decided here
    assert ctrl.at(4).apply_live == (True, False)  # ...and lands here
    assert ctrl.at(4).bootstrap_after_apply == (1,)  # re-enters at 5
    assert ctrl.at(5).full


def test_controller_min_live_fails_at_construction():
    with pytest.raises(ValueError, match="min_live"):
        MembershipController(
            2, cfg=MembershipConfig(min_live=2),
            schedule=ChurnSchedule.parse("drop:0@1,rejoin:0@3"))


@pytest.mark.parametrize("spec", [
    "drop:0@1,drop:0@2",       # double drop
    "rejoin:0@2",              # rejoin without a drop
    "drop:0@2,rejoin:0@2",     # rejoin not after its drop
    "straggle:0@1+3,drop:0@2",     # drop inside the straggle window
    "straggle:0@1+3,straggle:0@2+1",  # overlapping straggles
])
def test_controller_rejects_incoherent_scripts(spec):
    with pytest.raises(ValueError):
        MembershipController(4, schedule=ChurnSchedule.parse(spec))


def test_controller_rejects_out_of_range_group():
    with pytest.raises(ValueError, match="only 2 groups"):
        MembershipController(2, schedule=ChurnSchedule.parse("drop:2@1"))


def test_empty_schedule_is_not_elastic():
    ctrl = MembershipController(3)
    assert not ctrl.elastic
    assert ctrl.at(0).full and ctrl.at(11).full


# ---------------------------------------------------------------------------
# exact weighting (the unit properties behind the all-ones acceptance bar)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E", [2, 3, 4, 5])
def test_weighted_stack_mean_all_ones_bitwise(E):
    x = jax.random.normal(jax.random.PRNGKey(E), (E, 37, 5), jnp.float32)
    w = jnp.ones((E,), jnp.float32)
    a = jax.jit(lambda x: jnp.mean(x, axis=0))(x)
    b = jax.jit(weighted_stack_mean)(x, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weighted_stack_mean_mask_equals_subset_mean():
    # up to summation order: XLA's pairwise reduce associates a 4-row
    # and a 3-row sum differently, so subset agreement is 1-ulp, not
    # bitwise (the bitwise contract is the all-ones identity above)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 33), jnp.float32)
    w = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
    got = jax.jit(weighted_stack_mean)(x, w)
    want = jax.jit(lambda x: jnp.mean(x, axis=0))(x[jnp.asarray([0, 2, 3])])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=1e-6)


def test_weighted_stack_mean_zero_sum_is_zero():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8), jnp.float32)
    got = jax.jit(weighted_stack_mean)(x, jnp.zeros((3,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.zeros((8,), np.float32))


def _quantize_stack(E, n=512, seed=0):
    deltas = jax.random.normal(jax.random.PRNGKey(seed), (E, n), jnp.float32)
    qs = [quantize_blockwise_ref(d, block=BLOCK, bits=8) for d in deltas]
    wg = jnp.stack([q for q, _ in qs])
    sg = jnp.stack([s for _, s in qs])
    return wg, sg


@pytest.mark.parametrize("E", [2, 3, 4, 6])
def test_dequant_sum_sources_all_ones_bitwise(E):
    wg, sg = _quantize_stack(E)
    f = jax.jit(lambda wg, sg: dequant_sum_sources(wg, sg, bits=8,
                                                   block=BLOCK))
    fw = jax.jit(lambda wg, sg, w: dequant_sum_sources(
        wg, sg, bits=8, block=BLOCK, weights=w))
    a = f(wg, sg)
    b = fw(wg, sg, jnp.ones((E,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dequant_sum_sources_mask_equals_subset():
    """Weight-0 sources drop out exactly: the masked weighted sum over
    all E equals the unweighted sum over the surviving subset (same
    accumulation order — zeros are IEEE-exact additions)."""
    wg, sg = _quantize_stack(4)
    keep = jnp.asarray([0, 2, 3])
    got = jax.jit(lambda wg, sg, w: dequant_sum_sources(
        wg, sg, bits=8, block=BLOCK, weights=w))(
            wg, sg, jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32))
    want = jax.jit(lambda wg, sg: dequant_sum_sources(
        wg, sg, bits=8, block=BLOCK))(wg[keep], sg[keep])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dequant_sum_sources_downweight_normalizes():
    """Non-binary weights: result is the w-weighted mean of the
    dequantized payloads."""
    wg, sg = _quantize_stack(3, n=256, seed=2)
    w = jnp.asarray([1.0, 0.5, 0.25], jnp.float32)
    got = dequant_sum_sources(wg, sg, bits=8, block=BLOCK, weights=w)
    payloads = [dequant_sum_sources(wg[i:i + 1], sg[i:i + 1], bits=8,
                                    block=BLOCK) for i in range(3)]
    want = sum(float(wi) * p for wi, p in zip(w, payloads)) * (
        jnp.float32(1.0) / jnp.sum(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# simulator: all-ones bit-identity per strategy (the elastic graphs must
# reproduce the fixed path exactly when nobody churns)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm,delay", [
    (OuterCommConfig(), 0),
    (OuterCommConfig(), 2),
    (OuterCommConfig(compression="quantize", bits=8, block=BLOCK), 0),
    (OuterCommConfig(compression="int8-wire", bits=8, block=BLOCK), 2),
])
def test_sim_all_ones_membership_bit_identity(comm, delay):
    tc = _mtc(outer_comm=comm, sync_delay=delay)
    fixed = SimulatedRun(MC, tc.replace(membership=None), num_groups=4,
                         seed=0)
    h0 = fixed.run(25)
    elastic = SimulatedRun(MC, tc, num_groups=4, seed=0,
                           membership=MembershipController(
                               4, cfg=tc.membership))
    h1 = elastic.run(25)
    assert h0["train_loss"] == h1["train_loss"]
    elastic.flush(), fixed.flush()
    for a, b in zip(jax.tree.leaves(fixed.state.group_params),
                    jax.tree.leaves(elastic.state.group_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(fixed.state.outer.momentum),
                    jax.tree.leaves(elastic.state.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# simulator: scripted churn semantics
# ---------------------------------------------------------------------------


def _churn_sim(spec, tc=None, G=4, ckpt=None):
    tc = tc if tc is not None else _mtc()
    return SimulatedRun(
        MC, tc, num_groups=G, seed=0,
        membership=MembershipController(
            G, cfg=tc.membership, schedule=ChurnSchedule.parse(spec)),
        checkpoint_manager=ckpt)


def test_sim_dropped_group_keeps_stale_params_then_bootstraps():
    # warmup 10, interval 5: event k applies at step 14 + 5k (delay 0)
    r = _churn_sim("drop:1@1,rejoin:1@3")
    r.run(20)  # through event 1 (step 19): group 1 absent, no apply
    gp = jax.tree.leaves(r.state.group_params)[0]
    anchor = jax.tree.leaves(r.state.outer.anchor)[0]
    # live groups synced onto the new anchor; dropped group kept stale
    np.testing.assert_array_equal(np.asarray(gp[0]), np.asarray(anchor))
    assert float(jnp.abs(gp[1] - anchor).max()) > 0
    r.run(5)  # through event 2 (step 24): bootstrap for the rejoin at 3
    gp = jax.tree.leaves(r.state.group_params)[0]
    anchor = jax.tree.leaves(r.state.outer.anchor)[0]
    np.testing.assert_array_equal(np.asarray(gp[1]), np.asarray(anchor))
    # fresh inner-opt state for the bootstrapped group
    assert int(r.state.opt.count[1]) == 0
    assert all(float(jnp.abs(m[1]).max()) == 0.0
               for m in jax.tree.leaves(r.state.opt.mu))


def test_sim_checkpoint_bootstrap_donor(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tc = _mtc(membership=MembershipConfig(rejoin_bootstrap="checkpoint"))
    r = _churn_sim("drop:1@1,rejoin:1@3", tc=tc, ckpt=ckpt)
    r.run(15)  # past event 0: groups synced at the anchor
    donor = jax.tree.map(lambda x: np.asarray(x), r.state.params)
    ckpt.save(15, {"params": r.state.params})
    r.run(10)  # event 2's apply triggers the bootstrap for the rejoin
    gp = jax.tree.leaves(r.state.group_params)[0]
    np.testing.assert_array_equal(
        np.asarray(gp[1]), jax.tree.leaves(donor)[0])


def test_sim_straggler_receives_applies_but_contributes_nothing():
    r = _churn_sim("straggle:0@1+1")
    r.run(20)  # event 1 (step 19): group 0's delta discarded, apply lands
    gp = jax.tree.leaves(r.state.group_params)[0]
    anchor = jax.tree.leaves(r.state.outer.anchor)[0]
    # within the staleness bound the straggler still installs the target
    np.testing.assert_array_equal(np.asarray(gp[0]), np.asarray(anchor))


def test_sim_membership_wrong_group_count_rejected():
    with pytest.raises(ValueError, match="tracks 2 groups"):
        SimulatedRun(MC, _mtc(), num_groups=4, seed=0,
                     membership=MembershipController(2))


def test_sim_membership_chunked_not_implemented():
    tc = _mtc(comm_chunks=3)
    with pytest.raises(NotImplementedError, match="chunked"):
        SimulatedRun(MC, tc, num_groups=4, seed=0,
                     membership=MembershipController(4, cfg=tc.membership))


def test_outer_index_ordinals():
    tc = _mtc()  # warmup 10, interval 5
    sched = PierSchedule(tc)
    assert sched.outer_index(14) == 0
    assert sched.outer_index(19) == 1
    assert sched.outer_index(39) == 5
    with pytest.raises(ValueError):
        sched.outer_index(15)  # not a boundary
    with pytest.raises(ValueError):
        sched.outer_index(9)  # warmup accumulate, not an outer event


# ---------------------------------------------------------------------------
# convergence under churn (acceptance: <= 5% of full-membership loss)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("comm", [
    OuterCommConfig(),
    OuterCommConfig(compression="int8-wire", bits=8, block=BLOCK),
])
def test_churn_convergence_within_5pct(comm):
    tc = _mtc(total_steps=60, sync_delay=1, outer_comm=comm)
    full = SimulatedRun(MC, tc.replace(membership=None), num_groups=4,
                        seed=0)
    hf = full.run(60)
    churn = _churn_sim(
        "drop:1@1,rejoin:1@4,straggle:0@2+1,drop:3@6,rejoin:3@8", tc=tc)
    hc = churn.run(60)

    def tail(h):  # average of the last 5 steps' train loss
        return float(np.mean(h["train_loss"][-5:]))

    lf, lc = tail(hf), tail(hc)
    assert lc <= lf * 1.05, (lc, lf)
