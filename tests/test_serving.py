"""Serving subsystem (DESIGN.md §12): decode-kernel oracle equality, paged
vs dense parity, int8-KV tolerance, allocator invariants, the
continuous-batching engine, and the train→serve hot handoff."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import ParallelConfig
from repro.configs import get_reduced_config
from repro.kernels.decode_attention import (paged_decode_attention,
                                            paged_decode_attention_ref)
from repro.launch import mesh as M
from repro.models import registry as R
from repro.serve import kv_cache as KC
from repro.serve import paged_model as PM
from repro.serve.engine import EngineConfig, ServeEngine, generate
from repro.serve.handoff import CheckpointPoller


def _f32(name):
    return dataclasses.replace(get_reduced_config(name),
                               dtype="float32", param_dtype="float32")


def _mesh_pc():
    mesh = M.small_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    return mesh, pc


# ===========================================================================
# decode-attention kernel vs jnp oracle (bitwise in interpret mode)
# ===========================================================================

KERNEL_SHAPES = [
    # B, H, Hkv, hd, N, bs, T
    (2, 4, 4, 64, 8, 16, 3),   # mha
    (3, 8, 2, 64, 8, 16, 3),   # gqa 4:1
    (2, 4, 1, 32, 6, 8, 4),    # mqa
]


def _rand_paged(key, B, H, Hkv, hd, N, bs, T, dtype, *, quantized=False):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    rng = np.random.default_rng(0)
    # distinct physical blocks per sequence, some rows shorter than T
    tables = np.full((B, T), -1, np.int32)
    cls = np.zeros((B,), np.int32)
    perm = rng.permutation(np.arange(1, N))
    used = 0
    for b in range(B):
        n_blk = int(rng.integers(1, T + 1))
        n_blk = min(n_blk, len(perm) - used)
        tables[b, :n_blk] = perm[used:used + n_blk]
        used += n_blk
        cls[b] = int(rng.integers(1, n_blk * bs + 1))
    if quantized:
        kf = jax.random.normal(ks[1], (N, bs, Hkv, hd), jnp.float32)
        vf = jax.random.normal(ks[2], (N, bs, Hkv, hd), jnp.float32)
        from repro.kernels.ops import quantize_blockwise

        def q8(x):
            qv, s = quantize_blockwise(x.reshape(-1), bits=8, block=hd)
            return qv.reshape(x.shape), s.reshape(x.shape[:-1])

        k_pool, k_sc = q8(kf)
        v_pool, v_sc = q8(vf)
    else:
        k_pool = jax.random.normal(ks[1], (N, bs, Hkv, hd), dtype)
        v_pool = jax.random.normal(ks[2], (N, bs, Hkv, hd), dtype)
        k_sc = v_sc = None
    return (q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(cls),
            k_sc, v_sc)


@pytest.mark.parametrize("B,H,Hkv,hd,N,bs,T", KERNEL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_bitwise_vs_oracle(B, H, Hkv, hd, N, bs, T, dtype, rng):
    args = _rand_paged(rng, B, H, Hkv, hd, N, bs, T, dtype)
    out = paged_decode_attention(*args, interpret=True)
    ref = paged_decode_attention_ref(*args)
    # bitwise: the oracle mirrors the interpret-mode program structure
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("window,softcap", [(6, 0.0), (0, 30.0), (6, 30.0)])
def test_decode_kernel_bitwise_window_softcap(window, softcap, rng):
    args = _rand_paged(rng, 2, 4, 2, 32, 6, 8, 3, jnp.float32)
    out = paged_decode_attention(*args, window=window, softcap=softcap,
                                 interpret=True)
    ref = paged_decode_attention_ref(*args, window=window, softcap=softcap)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_decode_kernel_bitwise_int8(rng):
    args = _rand_paged(rng, 2, 4, 2, 64, 6, 8, 3, jnp.float32,
                       quantized=True)
    out = paged_decode_attention(*args, interpret=True)
    ref = paged_decode_attention_ref(*args)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_decode_kernel_empty_slot_zeros(rng):
    q, kp, vp, bt, cl, _, _ = _rand_paged(
        rng, 2, 4, 2, 32, 6, 8, 3, jnp.float32)
    cl = cl.at[1].set(0)
    out = paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    assert float(jnp.abs(out[1]).max()) == 0.0
    assert float(jnp.abs(out[0]).max()) > 0.0


# ===========================================================================
# paged decode parity vs the dense paths (mha + gqa), int8-KV tolerance
# ===========================================================================


def _paged_rollout(cfg, params, toks, S, D, pcfg):
    """Teacher-forced paged prefill + decode; returns per-token logits."""
    pools = KC.init_pools(cfg, pcfg)
    bs = pcfg.block_size
    pad = (-S) % bs
    n_blocks = pcfg.blocks_for(S + pad + D)
    table = list(range(1, 1 + n_blocks))
    bt = np.full((1, n_blocks), -1, np.int32)
    bt[0] = table
    bt = jnp.asarray(bt)
    prompt = jnp.pad(toks[:, :S], ((0, 0), (0, pad)))
    lg, pools = PM.paged_prefill(
        params, cfg, prompt, pools,
        jnp.asarray(table[: (S + pad) // bs], jnp.int32), pcfg=pcfg)
    out = [np.asarray(lg[0, S - 1], np.float32)]
    for t in range(D):
        pos = S + t
        lg, pools = PM.paged_decode_step(
            params, cfg, pools, toks[:, pos], jnp.array([pos], jnp.int32),
            bt, jnp.array([pos + 1], jnp.int32), pcfg=pcfg)
        out.append(np.asarray(lg[0], np.float32))
    return np.stack(out)  # (D + 1, V) logits for positions S-1 .. S+D-1


@pytest.mark.parametrize("arch", ["gpt2-small", "qwen3-1.7b"])
def test_paged_decode_parity_dense(arch, rng):
    """mha (gpt2) and gqa (qwen3): paged logits == dense full forward and
    dense decode path per token, ≤ 1e-5 fp32."""
    cfg = _f32(arch)
    params = R.init_params(rng, cfg)
    S, D = 6, 4
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, S + D), 0,
                              cfg.vocab_size)
    pcfg = KC.PagedCacheConfig(num_blocks=8, block_size=4, dtype="float32")
    paged = _paged_rollout(cfg, params, toks, S, D, pcfg)

    # dense full-sequence forward
    full, _ = R.forward(params, cfg, {"tokens": toks})
    full = np.asarray(full[0, S - 1: S + D], np.float32)
    assert np.abs(paged - full).max() < 1e-5

    # dense decode path, teacher-forced token by token
    _, state = R.prefill(params, cfg, {"tokens": toks[:, :S]},
                         max_len=S + D + 1)
    dense = []
    for t in range(D):
        lg, state = R.decode_step(params, cfg, state, toks[:, S + t:S + t + 1])
        dense.append(np.asarray(lg[0, 0], np.float32))
    assert np.abs(paged[1:] - np.stack(dense)).max() < 1e-5


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "xlstm-1.3b"])
def test_dense_decode_parity_fallback_archs(arch, rng):
    """mla and SSM configs: not paged-supported; their dense decode path
    matches the full forward per token (the path generate() falls back to)."""
    cfg = _f32(arch)
    ok, why = KC.paged_supported(cfg)
    assert not ok and why
    params = R.init_params(rng, cfg)
    S, D = 6, 3
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, S + D), 0,
                              cfg.vocab_size)
    full, _ = R.forward(params, cfg, {"tokens": toks})
    _, state = R.prefill(params, cfg, {"tokens": toks[:, :S]},
                         max_len=S + D + 1)
    for t in range(D):
        lg, state = R.decode_step(params, cfg, state, toks[:, S + t:S + t + 1])
        err = np.abs(np.asarray(lg[0, 0], np.float32)
                     - np.asarray(full[0, S + t], np.float32)).max()
        assert err < 1e-4, (arch, t, err)


def test_int8_kv_decode_tolerance(rng):
    """int8-KV logits within the documented tolerance of fp32-KV:
    ≤ 2% of the max |logit| (DESIGN.md §12). Measured ~0.3%."""
    cfg = _f32("qwen3-1.7b")
    params = R.init_params(rng, cfg)
    S, D = 6, 4
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, S + D), 0,
                              cfg.vocab_size)
    fp = _paged_rollout(cfg, params, toks, S, D,
                        KC.PagedCacheConfig(num_blocks=8, block_size=4,
                                            dtype="float32"))
    q8 = _paged_rollout(cfg, params, toks, S, D,
                        KC.PagedCacheConfig(num_blocks=8, block_size=4,
                                            quantized=True))
    err = np.abs(fp - q8).max()
    assert err <= 0.02 * np.abs(fp).max(), err
    # greedy decisions unchanged on this trace
    assert (fp.argmax(-1) == q8.argmax(-1)).all()


# ===========================================================================
# block allocator invariants (property tests)
# ===========================================================================


@given(num_blocks=st.integers(2, 64), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_allocator_invariants(num_blocks, seed):
    rng = np.random.default_rng(seed)
    alloc = KC.BlockAllocator(num_blocks)
    usable = num_blocks - 1
    live = []
    for _ in range(200):
        if live and (rng.random() < 0.4 or alloc.num_free == 0):
            blk = live.pop(int(rng.integers(len(live))))
            alloc.free(blk)
        elif alloc.num_free > 0:
            blk = alloc.alloc()
            assert blk != KC.SINK_BLOCK  # the sink never circulates
            assert 0 < blk < num_blocks
            assert blk not in live  # no double allocation
            live.append(blk)
        # conservation: free + allocated == usable, always
        assert alloc.num_free + len(alloc.allocated) == usable
        assert set(live) == set(alloc.allocated)
    alloc.free_many(live)
    assert alloc.num_free == usable


def test_allocator_errors():
    alloc = KC.BlockAllocator(4)
    blks = alloc.alloc_many(3)
    with pytest.raises(RuntimeError):
        alloc.alloc()  # exhausted
    with pytest.raises(RuntimeError):
        alloc.alloc_many(1)
    alloc.free(blks[0])
    with pytest.raises(ValueError):
        alloc.free(blks[0])  # double free
    with pytest.raises(ValueError):
        alloc.free(KC.SINK_BLOCK)  # the sink is never allocatable
    with pytest.raises(ValueError):
        KC.BlockAllocator(1)


# ===========================================================================
# continuous-batching engine
# ===========================================================================


def _engine_fixture(arch="gpt2-small", **ecfg_kw):
    from repro.parallel.steps import build_paged_serve_steps

    cfg = _f32(arch)
    mesh, pc = _mesh_pc()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    pcfg = KC.PagedCacheConfig(num_blocks=20, block_size=4, dtype="float32")
    bundle = build_paged_serve_steps(cfg, pc, mesh, pcfg=pcfg)
    kw = dict(max_slots=3, max_new_tokens=5, max_blocks_per_seq=5)
    kw.update(ecfg_kw)
    return cfg, params, bundle, pcfg, EngineConfig(**kw)


def _check_slot_invariants(engine):
    """Block-table/sequence-length consistency on every live slot."""
    seen = set()
    for s in engine.slots:
        if s is None:
            continue
        # enough blocks reserved for the current position
        assert len(s.blocks) * engine.pcfg.block_size >= s.pos
        assert len(s.blocks) == engine._blocks_needed(s.req)
        for b in s.blocks:
            assert b != KC.SINK_BLOCK
            assert b not in seen  # no block shared between sequences
            seen.add(b)
    assert seen == set(engine.alloc.allocated)


def test_engine_mixed_length_trace():
    cfg, params, bundle, pcfg, ecfg = _engine_fixture()
    engine = ServeEngine(params, cfg, bundle, pcfg, ecfg)
    rng = np.random.default_rng(0)
    lens = [3, 9, 5, 12, 2, 7]
    for L in lens:
        engine.submit(rng.integers(0, cfg.vocab_size, size=L), 5)
    steps = 0
    while engine.step():
        _check_slot_invariants(engine)
        steps += 1
        assert steps < 200
    results = sorted(engine.finished, key=lambda r: r.uid)
    assert [r.prompt_len for r in results] == lens
    assert all(len(r.tokens) == 5 for r in results)
    # no allocator leak after drain
    assert engine.alloc.num_free == pcfg.num_blocks - 1
    assert engine.stats["tokens_out"] == 5 * len(lens)


def test_engine_continuous_beats_static_decode_steps():
    """Same trace, both policies: continuous needs no more decode steps
    (the tokens/s mechanism serve_bench measures, without timing noise)."""
    trace = [(np.arange(3), 7), (np.arange(5), 2), (np.arange(2), 9),
             (np.arange(7), 3), (np.arange(4), 5)]
    steps = {}
    for continuous in (False, True):
        cfg, params, bundle, pcfg, ecfg = _engine_fixture(
            continuous=continuous)
        engine = ServeEngine(params, cfg, bundle, pcfg, ecfg)
        for prompt, n in trace:
            engine.submit(prompt % cfg.vocab_size, n)
        res = engine.run()
        assert sum(len(r.tokens) for r in res) == sum(n for _, n in trace)
        steps[continuous] = engine.stats["decode_steps"]
    assert steps[True] < steps[False], steps


def test_engine_admission_respects_pool():
    """A request too big for the free list waits; FIFO order is kept."""
    cfg, params, bundle, pcfg, ecfg = _engine_fixture(
        max_slots=2, max_new_tokens=8, max_blocks_per_seq=4)
    engine = ServeEngine(params, cfg, bundle, pcfg, ecfg)
    # each request needs 4 blocks (8 prompt + 8 new = 16 tokens / bs 4);
    # pool has 19 usable -> at most 4 concurrently, slots cap at 2
    for _ in range(5):
        engine.submit(np.arange(8) % cfg.vocab_size, 8)
    engine.step()
    assert engine.alloc.num_free == 19 - 2 * 4
    engine.run()
    assert engine.alloc.num_free == 19

    # a request that can never fit the block-table width fails loudly at
    # admission rather than deadlocking the queue
    engine2 = ServeEngine(params, cfg, bundle, pcfg, ecfg)
    engine2.submit(np.arange(40) % cfg.vocab_size, 8)  # 12 blocks > width 4
    with pytest.raises(ValueError):
        engine2.step()


def test_generate_helper_paths(rng):
    """One helper serves both worlds: paged for gqa, dense for SSM."""
    mesh, pc = _mesh_pc()
    cfg = _f32("qwen3-1.7b")
    params = R.init_params(rng, cfg)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab_size))
    out, info = generate(params, cfg, pc, mesh, prompts, 4)
    assert info["path"] == "paged" and out.shape == (2, 4)

    cfg_s = _f32("xlstm-1.3b")
    params_s = R.init_params(rng, cfg_s)
    prompts_s = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (2, 6), 0, cfg_s.vocab_size))
    out_s, info_s = generate(params_s, cfg_s, pc, mesh, prompts_s, 4)
    assert info_s["path"] == "dense" and out_s.shape == (2, 4)


# ===========================================================================
# train→serve hot handoff
# ===========================================================================


def test_hot_handoff_integration(tmp_path, rng):
    """Checkpoints written while the engine decodes swap in at the next
    step boundary; in-flight sequences complete; no allocator leak."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.parallel.steps import TrainState

    cfg, params, bundle, pcfg, ecfg = _engine_fixture(max_new_tokens=8)
    p_new = R.init_params(jax.random.PRNGKey(42), cfg)
    engine = ServeEngine(params, cfg, bundle, pcfg, ecfg)
    rng_np = np.random.default_rng(0)
    for L in (4, 9, 6, 11):
        engine.submit(rng_np.integers(0, cfg.vocab_size, size=L), 8)

    mgr = CheckpointManager(str(tmp_path))
    poller = CheckpointPoller(mgr, params)
    swap_step = {}

    def trainer_and_handoff(eng):
        # the "trainer": writes a (G,)-stacked TrainState checkpoint
        # mid-serve, the way launch/train.py does
        if eng.stats["steps"] == 2:
            stacked = jax.tree.map(lambda a: jnp.stack([a]), p_new)
            mgr.save(17, {"state": TrainState(params=stacked, opt={})})
        before = eng.stats["steps"]
        poller.on_step(eng)
        if poller.swapped_steps and not swap_step:
            swap_step["at"] = before

    results = engine.run(on_step=trainer_and_handoff)
    # the swap happened, at a step boundary after the save
    assert poller.swapped_steps == [17]
    assert swap_step["at"] >= 2  # never before the checkpoint existed
    # the engine now serves the new params
    np.testing.assert_array_equal(
        np.asarray(engine.params["embed"]["tokens"]),
        np.asarray(p_new["embed"]["tokens"]))
    # in-flight sequences completed, blocks all returned
    assert len(results) == 4
    assert all(len(r.tokens) == 8 for r in results)
    assert engine.alloc.num_free == pcfg.num_blocks - 1


def test_handoff_ignores_incomplete_checkpoint(tmp_path, rng):
    """A checkpoint without its manifest (trainer mid-write) is invisible."""
    import os

    from repro.checkpoint.manager import CheckpointManager

    cfg = _f32("gpt2-small")
    params = R.init_params(rng, cfg)
    mgr = CheckpointManager(str(tmp_path))
    step_dir = os.path.join(str(tmp_path), "step_00000005")
    os.makedirs(step_dir)  # no manifest: incomplete by construction
    poller = CheckpointPoller(mgr, params)
    assert poller.poll() is None
    mgr.save(6, {"params": params})
    got = poller.poll()
    assert got is not None and got[0] == 6
