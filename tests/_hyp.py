"""hypothesis shim: property-based when installed, example-based otherwise.

The property tests import ``given`` / ``settings`` / ``st`` from here instead
of from ``hypothesis``. When hypothesis is available (requirements-dev.txt)
they are re-exported untouched and the tests run as real property tests.
When it is missing (minimal images carry only the jax toolchain), ``given``
degrades to a deterministic ``pytest.mark.parametrize`` sweep: each strategy
contributes its boundary values first, then seeded-random draws — the same
assertions run over a fixed example set rather than a searched one.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import zlib

    import numpy as np
    import pytest

    NUM_EXAMPLES = 6

    class _Integers:
        def __init__(self, min_value=0, max_value=0):
            self.lo, self.hi = int(min_value), int(max_value)

        def sample(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats:
        def __init__(self, min_value=0.0, max_value=1.0):
            self.lo, self.hi = float(min_value), float(max_value)

        def sample(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return float(rng.uniform(self.lo, self.hi))

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng, i):
            if i < len(self.elements):
                return self.elements[i]
            return self.elements[int(rng.integers(len(self.elements)))]

    class _Booleans(_SampledFrom):
        def __init__(self):
            super().__init__([False, True])

    class _St:
        integers = staticmethod(_Integers)
        floats = staticmethod(_Floats)
        sampled_from = staticmethod(_SampledFrom)
        booleans = staticmethod(_Booleans)

    st = _St()

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            sig_names = list(inspect.signature(fn).parameters)
            mapping = list(zip(sig_names, arg_strats)) + list(kw_strats.items())
            names = [n for n, _ in mapping]
            rng = np.random.default_rng(zlib.adler32(fn.__name__.encode()))
            rows = [
                pytest.param(*[s.sample(rng, i) for _, s in mapping],
                             id=f"ex{i}")
                for i in range(NUM_EXAMPLES)
            ]
            return pytest.mark.parametrize(",".join(names), rows)(fn)

        return deco

    def settings(*_args, **_kw):
        return lambda fn: fn
