"""KernelBackend registry, backend-aware transports, and env presets.

Covers the DESIGN.md §13 contract: lazy per-process resolution
(env var / forced override / platform auto-detect, with an explicit cache
reset), the per-kernel capability table, jnp-ref <-> interpret parity, the
no-Pallas guarantee of the jnp-ref lane, backend-aware wire-transport
resolution, append-only env presets, and the acceptance criterion that no
``default_interpret`` call site survives outside ``kernels/backend.py``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.kernels import backend as kb
from repro.kernels import ops as kops
from repro.kernels.decode_attention import (paged_decode_attention,
                                            paged_decode_attention_ref)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pier_update import pier_update
from repro.kernels.quantize import dequantize_blockwise, quantize_blockwise
from repro.kernels.ring_allreduce import resolve_transport
from repro.kernels.rmsnorm import rmsnorm
from repro.launch.mesh import GPU_XLA_FLAGS, _merge_xla_flags, apply_env_preset


@pytest.fixture(autouse=True)
def _restore_backend():
    """Every test leaves the process-global backend state as it found it."""
    forced = kb._forced
    yield
    kb._forced = forced
    kb.reset_backend_cache()


def _fake_platform(monkeypatch, platform: str):
    monkeypatch.setattr(kb, "_detect_platform", lambda: platform)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    kb.set_kernel_backend(None)  # clear any forced override + cache


# ---------------------------------------------------------------------------
# resolution: lazy, env-overridable, resettable
# ---------------------------------------------------------------------------


def test_default_resolution_matches_env_or_platform():
    kb.reset_backend_cache()
    expected = (os.environ.get("REPRO_KERNEL_BACKEND", "").strip()
                or kb.default_backend_name())
    assert kb.resolve_backend().name == expected


def test_env_var_override_and_reset(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "jnp-ref")
    kb.set_kernel_backend(None)
    assert kb.resolve_backend().name == "jnp-ref"
    assert kb.resolve_kernel("quantize") == ("jnp", False)
    # the resolution is cached: flipping the env var without a reset
    # changes nothing until reset_backend_cache drops the cache
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert kb.resolve_backend().name == "jnp-ref"
    kb.reset_backend_cache()
    assert kb.resolve_backend().name == "interpret"


def test_invalid_backend_names_raise(monkeypatch):
    with pytest.raises(ValueError, match="unknown kernel backend"):
        kb.set_kernel_backend("cuda-graphs")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "warp-drive")
    kb.set_kernel_backend(None)
    with pytest.raises(ValueError, match="unknown kernel backend"):
        kb.resolve_backend()


def test_forced_override_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    kb.set_kernel_backend("jnp-ref")
    assert kb.resolve_backend().name == "jnp-ref"
    # reset_backend_cache must NOT clear the explicit override (it is a
    # user decision, not a cache)
    kb.reset_backend_cache()
    assert kb.resolve_backend().name == "jnp-ref"
    kb.set_kernel_backend(None)
    assert kb.resolve_backend().name == "interpret"


def test_on_tpu_is_lazily_cached_until_reset(monkeypatch):
    _fake_platform(monkeypatch, "cpu")
    assert kb.on_tpu() is False
    # the answer is pinned until an explicit reset — exactly the
    # functools.cache bug, but now with a documented escape hatch
    monkeypatch.setattr(kb, "_detect_platform", lambda: "tpu")
    assert kb.on_tpu() is False
    kb.reset_backend_cache()
    assert kb.on_tpu() is True


def test_unknown_kernel_raises():
    with pytest.raises(KeyError, match="unknown kernel"):
        kb.resolve_backend().lane("conv3d")


# ---------------------------------------------------------------------------
# capability table: per-platform lanes
# ---------------------------------------------------------------------------


def test_fake_tpu_resolves_compiled_flash_attention(monkeypatch):
    # regression for the hardcoded ``interpret: bool = True`` default:
    # on a TPU platform the resolved lane must be the COMPILED Pallas body
    _fake_platform(monkeypatch, "tpu")
    assert kb.resolve_backend().name == "tpu-mosaic"
    assert kb.resolve_kernel("flash_attention") == ("pallas", False)
    assert kb.resolve_kernel("quantize") == ("pallas", False)
    assert kb.resolve_kernel("decode_attention") == ("pallas", False)
    import inspect

    for fn in (flash_attention, rmsnorm):
        assert inspect.signature(fn).parameters["interpret"].default is None


def test_fake_gpu_lanes(monkeypatch):
    _fake_platform(monkeypatch, "gpu")
    assert kb.resolve_backend().name == "gpu-triton"
    # plain-BlockSpec kernels compile through the Triton lowering
    assert kb.resolve_kernel("quantize") == ("pallas", False)
    assert kb.resolve_kernel("rmsnorm") == ("pallas", False)
    # TPU-idiomatic kernels fall back to the jnp oracle
    assert kb.resolve_kernel("pier_update")[0] == "jnp"
    assert kb.resolve_kernel("flash_attention")[0] == "jnp"
    assert kb.resolve_kernel("decode_attention")[0] == "jnp"
    assert kb.kernel_lane("ring_allreduce") == kb.JNP


def test_explicit_interpret_bool_overrides_lane():
    # the legacy per-call override: an explicit bool always runs the
    # Pallas body (the bitwise kernel-vs-oracle harness pins True)
    kb.set_kernel_backend("jnp-ref")
    assert kb.resolve_kernel("quantize", True) == ("pallas", True)
    assert kb.resolve_kernel("quantize", False) == ("pallas", False)


# ---------------------------------------------------------------------------
# jnp-ref lane: parity with interpret, and zero Pallas calls
# ---------------------------------------------------------------------------


def test_jnp_ref_parity_with_interpret():
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(1000), jnp.float32)
    mom = jnp.asarray(rs.randn(1000), jnp.float32)
    dlt = jnp.asarray(rs.randn(1000), jnp.float32)
    kb.set_kernel_backend("jnp-ref")
    q_j, s_j = quantize_blockwise(x, bits=8, block=256)
    d_j = dequantize_blockwise(q_j, s_j, block=256)
    p_j, m_j = pier_update(x, mom, dlt, jnp.float32(0.9), jnp.float32(0.7))
    kb.set_kernel_backend("interpret")
    q_i, s_i = quantize_blockwise(x, bits=8, block=256)
    d_i = dequantize_blockwise(q_i, s_i, block=256)
    p_i, m_i = pier_update(x, mom, dlt, jnp.float32(0.9), jnp.float32(0.7))
    # the quantizer round trip is bitwise across lanes (the kernel body
    # and the oracle run the same reciprocal-multiply graph)
    np.testing.assert_array_equal(np.asarray(q_j), np.asarray(q_i))
    np.testing.assert_array_equal(np.asarray(s_j), np.asarray(s_i))
    np.testing.assert_array_equal(np.asarray(d_j), np.asarray(d_i))
    np.testing.assert_allclose(np.asarray(p_j), np.asarray(p_i), atol=1e-6)

    B, S, H, hd = 1, 32, 2, 16
    q3 = jnp.asarray(rs.randn(B, S, H, hd), jnp.float32)
    k3 = jnp.asarray(rs.randn(B, S, H, hd), jnp.float32)
    v3 = jnp.asarray(rs.randn(B, S, H, hd), jnp.float32)
    kb.set_kernel_backend("jnp-ref")
    o_j = flash_attention(q3, k3, v3)
    n_j = rmsnorm(q3.reshape(-1, hd), jnp.ones((hd,), jnp.float32))
    kb.set_kernel_backend("interpret")
    o_i = flash_attention(q3, k3, v3)
    n_i = rmsnorm(q3.reshape(-1, hd), jnp.ones((hd,), jnp.float32))
    np.testing.assert_allclose(np.asarray(o_j), np.asarray(o_i), atol=2e-6)
    np.testing.assert_allclose(np.asarray(n_j), np.asarray(n_i), atol=1e-6)


def test_jnp_ref_decode_matches_oracle():
    rs = np.random.RandomState(1)
    B, H, hd, N, bs, T = 2, 2, 8, 6, 4, 3
    q = jnp.asarray(rs.randn(B, H, hd), jnp.float32)
    kp = jnp.asarray(rs.randn(N, bs, H, hd), jnp.float32)
    vp = jnp.asarray(rs.randn(N, bs, H, hd), jnp.float32)
    bt = jnp.asarray(rs.randint(0, N, (B, T)), jnp.int32)
    cl = jnp.asarray([5, 9], jnp.int32)
    kb.set_kernel_backend("jnp-ref")
    out = paged_decode_attention(q, kp, vp, bt, cl)
    ref = paged_decode_attention_ref(q, kp, vp, bt, cl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_jnp_ref_needs_no_pallas(monkeypatch):
    """Every ops.py entry point runs with pallas_call stubbed to raise."""
    from jax.experimental import pallas as pl_mod

    def boom(*a, **k):
        raise AssertionError("pallas_call invoked on the jnp-ref lane")

    kb.set_kernel_backend("jnp-ref")
    monkeypatch.setattr(pl_mod, "pallas_call", boom)
    rs = np.random.RandomState(2)
    x = jnp.asarray(rs.randn(512), jnp.float32)
    q, s = kops.quantize_blockwise(x, bits=8, block=128)
    kops.dequantize_blockwise(q, s, block=128)
    B, S, H, hd = 1, 16, 2, 8
    t = jnp.asarray(rs.randn(B, S, H, hd), jnp.float32)
    kops.flash_attention(t, t, t)
    kops.rmsnorm(t, jnp.ones((hd,), jnp.float32))
    kp = jnp.asarray(rs.randn(4, 4, H, hd), jnp.float32)
    kops.paged_decode_attention(
        jnp.asarray(rs.randn(B, H, hd), jnp.float32), kp, kp,
        jnp.zeros((B, 2), jnp.int32), jnp.asarray([3], jnp.int32))
    pier_update(x, x, x, jnp.float32(0.9), jnp.float32(0.5))
    # the compressed outer pipeline's pallas entry too (quant_fns)
    from repro.core.outer import compress_delta

    compress_delta(t.reshape(-1), None, bits=8, block=64, use_pallas=True)


# ---------------------------------------------------------------------------
# backend-aware transport resolution
# ---------------------------------------------------------------------------


def test_transport_off_tpu_is_collective():
    expected = "ring" if compat.HAS_NEW_SHARD_MAP else "psum"
    assert resolve_transport(axis_names=("data_outer",)) == expected
    assert resolve_transport(axis_names=("pod", "data_outer")) == expected


def test_transport_dma_needs_tpu_and_compiled_lane(monkeypatch):
    fallback = "ring" if compat.HAS_NEW_SHARD_MAP else "psum"
    _fake_platform(monkeypatch, "tpu")
    assert resolve_transport(axis_names=("data_outer",)) == "dma"
    # dma never spans multiple exchange axes, never runs without pallas
    assert resolve_transport(
        axis_names=("pod", "data_outer")) == fallback
    assert resolve_transport(
        axis_names=("data_outer",), use_pallas=False) == fallback
    # backend-aware: an interpret/jnp-ref override disables dma even on
    # real TPU hardware (its ring_allreduce lane is not COMPILED there)
    kb.set_kernel_backend("interpret")
    assert resolve_transport(axis_names=("data_outer",)) == fallback
    # a forced tpu-mosaic backend off-TPU still falls back (on_tpu gate)
    _fake_platform(monkeypatch, "cpu")
    kb.set_kernel_backend("tpu-mosaic")
    assert resolve_transport(axis_names=("data_outer",)) == fallback


def test_sync_plans_name_their_transport():
    from repro.sync.strategies import Chunked, FlatFP32, Int8Wire

    pshapes = {"w": jax.ShapeDtypeStruct((64,), jnp.float32)}
    expected = "ring" if compat.HAS_NEW_SHARD_MAP else "psum"
    assert FlatFP32().plan(pshapes, None).transport == "collective"
    assert Int8Wire().plan(pshapes, None).transport == expected
    assert Chunked(inner=Int8Wire(), num_chunks=2).plan(
        pshapes, None).transport == expected


# ---------------------------------------------------------------------------
# env-preset hygiene (append, idempotent, conflict no-op)
# ---------------------------------------------------------------------------


def test_gpu_preset_appends_to_existing_xla_flags():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = apply_env_preset("gpu-triton", env=env)
    flags = env["XLA_FLAGS"].split()
    # the user's flag survives, in place, ahead of the preset's
    assert flags[0] == "--xla_force_host_platform_device_count=8"
    for f in GPU_XLA_FLAGS:
        assert f in flags
    assert r["xla_flags_appended"] == list(GPU_XLA_FLAGS)
    assert r["xla_flags_skipped"] == []


def test_env_preset_is_idempotent():
    env = {}
    apply_env_preset("gpu-triton", env=env)
    before = dict(env)
    r2 = apply_env_preset("gpu-triton", env=env)
    assert env == before
    assert r2["xla_flags_appended"] == []
    assert r2["xla_flags_skipped"] == list(GPU_XLA_FLAGS)
    assert r2["env_set"] == {}


def test_env_preset_noops_on_conflicting_flag():
    # the user disabled async collectives explicitly: the preset must not
    # add a second (winning) occurrence or rewrite the value
    user = "--xla_gpu_enable_async_collectives=false"
    env = {"XLA_FLAGS": user}
    r = apply_env_preset("gpu-triton", env=env)
    assert env["XLA_FLAGS"].split().count(user) == 1
    assert "--xla_gpu_enable_async_collectives=true" not in env["XLA_FLAGS"]
    assert "--xla_gpu_enable_async_collectives=true" in r["xla_flags_skipped"]


def test_host_device_count_preset():
    env = {}
    apply_env_preset("jnp-ref", env=env, host_device_count=4)
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=4"
    # user already forced a count: preset defers
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = apply_env_preset("interpret", env=env, host_device_count=4)
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=8"
    assert r["xla_flags_appended"] == []
    # accelerator lanes never force the host platform count
    env = {}
    apply_env_preset("tpu-mosaic", env=env, host_device_count=4)
    assert "XLA_FLAGS" not in env


def test_env_preset_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown kernel backend"):
        apply_env_preset("rocm")


def test_merge_xla_flags_pure():
    merged, appended, skipped = _merge_xla_flags(
        "--a=1 --b=2", ["--b=3", "--c=4"])
    assert merged == "--a=1 --b=2 --c=4"
    assert appended == ["--c=4"] and skipped == ["--b=3"]


# ---------------------------------------------------------------------------
# acceptance: no default_interpret call sites outside backend.py
# ---------------------------------------------------------------------------


def test_no_default_interpret_callsites_outside_backend():
    import repro

    pkg = list(repro.__path__)[0]
    offenders = []
    for root, _dirs, files in os.walk(pkg):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(root, fname)
            if os.path.join("kernels", "backend.py") in path:
                continue
            with open(path) as f:
                if "default_interpret" in f.read():
                    offenders.append(os.path.relpath(path, pkg))
    assert not offenders, offenders
