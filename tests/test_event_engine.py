"""Unified outer-event engine + adaptive sync controller (DESIGN.md §9).

The contract under test:

- **Event invariants** (property tests over arbitrary legal
  (warmup_frac, sync_interval, sync_delay) triples): every boundary —
  warmup accumulate and outer sync alike — is a dispatch/apply pair with
  ``apply_step = sync_step + delay``; at most one dispatch is ever
  outstanding; an apply always precedes the next dispatch, including
  across the warmup→inner transition.
- **Warmup overlap**: a warmup-overlapped run (``sync_delay > 0`` during
  warmup) is *bit-identical* to eager warmup once the window closes —
  the accumulate reads dispatch-time params and nothing reads the outer
  state inside the window (core/outer.py:warmup_apply) — and full
  delayed runs stay within the 5% convergence bound.
- **Decision controllers**: ``FixedDelayController`` clamps out-of-range
  delays against ``sync_interval``; ``MeasuredDelayController`` re-opens
  measurement every ``remeasure_every`` windows; the
  ``AdaptiveSyncController`` steps down its strategy ladder exactly when
  the measured t_comm stays exposed at the max legal delay.
- **Mid-run strategy switch**: controller-driven switches replay
  bit-for-bit against manual ``switch_strategy`` calls on the simulator,
  and the simulator and the Trainer stay bitwise equal at every sync
  boundary across a switch (zero-inner-LR lockstep, where the outer
  machinery is the entire computation), including the residual
  materialize/drop transitions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import OuterCommConfig, ParallelConfig, TrainConfig
from repro.core.pier import PierSchedule
from repro.core.simulate import SimulatedRun
from repro.sync import (AdaptiveSyncController, DelayDecisionAdapter,
                        FixedDelayController, FlatFP32, Hierarchical,
                        MeasuredDelayController, Quantized,
                        ScriptedSyncController, SyncDecision, default_ladder,
                        resolve_strategy)
from test_delayed_sync import MC, _tc

BLOCK = 64


# ---------------------------------------------------------------------------
# PierSchedule.events invariants (property tests)
# ---------------------------------------------------------------------------


def _sched(total_steps, sync_interval, sync_delay, warmup_frac,
           momentum_warmup=True, optimizer="pier"):
    return PierSchedule(TrainConfig(
        optimizer=optimizer, total_steps=total_steps,
        sync_interval=sync_interval, sync_delay=sync_delay,
        warmup_frac=warmup_frac, momentum_warmup=momentum_warmup,
        lazy_start=optimizer != "diloco",
        global_batch_size=8, seq_len=16))


@given(r=st.integers(1, 7), d_raw=st.integers(0, 6),
       w=st.floats(0.0, 0.6), mw=st.booleans())
@settings(max_examples=40, deadline=None)
def test_events_single_outstanding_and_pairing(r, d_raw, w, mw):
    """At most one outstanding dispatch; every apply matches the one
    outstanding (op, sync_step); apply always precedes the next dispatch,
    uniformly across the warmup→inner boundary."""
    d = min(d_raw, r - 1)
    sched = _sched(60, r, d, w, momentum_warmup=mw)
    outstanding = None  # (op, sync_step, apply_step) | None
    for step in range(60):
        for ev in sched.events(step):
            assert ev.apply_step == ev.sync_step + d
            if ev.kind == "dispatch":
                assert outstanding is None, (step, ev, outstanding)
                assert ev.sync_step == step
                # op matches the phase of the boundary
                expect = "accumulate" if step < sched.warmup_steps else "outer"
                assert ev.op == expect
                outstanding = (ev.op, ev.sync_step, ev.apply_step)
            else:
                assert outstanding == (ev.op, ev.sync_step, ev.apply_step)
                assert ev.apply_step == step
                outstanding = None
        # between steps: the window is empty or within its legal span
        if outstanding is not None:
            assert step < outstanding[2] <= step + d


@given(r=st.integers(1, 7), d_raw=st.integers(0, 6), w=st.floats(0.0, 0.6))
@settings(max_examples=25, deadline=None)
def test_events_every_boundary_dispatches_exactly_once(r, d_raw, w):
    """Dispatch count == boundary count; each in-horizon dispatch gets
    exactly one apply, at sync_step + delay."""
    d = min(d_raw, r - 1)
    sched = _sched(60, r, d, w)
    dispatches, applies = [], []
    for step in range(60 + d):
        for ev in sched.events(step):
            if ev.sync_step >= 60:
                continue  # boundaries past the horizon (drain margin only)
            (dispatches if ev.kind == "dispatch" else applies).append(
                (ev.op, ev.sync_step))
    boundaries = [s for s in range(60) if sched.is_sync_step(s)]
    assert [s for _, s in dispatches] == boundaries
    assert applies == dispatches  # every dispatch applied, in order


def test_events_warmup_window_crosses_phase_boundary():
    """An accumulate dispatched on the last warmup boundary applies inside
    the inner phase — legally (the first outer dispatch is a full
    sync_interval later)."""
    sched = _sched(40, 5, 4, 0.25)  # warmup 10, accumulates at 4, 9
    evs = sched.events(13)  # 9 + 4 — an inner-phase step
    assert [(e.kind, e.op, e.sync_step) for e in evs] == [
        ("apply", "accumulate", 9)]
    # and the first outer dispatch at 14 follows strictly after
    assert [(e.kind, e.op) for e in sched.events(14)] == [
        ("dispatch", "outer")]


def test_momentum_warmup_off_suppresses_accumulate_pairs():
    sched = _sched(40, 5, 2, 0.25, momentum_warmup=False)
    for step in range(10):
        assert sched.events(step) == ()


# ---------------------------------------------------------------------------
# warmup overlap: bit-identity against eager warmup + convergence
# ---------------------------------------------------------------------------


def test_warmup_overlap_bit_identical_to_eager_warmup():
    """Delayed warmup accumulates == eager, bit for bit, once the window
    closes: the accumulate reads dispatch-time params and nothing reads
    the outer state inside the window (core/outer.py:warmup_apply)."""
    tc = _tc(sync_delay=0)  # warmup steps 0..9, accumulates at 4, 9
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    eager.run(13)
    delayed = SimulatedRun(MC, _tc(sync_delay=3), num_groups=2, seed=0)
    delayed.run(13)  # accumulate at 9 applied at 12; first dispatch at 14
    assert delayed._inflight is None
    for a, b in zip(jax.tree.leaves(eager.state.params),
                    jax.tree.leaves(delayed.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(eager.state.outer.momentum),
                    jax.tree.leaves(delayed.state.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(eager.state.outer.anchor),
                    jax.tree.leaves(delayed.state.outer.anchor)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (int(eager.state.outer.num_syncs)
            == int(delayed.state.outer.num_syncs) == 2)


def test_warmup_overlap_mid_window_holds_pre_dispatch_state():
    """Inside an accumulate window the live outer state is the
    pre-dispatch one (the pending result installs at apply_step)."""
    r = SimulatedRun(MC, _tc(sync_delay=3), num_groups=2, seed=0)
    r.run(5)  # accumulate dispatched at 4, pending until 7
    assert r._inflight is not None and r._inflight[1] == "accumulate"
    assert int(r.state.outer.num_syncs) == 0  # pre-dispatch state is live
    r.run(3)  # apply lands at 7
    assert r._inflight is None
    assert int(r.state.outer.num_syncs) == 1


@pytest.mark.slow
def test_warmup_overlap_convergence_within_5pct():
    """Full warmup-overlapped delayed run within 5% of eager — the
    acceptance bound of tests/test_delayed_sync.py, here with a LONG
    warmup (40% of the run) so most of the overlapped windows are warmup
    accumulates. (Warmup overlap itself is bit-neutral — proven exactly
    by test_warmup_overlap_bit_identical_to_eager_warmup — so any loss
    gap comes from the post-warmup overlap depth, same as PR 1.)"""
    tc = _tc(total_steps=60, warmup_frac=0.4, sync_interval=5)
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    he = eager.run(60, eval_every=60)
    delayed = SimulatedRun(MC, tc.replace(sync_delay=2), num_groups=2,
                           seed=0)
    hd = delayed.run(60, eval_every=60)
    ve, vd = he["val_loss"][-1], hd["val_loss"][-1]
    assert vd <= ve * 1.05, (ve, vd)


# ---------------------------------------------------------------------------
# FixedDelayController clamping (satellite: config-time/controller bounds)
# ---------------------------------------------------------------------------


def test_fixed_delay_clamps_against_sync_interval():
    with pytest.warns(UserWarning, match="clamping"):
        ctrl = FixedDelayController(7, sync_interval=5)
    assert ctrl.initial_delay() == 4
    with pytest.warns(UserWarning, match="clamping"):
        ctrl = FixedDelayController(-1, sync_interval=5)
    assert ctrl.initial_delay() == 0
    assert FixedDelayController(3, sync_interval=5).initial_delay() == 3
    with pytest.raises(ValueError):
        FixedDelayController(-1)


def test_config_time_validation_still_raises():
    with pytest.raises(ValueError):
        _tc(sync_delay=5, sync_interval=5)


# ---------------------------------------------------------------------------
# MeasuredDelayController.remeasure_every (satellite)
# ---------------------------------------------------------------------------


def test_remeasure_every_reopens_measurement():
    tc = _tc(sync_delay=0, sync_interval=10)
    ctrl = MeasuredDelayController(tc, min_windows=2, max_windows=2,
                                   skip_windows=0, remeasure_every=3)
    for _ in range(2):
        ctrl.observe_step(t_inner=0.01)
        ctrl.observe_window(t_comm=0.02)
        ctrl.tick_window()
    assert not ctrl.wants_measurement
    assert ctrl.current_delay() == 2
    # three unmeasured windows elapse -> a fresh burst of min_windows
    for i in range(3):
        assert not ctrl.wants_measurement
        ctrl.tick_window()
    assert ctrl.wants_measurement
    # the burst folds fresh (slower-fabric) samples into the EMA
    for _ in range(2):
        ctrl.observe_window(t_comm=0.08)
        ctrl.tick_window()
    assert not ctrl.wants_measurement
    assert ctrl.current_delay() > 2


def test_remeasure_zero_keeps_measure_once_behavior():
    ctrl = MeasuredDelayController(_tc(), min_windows=2, max_windows=3,
                                   skip_windows=0)
    for _ in range(3):
        ctrl.observe_window(t_comm=0.1, t_inner=0.1)
        ctrl.tick_window()
    for _ in range(50):
        ctrl.tick_window()
    assert not ctrl.wants_measurement


def _measured_trainer(tc, *, min_windows=2, max_windows=3):
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    mdc = MeasuredDelayController(tc, min_windows=min_windows,
                                  max_windows=max_windows, skip_windows=1)
    tr = Trainer(MC, tc, pc, mesh,
                 sync_controller=DelayDecisionAdapter(mdc))
    return tr, mdc


def _run_trainer(tr, steps):
    from repro.launch import mesh as M
    from repro.launch.train import synthetic_pipeline

    pipe = synthetic_pipeline(tr.mesh, M.data_axes(tr.mesh), MC, tr.tc)
    try:
        tr.run(steps, pipe, log_every=0)
    finally:
        pipe.close()


def test_warmup_windows_feed_measured_controller():
    """fp32 strategies sample t_comm on the warmup accumulate windows
    (the accumulate reduces the same full-precision tree as an fp32
    outer sync), so d* resolves before the first post-warmup boundary
    instead of burning the first real sync windows on measurement."""
    # warmup 12 of 24, interval 4 -> accumulate boundaries at steps
    # 3/7/11, first outer sync at step 15
    tc = _tc(total_steps=24, sync_interval=4, warmup_frac=0.5,
             sync_delay=0)
    tr, mdc = _measured_trainer(tc)
    _run_trainer(tr, 12)  # warmup only — no outer window has run yet
    assert mdc.windows == 3  # all three accumulate windows were sampled
    assert not mdc.wants_measurement  # max_windows reached inside warmup
    assert mdc.t_comm is not None


def test_warmup_measurement_rescaled_for_compressed_wire():
    """The accumulate always reduces fp32, which over-estimates a packed
    int8 wire's collective by the payload-width ratio — so compressed
    strategies now measure during warmup too, with ``warmup=True``
    samples scaled by ``warmup_scale`` (wire bytes/param over fp32's
    4.0; the scale value itself is unit-tested in test_rs_ag_wire.py).
    Before DESIGN.md §14 these windows were skipped outright and d*
    deferred to the fallback until post-warmup syncs were paid for."""
    tc = _tc(total_steps=24, sync_interval=4, warmup_frac=0.5,
             sync_delay=0,
             outer_comm=OuterCommConfig(compression="int8-wire", bits=8,
                                        block=BLOCK))
    tr, mdc = _measured_trainer(tc)
    _run_trainer(tr, 12)
    assert mdc.windows == 3  # warmup windows sampled like fp32's
    assert not mdc.wants_measurement  # max_windows reached inside warmup
    assert mdc.t_comm is not None


# ---------------------------------------------------------------------------
# AdaptiveSyncController: ladder + exposure-triggered switching
# ---------------------------------------------------------------------------


def test_default_ladder_shapes():
    assert [s.name for s in default_ladder(FlatFP32())] == [
        "flat-fp32", "quantized(int8,block=256)",
        "quantized(int4,block=256)"]
    assert default_ladder(Quantized(8, BLOCK)) == (
        Quantized(8, BLOCK), Quantized(4, BLOCK))
    assert default_ladder(Quantized(4, BLOCK)) == (Quantized(4, BLOCK),)
    # pods + non-hierarchical chain: the last rung toggles the two-stage
    # reduce on the cheapest wire format
    lad = default_ladder(FlatFP32(), num_pods=4)
    assert lad[-1] == Hierarchical(inner=Quantized(4, 256))
    # already-hierarchical chains never double-wrap
    lad = default_ladder(Hierarchical(inner=Quantized(8, BLOCK)), num_pods=4)
    assert lad == (Hierarchical(inner=Quantized(8, BLOCK)),
                   Hierarchical(inner=Quantized(4, BLOCK)))


def _feed(ctrl, *, t_inner, t_comm, windows):
    for _ in range(windows):
        ctrl.observe_step(t_inner)
        ctrl.observe_window(t_comm=t_comm)
        ctrl.tick_window()


def test_adaptive_switches_when_exposed_at_max_delay():
    tc = _tc(sync_delay=0, sync_interval=5)
    ctrl = AdaptiveSyncController(
        tc, ladder=default_ladder(Quantized(8, BLOCK)), min_windows=2,
        max_windows=2)
    assert ctrl.initial_decision() == SyncDecision(0, None)
    # t_comm = 10 x t_inner > max legal delay (4): exposed even fully
    # overlapped -> step down the ladder at max overlap (3 windows: the
    # first wall-clocks compilation and is skipped, then min_windows=2)
    _feed(ctrl, t_inner=0.01, t_comm=0.1, windows=3)
    dec = ctrl.current_decision()
    assert dec.strategy == Quantized(4, BLOCK)
    assert dec.delay == 4
    # measurement restarts against the new wire format (t_inner carried)
    assert ctrl.wants_measurement
    assert ctrl.delay_controller.t_inner is not None
    # the cheaper format fits: settle on its measured d*, no more rungs
    _feed(ctrl, t_inner=0.01, t_comm=0.02, windows=3)
    dec = ctrl.current_decision()
    assert dec.strategy is None and dec.delay == 2


def test_adaptive_keeps_strategy_when_delay_suffices():
    tc = _tc(sync_delay=0, sync_interval=5)
    ctrl = AdaptiveSyncController(
        tc, ladder=default_ladder(Quantized(8, BLOCK)), min_windows=2,
        max_windows=2)
    _feed(ctrl, t_inner=0.01, t_comm=0.03, windows=3)
    dec = ctrl.current_decision()
    assert dec.strategy is None and dec.delay == 3


def test_adaptive_ladder_exhaustion_stays_on_last_rung():
    tc = _tc(sync_delay=0, sync_interval=5)
    ctrl = AdaptiveSyncController(
        tc, ladder=(Quantized(4, BLOCK),), min_windows=2, max_windows=2)
    _feed(ctrl, t_inner=0.01, t_comm=1.0, windows=3)
    dec = ctrl.current_decision()
    assert dec.strategy is None and dec.delay == 4  # clamped, no switch


def test_make_sync_controller_hook():
    """The strategy hook returns the decision protocol: an adapter over
    the (deprecated) delay controller by default, the adaptive ladder
    controller on request."""
    tc, pc = _tc(), ParallelConfig()
    default = FlatFP32().make_sync_controller(tc, MC, pc, chip="")
    assert isinstance(default, DelayDecisionAdapter)
    assert isinstance(default.delay_controller, MeasuredDelayController)
    assert default.initial_decision().strategy is None
    adaptive = Quantized(8, BLOCK).make_sync_controller(
        tc, MC, pc, chip="", adaptive=True, remeasure_every=7)
    assert isinstance(adaptive, AdaptiveSyncController)
    assert adaptive.ladder == (Quantized(8, BLOCK), Quantized(4, BLOCK))
    assert adaptive.delay_controller.remeasure_every == 7


def test_scripted_controller_emits_strategy_once():
    q4 = Quantized(4, BLOCK)
    ctrl = ScriptedSyncController(2, {2: q4})
    assert ctrl.initial_decision() == SyncDecision(2, None)
    ctrl.tick_window()
    assert ctrl.current_decision() == SyncDecision(2, None)
    ctrl.tick_window()
    assert ctrl.current_decision() == SyncDecision(2, q4)
    ctrl.tick_window()
    assert ctrl.current_decision() == SyncDecision(2, None)


def test_scripted_controller_replay_determinism():
    """Scripted decisions are pure data keyed on the window count: two
    controllers built from the same script emit identical decision
    sequences, and a fresh controller replays the exact sequence a prior
    run produced — the property the sim↔Trainer lockstep tests (and
    offline replay of a recorded adaptive run) stand on."""
    def mk():
        return ScriptedSyncController(
            2, {1: SyncDecision(1, None), 3: Quantized(4, BLOCK),
                5: SyncDecision(0, Quantized(8, BLOCK))})

    def drive(ctrl, n=8):
        seq = [ctrl.initial_decision()]
        for _ in range(n):
            ctrl.tick_window()
            seq.append(ctrl.current_decision())
        return seq

    first = drive(mk())
    assert drive(mk()) == first  # same script -> same sequence
    assert first[2] == SyncDecision(1, None)  # standing delay kept
    assert first[3] == SyncDecision(1, Quantized(4, BLOCK))
    assert first[4].strategy is None  # never re-emitted
    assert first[5] == SyncDecision(0, Quantized(8, BLOCK))
    assert first[6] == SyncDecision(0, None)
    assert first[8] == SyncDecision(0, None)
    # wants_measurement never opens: decisions are data, not measurement
    ctrl = mk()
    assert not ctrl.wants_measurement


def test_clamped_delay_edges():
    """The single clamp both engines adopt (DESIGN.md §9/§11)."""
    # delay == sync_interval - 1: the largest legal overlap, unchanged
    assert SyncDecision(4).clamped_delay(5) == 4
    # delay 0 stays eager
    assert SyncDecision(0).clamped_delay(5) == 0
    # interval 1 leaves no legal in-flight window at all
    assert SyncDecision(3).clamped_delay(1) == 0
    assert SyncDecision(0).clamped_delay(1) == 0
    # out-of-range decisions clamp instead of desynchronizing the engines
    assert SyncDecision(-2).clamped_delay(5) == 0
    assert SyncDecision(99).clamped_delay(5) == 4


# ---------------------------------------------------------------------------
# mid-run strategy switch: simulator semantics
# ---------------------------------------------------------------------------


def _sim_tc(**kw):
    base = dict(total_steps=24, global_batch_size=8, seq_len=16,
                sync_interval=4, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25, sync_delay=2)
    base.update(kw)
    return TrainConfig(**base)


def test_controller_switch_bitwise_equals_manual_switch():
    """A scripted controller switching Quantized(8)->Quantized(4) after
    window 2 replays bit-for-bit against manual switch_strategy calls at
    the same boundary — the decision plumbing adds nothing numerically."""
    tc = _sim_tc(outer_comm=OuterCommConfig(compression="quantize",
                                            block=BLOCK))
    q4 = Quantized(4, BLOCK)
    driven = SimulatedRun(MC, tc, num_groups=2, seed=0,
                          sync_controller=ScriptedSyncController(2, {2: q4}))
    driven.run(24)
    driven.flush()

    manual = SimulatedRun(MC, tc, num_groups=2, seed=0)
    # windows (outer dispatches) fire at steps 7, 11, 15, 19, 23; the
    # controller decision lands right after the 2nd dispatch (step 11),
    # flushing its window early — replay that exactly
    manual.run(12)
    manual.switch_strategy(q4)
    manual.run(12)
    manual.flush()

    assert driven.strategy == manual.strategy == q4
    for a, b in zip(jax.tree.leaves(driven.state.group_params),
                    jax.tree.leaves(manual.state.group_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(driven.state.outer.momentum),
                    jax.tree.leaves(manual.state.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(driven.state.outer.residual),
                    jax.tree.leaves(manual.state.outer.residual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_switch_materializes_and_drops_residual():
    """flat -> quantized materializes a zero residual (first-sync
    semantics); quantized -> flat drops it."""
    r = SimulatedRun(MC, _sim_tc(), num_groups=2, seed=0)
    assert r.state.outer.residual is None
    r.run(13)  # past the first outer dispatch/apply (7 -> 9)
    r.switch_strategy(Quantized(8, BLOCK))
    assert r.plan.needs_residual
    leaves = jax.tree.leaves(r.state.outer.residual)
    assert leaves and all(l.shape[0] == 2 for l in leaves)
    assert all(float(jnp.abs(l).max()) == 0.0 for l in leaves)
    r.run(4)  # a quantized sync runs; error feedback populates
    assert any(float(jnp.abs(l).max()) > 0
               for l in jax.tree.leaves(r.state.outer.residual))
    r.switch_strategy(FlatFP32())
    assert r.state.outer.residual is None
    r.run(7)
    r.flush()
    assert int(r.state.outer.num_syncs) >= 4


def test_switch_delay_decision_rebuilds_schedule():
    """A delay-only decision mid-run re-times subsequent windows without
    stranding the in-flight one."""
    ctrl = ScriptedSyncController(2, {2: SyncDecision(0, None)})
    r = SimulatedRun(MC, _sim_tc(), num_groups=2, seed=0,
                     sync_controller=ctrl)
    r.run(24)
    assert r.tc.sync_delay == 0
    assert r._inflight is None  # d=0 windows apply on their own step


# ---------------------------------------------------------------------------
# simulator <-> Trainer lockstep across a switch (bitwise, zero inner LR)
# ---------------------------------------------------------------------------


def _lockstep_pair(tc, controller_a, controller_b, steps=24):
    """Drive a SimulatedRun and a Trainer on identical batches; return
    (sim, trainer, boundary_steps_compared)."""
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    sim = SimulatedRun(MC, tc, num_groups=1, seed=0,
                       sync_controller=controller_a)
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh, sync_controller=controller_b)
    compared = []
    for step in range(steps):
        batch = sim._global_batch(step)
        dist = jax.device_put(batch, tr.bundle.batch_sharding(batch))
        tr.train_step(dist)
        sim.run(1)
        if (step + 1) % tc.sync_interval == 0:
            # a sync boundary: live params and outer state must agree
            # bit for bit (zero inner LR -> the outer machinery is the
            # entire computation on both sides)
            sim_params = (sim.state.group_params if sim.state.group_params
                          is not None else jax.tree.map(
                              lambda x: x[None], sim.state.params))
            for a, b in zip(jax.tree.leaves(sim_params),
                            jax.tree.leaves(tr.state.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(sim.state.outer.momentum),
                            jax.tree.leaves(tr.outer.momentum)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            compared.append(step)
    return sim, tr, compared


@pytest.mark.slow
def test_sim_trainer_lockstep_bitwise_across_switch():
    """Controller-driven mid-run strategy switch, end to end in both
    engines: simulator and Trainer states bitwise equal at every sync
    boundary (zero inner LR isolates the outer event machinery — the
    dispatch windows, the switch flush, and the residual retarget are
    the entire computation)."""
    q4 = Quantized(4, BLOCK)
    tc = _sim_tc(inner_lr=0.0, inner_min_lr=0.0,
                 outer_comm=OuterCommConfig(compression="quantize",
                                            block=BLOCK))
    sim, tr, compared = _lockstep_pair(
        tc, ScriptedSyncController(2, {2: q4}),
        ScriptedSyncController(2, {2: q4}))
    assert len(compared) == 6
    assert sim.strategy == tr.strategy == q4
    assert int(sim.state.outer.num_syncs) == int(tr.outer.num_syncs)


@pytest.mark.slow
def test_sim_trainer_lockstep_bitwise_flat_to_quantized():
    """The residual-materializing transition (flat -> quantized) through
    both engines' retarget paths, bitwise at every boundary."""
    q8 = Quantized(8, BLOCK)
    tc = _sim_tc(inner_lr=0.0, inner_min_lr=0.0)
    sim, tr, compared = _lockstep_pair(
        tc, ScriptedSyncController(2, {3: q8}),
        ScriptedSyncController(2, {3: q8}))
    assert len(compared) == 6
    assert sim.strategy == tr.strategy == q8
    assert sim.state.outer.residual is not None
    assert tr.outer.residual is not None


@pytest.mark.slow
def test_trainer_switch_real_lr_smoke():
    """Real-LR Trainer run across a controller switch: the switch lands,
    the run drains cleanly, and training stays sane (the sim<->trainer
    numeric equivalence on a real mesh rides in md_equivalence.py)."""
    from repro.data.pipeline import synthetic_pipeline
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    q4 = Quantized(4, BLOCK)
    tc = _sim_tc(outer_comm=OuterCommConfig(compression="quantize",
                                            block=BLOCK))
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh,
                 sync_controller=ScriptedSyncController(2, {2: q4}))
    assert tr.strategy == resolve_strategy(tc)
    pipe = synthetic_pipeline(mesh, M.data_axes(mesh), MC, tr.tc)
    try:
        tr.run(24, pipe, log_every=0)
    finally:
        pipe.close()
    assert tr.strategy == q4
    assert tr.bundle.plan.name == q4.name
    assert tr._inflight is None
    assert len(tr._bundles) == 2  # re-jit boundary: one bundle per strategy
    assert np.isfinite(tr.history[-1]["loss"])
