"""Sharded outer exchange (DESIGN.md §10) + span/spec property tests.

Single-device semantics of the :class:`repro.sync.Sharded` combinator
(the mesh-level equivalences live in tests/multidevice/md_equivalence.py),
plus property tests for ``balanced_spans`` and the ``param_spec``
divisibility fallback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import OuterCommConfig, ParallelConfig, TrainConfig
from repro.kernels.ref import aligned_block_count
from repro.parallel.sharding import param_spec
from repro.sync import (FlatFP32, Hierarchical, Int8Wire, Quantized,
                        ReduceCtx, Sharded, balanced_spans,
                        resolve_strategy, strategy_name)


# ---------------------------------------------------------------------------
# balanced_spans properties (satellite: sync/base.py)
# ---------------------------------------------------------------------------


def _sizes_from(seed, n):
    rng = np.random.default_rng(seed)
    return [int(s) for s in rng.integers(1, 10_000, size=n)]


@given(seed=st.integers(0, 2**16), n=st.integers(1, 40),
       num_chunks=st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_balanced_spans_partition_properties(seed, n, num_chunks):
    sizes = _sizes_from(seed, n)
    spans = balanced_spans(sizes, num_chunks)
    # non-empty, contiguous, exactly covering [0, n)
    assert spans[0][0] == 0 and spans[-1][1] == n
    for (lo, hi), (lo2, _) in zip(spans, spans[1:]):
        assert hi == lo2
    for lo, hi in spans:
        assert lo < hi
    # at most num_chunks spans (fewer when there are fewer leaves)
    assert len(spans) <= max(1, min(num_chunks, n))


@given(seed=st.integers(0, 2**16), n=st.integers(2, 40),
       num_chunks=st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_balanced_spans_are_balanced(seed, n, num_chunks):
    """No span exceeds a fair share by more than one leaf's worth."""
    sizes = _sizes_from(seed, n)
    spans = balanced_spans(sizes, num_chunks)
    total = sum(sizes)
    fair = total / len(spans)
    biggest = max(sizes)
    for lo, hi in spans[:-1]:  # the tail span absorbs the remainder
        assert sum(sizes[lo:hi]) <= fair + biggest


def test_balanced_spans_degenerate():
    assert balanced_spans([5], 4) == ((0, 1),)
    assert balanced_spans([1, 1, 1], 1) == ((0, 3),)


# ---------------------------------------------------------------------------
# param_spec divisibility fallback (satellite: parallel/sharding.py)
# ---------------------------------------------------------------------------


@given(kv_heads=st.sampled_from([1, 2, 3, 5, 6]),
       model_size=st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_param_spec_gqa_fallback_replicates(kv_heads, model_size):
    """GQA kv-head dims that don't divide the model axis fall back to
    replicated on that dim instead of erroring."""
    pc = ParallelConfig(data_axis_size=2, model_axis_size=model_size,
                        data_outer=1)
    sizes = {"data_outer": 1, "data_inner": 2, "model": model_size}
    spec = param_spec(("blocks", "attn", "wk"), (64, kv_heads, 16), sizes, pc)
    assert isinstance(spec, jax.sharding.PartitionSpec)
    head_axis = tuple(spec)[1]
    if kv_heads % model_size == 0:
        assert head_axis == "model"
    else:
        assert head_axis is None


def test_param_spec_never_raises_on_awkward_shapes():
    pc = ParallelConfig(data_axis_size=2, model_axis_size=8, data_outer=1)
    sizes = {"data_inner": 2, "model": 8}
    for shape in [(7, 3), (1,), (13, 13, 13), (8, 8)]:
        spec = param_spec(("blocks", "mlp", "w_up"), shape, sizes, pc)
        assert len(tuple(spec)) <= len(shape)


# ---------------------------------------------------------------------------
# aligned_block_count
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 100_000), block=st.sampled_from([1, 32, 256]),
       align=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=30, deadline=None)
def test_aligned_block_count_properties(n, block, align):
    nb = aligned_block_count(n, block, align)
    assert nb % align == 0
    assert nb * block >= n
    # minimal: one fewer aligned step would not cover n
    assert (nb - align) * block < n or nb == align


def test_aligned_block_count_validates():
    with pytest.raises(ValueError):
        aligned_block_count(10, 0)
    with pytest.raises(ValueError):
        aligned_block_count(10, 8, 0)


# ---------------------------------------------------------------------------
# Sharded combinator: resolution, validation, single-device semantics
# ---------------------------------------------------------------------------


def test_sharded_resolution_and_names():
    s = resolve_strategy(OuterCommConfig(sharded=True))
    assert isinstance(s, Sharded) and isinstance(s.inner, FlatFP32)
    assert s.name == "sharded[flat-fp32]"
    assert not s.needs_residual and s.sharded_state

    q = resolve_strategy(OuterCommConfig(
        compression="quantize", bits=8, block=64, sharded=True))
    assert isinstance(q.inner, Quantized)
    assert q.name == "sharded[quantized(int8,block=64)]"
    assert q.needs_residual and q.wire_format == "fp32"
    assert strategy_name(bits=8, block=64, sharded=True) == q.name

    # combinators propagate sharded_state; replicated strategies do not
    h = resolve_strategy(OuterCommConfig(
        compression="quantize", sharded=True, hierarchical=True))
    assert isinstance(h, Hierarchical) and h.sharded_state
    c = resolve_strategy(OuterCommConfig(
        compression="quantize", sharded=True, chunks=3))
    assert c.sharded_state and c.name.startswith("chunked(3)[sharded[")
    assert not resolve_strategy(OuterCommConfig()).sharded_state
    assert not Quantized().sharded_state


def test_sharded_composes_wire_cores_rejects_combinators():
    # Sharded(Int8Wire) now composes (DESIGN.md §14): the wire core is
    # force-normalized onto the rs-ag path so each lane's exchange ships
    # only slot-sized buffers.
    s = Sharded(Int8Wire())
    assert s.inner.reduce_scatter and s.needs_residual2
    assert Sharded(Int8Wire(reduce_scatter=True)).inner.reduce_scatter
    comm = OuterCommConfig(compression="int8-wire", sharded=True)
    r = resolve_strategy(comm)
    assert isinstance(r, Sharded) and r.inner.reduce_scatter
    # nested combinators still cannot ride inside the sharded exchange
    with pytest.raises(ValueError, match="Sharded composes"):
        Sharded(Sharded(FlatFP32()))


def test_sharded_plan_delegates_with_own_name():
    pshapes = {"a": jax.ShapeDtypeStruct((8, 4), jnp.float32),
               "b": jax.ShapeDtypeStruct((16,), jnp.float32)}
    tc = TrainConfig()
    s = Sharded(Quantized(8, 32))
    plan = s.plan(pshapes, tc)
    inner_plan = Quantized(8, 32).plan(pshapes, tc)
    assert plan.name == s.name
    assert plan.spans == inner_plan.spans
    assert plan.needs_residual == inner_plan.needs_residual


def _unit_ctx():
    """A mesh-less ReduceCtx: constraints no-op, auto shard count is 1."""
    return ReduceCtx(manual=(), fast_axes=(), slow_axes=(),
                     exchange_axes=(), axis_sizes={})


@pytest.mark.parametrize("shape", [(13, 7), (16, 8)])
@pytest.mark.parametrize("inner", [FlatFP32(), Quantized(8, 32),
                                   Quantized(4, 16)])
def test_sharded_reduce_leaf_matches_inner_without_mesh(inner, shape):
    """With no auto axes the sharded payload pipeline is bit-identical to
    the inner strategy's — on both sides of the ragged-leaf split:
    (16, 8) divides into whole blocks (shard-local quantize path) while
    (13, 7) is ragged (replicated compress_delta fallback)."""
    tc = TrainConfig()
    d = jnp.asarray(np.random.default_rng(0).normal(size=shape),
                    jnp.float32)
    r = (jnp.asarray(np.random.default_rng(1).normal(size=shape),
                     jnp.float32)
         if inner.needs_residual else None)
    ctx = _unit_ctx()
    base_p, base_r = inner.reduce_leaf(d, r, tc, ctx)
    shard_p, shard_r = Sharded(inner).reduce_leaf(d, r, tc, ctx)
    np.testing.assert_array_equal(np.asarray(base_p), np.asarray(shard_p))
    if inner.needs_residual:
        np.testing.assert_array_equal(np.asarray(base_r),
                                      np.asarray(shard_r))


def test_sharded_sim_reduce_delegates():
    tc = TrainConfig()
    rng = np.random.default_rng(2)
    delta = {"w": jnp.asarray(rng.normal(size=(2, 6, 5)), jnp.float32)}
    res = {"w": jnp.zeros((2, 6, 5), jnp.float32)}
    inner = Quantized(8, 16)
    a_p, a_r = inner.sim_reduce(delta, res, tc)
    b_p, b_r = Sharded(inner).sim_reduce(delta, res, tc)
    np.testing.assert_array_equal(np.asarray(a_p["w"]), np.asarray(b_p["w"]))
    np.testing.assert_array_equal(np.asarray(a_r["w"]), np.asarray(b_r["w"]))


def test_sharded_aligned_padding_keeps_block_contents():
    """Aligned padding adds only all-zero blocks: quantizing the padded
    flat payload reproduces the unpadded blocks bitwise and scales 0 for
    the pad blocks (which the [:n] slice then drops)."""
    from repro.kernels.ref import quantize_blockwise_ref

    rng = np.random.default_rng(3)
    n, block, align = 100, 16, 8
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    nb = aligned_block_count(n, block)  # quantizer's own padding
    nba = aligned_block_count(n, block, align)
    xp = jnp.pad(x, (0, nba * block - n))
    q0, s0 = quantize_blockwise_ref(x, bits=8, block=block)
    q1, s1 = quantize_blockwise_ref(xp, bits=8, block=block)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1[:nb * block]))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1[:nb]))
    assert float(jnp.abs(s1[nb:]).max(initial=0.0)) == 0.0
