"""Property-based tests (hypothesis) for attention-layer invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.models.attention import gqa_attention, chunk_policy
from repro.models import layers as L


def _qkv(seed, B, S, H, Hkv, hd):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, Hkv, hd)),
            jax.random.normal(ks[2], (B, S, Hkv, hd)))


@given(seed=st.integers(0, 2**16), S=st.sampled_from([8, 17, 33]),
       g=st.sampled_from([1, 2, 4]))
@settings(max_examples=15, deadline=None)
def test_causality_property(seed, S, g):
    """Row i of the output is independent of keys/values at positions > i."""
    H, hd = 4, 16
    Hkv = H // g
    q, k, v = _qkv(seed, 1, S, H, Hkv, hd)
    pos = jnp.arange(S)
    out1 = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos,
                         causal=True)
    k2 = k.at[:, -1].set(k[:, -1] + 100.0)
    v2 = v.at[:, -1].set(v[:, -1] - 100.0)
    out2 = gqa_attention(q, k2, v2, q_positions=pos, kv_positions=pos,
                         causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_attention_convexity(seed):
    """Outputs are convex combinations of values: bounded by [min_v, max_v]."""
    q, k, v = _qkv(seed, 2, 16, 2, 2, 8)
    pos = jnp.arange(16)
    out = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos,
                        causal=False)
    assert float(out.max()) <= float(v.max()) + 1e-4
    assert float(out.min()) >= float(v.min()) - 1e-4


@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_chunked_equals_unchunked(seed, chunk):
    """The q-blocked memory-efficient path is numerically identical."""
    S = 32
    q, k, v = _qkv(seed, 1, S, 4, 2, 16)
    pos = jnp.arange(S)
    with chunk_policy("never"):
        ref = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, window=12)
    with chunk_policy(chunk):
        out = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, window=12)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@given(i=st.integers(0, 30), j=st.integers(0, 30),
       delta=st.integers(0, 12), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_rope_relative_property(i, j, delta, seed):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    hd = 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (1, 1, 1, hd))
    k = jax.random.normal(ks[1], (1, 1, 1, hd))

    def score(pi, pj):
        qr = L.apply_rope(q, jnp.array([pi]), 10_000.0)
        kr = L.apply_rope(k, jnp.array([pj]), 10_000.0)
        return float(jnp.sum(qr * kr))

    assert score(i, j) == pytest.approx(score(i + delta, j + delta),
                                        rel=1e-4, abs=1e-4)


@given(seed=st.integers(0, 2**16), p=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_rope_preserves_norm(seed, p):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, 64))
    r = L.apply_rope(x, jnp.array([p]), 10_000.0)
    assert float(jnp.linalg.norm(r)) == pytest.approx(
        float(jnp.linalg.norm(x)), rel=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_softmax_rows_sum_to_one_after_window(seed):
    """Even fully-windowed rows produce finite outputs (self-attention
    always has >= 1 valid key: the diagonal)."""
    S = 24
    q, k, v = _qkv(seed, 1, S, 2, 1, 8)
    pos = jnp.arange(S)
    out = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos,
                        causal=True, window=1)
    assert bool(jnp.isfinite(out).all())
    # window=1 -> each token attends only to itself -> output == v (per head)
    np.testing.assert_allclose(
        np.asarray(out[:, :, 0]), np.asarray(v[:, :, 0]), atol=1e-5)
