"""Pluggable outer-sync strategy API (DESIGN.md §7).

The contract under test:

- **Config shim**: every legacy flat flag combination (``outer_compression``
  × ``hierarchical_reduce`` × ``comm_chunks`` × ``sync_delay``) folds into
  the grouped ``OuterCommConfig`` (with a DeprecationWarning), reads back
  through the legacy properties, survives ``replace()`` round-trips, and
  resolves to the expected strategy object.
- **Equivalence matrix**: a legacy-flag config and its grouped
  ``OuterCommConfig`` spelling produce bit-identical params/momentum on the
  simulator, for every combination the legacy tests cover. (Bit-identity of
  the strategy path to the *pre-refactor* numerics is pinned separately by
  tests/test_delayed_sync.py's inlined legacy loop and
  tests/test_compression.py's knobs-off/int8 suites, which predate the
  strategy API and must keep passing unchanged.)
- **Per-chunk apply**: a chunked plan installs each leaf span through its
  own per-chunk apply; spans are disjoint, so any apply order is
  bit-identical (property test over permutations), the distributed
  per-chunk apply reproduces the unchunked Trainer bitwise, and
  ``comm_chunks > 1, sync_delay > 0`` converges within the same 5% bound
  used by tests/test_delayed_sync.py.
- **Delay controllers**: ``MeasuredDelayController`` defers to the
  analytic-model fallback below 2 measured windows and re-resolves
  d* = ceil(t_comm/t_inner) (clamped) after; unknown ``--chip`` values
  warn and fall back to eager instead of raising mid-run.
"""

import itertools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import OuterCommConfig, ParallelConfig, TrainConfig
from repro.core.simulate import SimulatedRun
from repro.sync import (Chunked, FlatFP32, Hierarchical, MeasuredDelayController,
                        ModelDelayController, Quantized, balanced_spans,
                        resolve_strategy, strategy_name)
from test_delayed_sync import MC, _tc

BLOCK = 64


def _legacy_tc(compression, hier, chunks, delay, **kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25, sync_delay=delay,
                outer_compression=compression, outer_comm_block=BLOCK,
                hierarchical_reduce=hier, comm_chunks=chunks)
    base.update(kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return TrainConfig(**base)


def _grouped_tc(compression, hier, chunks, delay, **kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25, sync_delay=delay,
                outer_comm=OuterCommConfig(
                    compression=compression, block=BLOCK,
                    hierarchical=hier, chunks=chunks))
    base.update(kw)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# config shim
# ---------------------------------------------------------------------------


def test_legacy_flags_fold_into_outer_comm_with_deprecation():
    with pytest.warns(DeprecationWarning):
        tc = TrainConfig(outer_compression="quantize", outer_comm_bits=4,
                         outer_comm_block=32, hierarchical_reduce=True,
                         comm_chunks=3)
    assert tc.outer_comm == OuterCommConfig(
        compression="quantize", bits=4, block=32, hierarchical=True,
        chunks=3)
    # legacy reads go through the grouped config
    assert tc.outer_compression == "quantize"
    assert tc.outer_comm_bits == 4
    assert tc.outer_comm_block == 32
    assert tc.hierarchical_reduce is True
    assert tc.comm_chunks == 3


def test_grouped_config_replace_roundtrips():
    tc = TrainConfig(outer_comm=OuterCommConfig(compression="quantize"))
    assert tc.outer_comm.compression == "quantize"
    # a legacy-key replace folds into the grouped config...
    with pytest.warns(DeprecationWarning):
        tc2 = tc.replace(comm_chunks=4)
    assert tc2.outer_comm.chunks == 4
    assert tc2.outer_comm.compression == "quantize"
    # ...a grouped replace swaps it wholesale...
    tc3 = tc2.replace(outer_comm=OuterCommConfig(hierarchical=True))
    assert tc3.outer_comm == OuterCommConfig(hierarchical=True)
    # ...and non-comm replaces carry it through unchanged.
    tc4 = tc3.replace(sync_delay=2, sync_interval=9)
    assert tc4.outer_comm == tc3.outer_comm
    assert tc4.replace() == tc4


def test_grouped_config_validation():
    with pytest.raises(ValueError):
        OuterCommConfig(compression="int8")
    with pytest.raises(ValueError):
        OuterCommConfig(compression="quantize", bits=5)
    with pytest.raises(ValueError):
        OuterCommConfig(chunks=0)
    with pytest.raises(ValueError):
        OuterCommConfig(block=0)


# ---------------------------------------------------------------------------
# strategy resolution
# ---------------------------------------------------------------------------


def test_resolve_strategy_structure():
    assert resolve_strategy(OuterCommConfig()) == FlatFP32()
    assert resolve_strategy(OuterCommConfig(
        compression="quantize", bits=4, block=32)) == Quantized(4, 32)
    assert resolve_strategy(OuterCommConfig(hierarchical=True)) \
        == Hierarchical(inner=FlatFP32())
    s = resolve_strategy(OuterCommConfig(
        compression="quantize", hierarchical=True, chunks=3))
    assert s == Chunked(inner=Hierarchical(inner=Quantized(8, 256)),
                        num_chunks=3)
    assert s.needs_residual and s.two_stage
    # TrainConfig carrying the grouped (or legacy) knobs resolves the same
    tc = _legacy_tc("quantize", True, 3, 0, outer_comm_bits=8,
                    outer_comm_block=256)
    assert resolve_strategy(tc) == s


def test_strategy_names():
    assert strategy_name() == "flat-fp32"
    assert strategy_name(bits=8, hierarchical=True) \
        == "hierarchical[quantized(int8,block=256)]"
    assert strategy_name(bits=4, block=64, chunks=2) \
        == "chunked(2)[quantized(int4,block=64)]"
    assert strategy_name(chunks=4) == "chunked(4)[flat-fp32]"


def test_balanced_spans_cover_and_order():
    spans = balanced_spans([5, 1, 1, 10, 2, 2], 3)
    assert spans[0][0] == 0 and spans[-1][1] == 6
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c and a < b and c < d
    assert balanced_spans([3], 4) == ((0, 1),)


# ---------------------------------------------------------------------------
# equivalence matrix: legacy flat flags == grouped OuterCommConfig
# ---------------------------------------------------------------------------

# delay 4 = the max legal window at sync_interval 5 — under the unified
# event engine (DESIGN.md §9) delays > 0 also overlap the warmup
# accumulates, so the matrix covers warmup-phase windows as well
MATRIX = list(itertools.product(
    ("none", "quantize"), (False, True), (1, 3), (0, 2, 4)))


@pytest.mark.parametrize("compression,hier,chunks,delay", MATRIX)
def test_legacy_flags_resolve_identically_to_grouped_config(
        compression, hier, chunks, delay):
    """Every (compression × hierarchy × chunks × delay) legacy flag
    combination covered by test_compression.py / test_delayed_sync.py
    folds into a TrainConfig *equal* to its grouped spelling and resolves
    to the same strategy — equal frozen configs drive the deterministic
    simulator/distributed paths identically (run-level bit-identity is
    additionally asserted on representative combos below)."""
    legacy = _legacy_tc(compression, hier, chunks, delay)
    grouped = _grouped_tc(compression, hier, chunks, delay)
    assert legacy == grouped
    assert hash(legacy) == hash(grouped)
    assert resolve_strategy(legacy) == resolve_strategy(grouped)


@pytest.mark.parametrize("compression,hier,chunks,delay",
                         [("none", True, 1, 2), ("quantize", False, 2, 2),
                          ("quantize", False, 1, 4)])
def test_legacy_flags_bit_identical_to_grouped_config_sim(
        compression, hier, chunks, delay):
    """Run-level half of the equivalence matrix: legacy-flag and grouped
    configs produce bit-identical simulator params/momentum."""
    legacy = _legacy_tc(compression, hier, chunks, delay)
    grouped = _grouped_tc(compression, hier, chunks, delay)
    a = SimulatedRun(MC, legacy, num_groups=2, seed=0)
    a.run(25)
    b = SimulatedRun(MC, grouped, num_groups=2, seed=0)
    b.run(25)
    for x, y in zip(jax.tree.leaves(a.state.group_params),
                    jax.tree.leaves(b.state.group_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.state.outer.momentum),
                    jax.tree.leaves(b.state.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# per-chunk apply: ordering / interleaving properties
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mid_flight_chunked():
    """One chunked run paused mid-flight (dispatch at 14, apply due 16),
    shared by the ordering/interleaving tests (its in-flight tuple is
    read-only for them)."""
    tc = _legacy_tc("none", False, 3, 2)
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    r.run(15)
    assert r._inflight is not None
    return r


@given(seed=st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_per_chunk_apply_order_invariant(mid_flight_chunked, seed):
    """Chunks install disjoint leaf spans with per-span corrections, so
    every apply order (early-arriving chunk first, reversed, shuffled)
    lands bit-identically — exercised through the simulator's own
    per-chunk apply path, restoring the in-flight state between orders."""
    r = mid_flight_chunked
    saved_inflight = r._inflight
    saved_group = r.state.group_params
    saved_params = r.state.params
    assert saved_inflight is not None

    def apply_in_order(order):
        r._inflight = saved_inflight
        r.state.group_params = saved_group
        r.state.params = saved_params
        r._apply_inflight(order=order)
        leaves = jax.tree.leaves(r.state.group_params)
        # restore the mid-flight state for the next order / test
        r._inflight = saved_inflight
        r.state.group_params = saved_group
        r.state.params = saved_params
        return leaves

    rng = np.random.default_rng(seed)
    n = r.plan.num_chunks
    ref = apply_in_order(list(range(n)))
    for order in (list(range(n))[::-1], list(rng.permutation(n))):
        got = apply_in_order(order)
        for x, y in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_per_chunk_apply_interleaves_with_inner_steps(mid_flight_chunked):
    """Between dispatch and the per-chunk applies the groups keep training;
    the partial corrections preserve that in-flight progress exactly as
    the fused apply does (bitwise, since spans partition the leaves)."""
    r = mid_flight_chunked
    leaf = jax.tree.leaves(r.state.group_params)[0]
    assert float(jnp.abs(leaf[0] - leaf[1]).max()) > 0  # still diverged
    r.run(2)  # apply lands at 16, span by span
    assert r._inflight is None
    ref = SimulatedRun(MC, _tc(sync_delay=2), num_groups=2, seed=0)
    ref.run(17)
    for x, y in zip(jax.tree.leaves(ref.state.group_params),
                    jax.tree.leaves(r.state.group_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_per_chunk_apply_convergence_within_5pct():
    """comm_chunks>1 with sync_delay>0 (the per-chunk apply pipeline, with
    a quantized payload) stays within the 5% bound of the eager fp32
    baseline — the acceptance bound of tests/test_delayed_sync.py."""
    tc = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5)
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    he = eager.run(60, eval_every=60)
    tcc = _legacy_tc("quantize", False, 3, 2, total_steps=60,
                     warmup_frac=0.2, sync_interval=5)
    chunked = SimulatedRun(MC, tcc, num_groups=2, seed=0)
    hc = chunked.run(60, eval_every=60)
    ve, vc = he["val_loss"][-1], hc["val_loss"][-1]
    assert vc <= ve * 1.05, (ve, vc)


# ---------------------------------------------------------------------------
# distributed path: grouped config == legacy flags, per-chunk apply bitwise
# ---------------------------------------------------------------------------


def _trainer_run(tc, steps=20):
    from repro.data.pipeline import synthetic_pipeline
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh)
    pipe = synthetic_pipeline(mesh, M.data_axes(mesh), MC, tr.tc)
    try:
        tr.run(steps, pipe, log_every=0)
    finally:
        pipe.close()
    return tr


def test_distributed_grouped_config_matches_legacy_flags():
    base = dict(optimizer="pier", total_steps=20, global_batch_size=4,
                seq_len=16, sync_interval=4, warmup_frac=0.25, seed=0)
    legacy = _trainer_run(TrainConfig(
        **base, sync_delay=2, comm_chunks=2, outer_compression="quantize",
        outer_comm_block=BLOCK))
    grouped = _trainer_run(TrainConfig(
        **base, sync_delay=2, outer_comm=OuterCommConfig(
            compression="quantize", block=BLOCK, chunks=2)))
    assert legacy.strategy == grouped.strategy
    for a, b in zip(jax.tree.leaves(legacy.state.params),
                    jax.tree.leaves(grouped.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(legacy.outer.residual),
                    jax.tree.leaves(grouped.outer.residual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# delay controllers
# ---------------------------------------------------------------------------


def test_measured_delay_falls_back_below_two_windows():
    tc = _tc(sync_delay=4, sync_interval=10)
    model = ModelDelayController(tc, MC, ParallelConfig(), chip="")
    ctrl = MeasuredDelayController(tc, fallback=model, skip_windows=1)
    assert ctrl.initial_delay() == 0  # no chip hint -> model says eager
    assert ctrl.current_delay() == 0  # no windows yet -> fallback
    ctrl.observe_step(t_inner=0.01)
    ctrl.observe_window(t_comm=5.0)  # compile-dominated, skipped
    ctrl.observe_window(t_comm=0.05)
    assert ctrl.current_delay() == 0  # only 1 measured window -> fallback
    ctrl.observe_window(t_comm=0.05)
    # >= 2 measured windows: d* = ceil(0.05 / 0.01) = 5
    assert ctrl.current_delay() == 5


def test_measured_delay_clamps_to_sync_interval():
    tc = _tc(sync_delay=0, sync_interval=5)
    ctrl = MeasuredDelayController(tc, skip_windows=0)
    for _ in range(3):
        ctrl.observe_step(t_inner=0.001)
        ctrl.observe_window(t_comm=10.0)
    assert ctrl.current_delay() == tc.sync_interval - 1
    assert not ctrl.wants_measurement or ctrl.windows < ctrl.max_windows


def test_measured_delay_stops_measuring_after_max_windows():
    ctrl = MeasuredDelayController(_tc(), min_windows=2, max_windows=3,
                                   skip_windows=0)
    assert ctrl.wants_measurement
    for _ in range(3):
        ctrl.observe_window(t_comm=0.1, t_inner=0.1)
    assert not ctrl.wants_measurement


def test_unknown_chip_warns_and_falls_back_to_eager():
    """An unknown --chip value must not raise mid-run: resolve warns and
    the launcher falls back to d*=0."""
    from repro.launch.train import resolve_auto_sync_delay

    tc = _tc(sync_delay="auto")
    pc = ParallelConfig(data_axis_size=16, model_axis_size=16, data_outer=4)
    with pytest.warns(UserWarning, match="unknown chip"):
        d = resolve_auto_sync_delay(tc, MC, pc, chip="warp-drive")
    assert d == 0


def test_trainer_auto_delay_measures_and_re_resolves():
    """sync_delay='auto' without a chip hint: starts eager, measures the
    first sync windows, and re-resolves d* from the EMAs."""
    tc = TrainConfig(optimizer="pier", total_steps=24, global_batch_size=4,
                     seq_len=16, sync_interval=4, warmup_frac=0.25,
                     sync_delay="auto")
    tr = _trainer_run(tc, steps=24)
    assert tr.delay_controller is not None
    assert tr.delay_controller.windows >= 2
    assert isinstance(tr.tc.sync_delay, int)
    assert 0 <= tr.tc.sync_delay < tr.tc.sync_interval
    # the run drained cleanly (no stranded in-flight dispatch)
    assert tr._inflight is None


def test_strategy_delay_controller_hook_is_injectable():
    """A custom strategy can override the sync_delay='auto' injection
    point — the hook returns the controller, not a hardcoded lookup."""
    from repro.sync import DelayController

    class Always3(DelayController):
        def initial_delay(self):
            return 3

    class MyStrategy(FlatFP32):
        def make_delay_controller(self, tc, mc, pc, *, chip="",
                                  measured=True):
            return Always3()

    ctrl = MyStrategy().make_delay_controller(_tc(), MC, ParallelConfig())
    assert ctrl.initial_delay() == 3
    # the default hook wires measured-with-model-fallback
    default = FlatFP32().make_delay_controller(
        _tc(), MC, ParallelConfig(), chip="")
    assert isinstance(default, MeasuredDelayController)
    assert isinstance(default.fallback, ModelDelayController)
