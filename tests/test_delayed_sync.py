"""Delayed (overlapped) outer sync: schedule events, exactness, convergence.

The contract under test (see DESIGN.md):

- ``sync_delay = 0`` is bit-identical to the pre-delay eager path (the
  dispatch+apply pair degenerates to the classic fused outer step).
- ``sync_delay = d`` applies the Δθ dispatched at sync step t at step t+d,
  with the stale-delta correction preserving in-flight inner progress.
- With zero inner LR there is no in-flight progress, so any delay matches
  eager exactly.
- The delay moves *when* the outer result lands, never *how often* the
  global collective fires: ``global_comm_fraction`` is delay-invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import ModelConfig, TrainConfig
from repro.core.outer import OuterState, outer_apply, outer_update
from repro.core.pier import PierSchedule
from repro.core.simulate import SimulatedRun

MC = ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                 d_ff=128, vocab_size=128, dtype="float32",
                 norm="layernorm", activation="gelu", positional="learned",
                 max_position_embeddings=64)


def _tc(**kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25)
    base.update(kw)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_sync_delay_validation():
    with pytest.raises(ValueError):
        _tc(sync_delay=-1)
    with pytest.raises(ValueError):
        _tc(sync_delay=5, sync_interval=5)  # apply must precede next dispatch
    _tc(sync_delay=4, sync_interval=5)  # largest legal delay


# ---------------------------------------------------------------------------
# schedule event model
# ---------------------------------------------------------------------------


def test_events_eager_fused():
    """d=0: dispatch immediately followed by its own apply, same step."""
    sched = PierSchedule(_tc(sync_delay=0))
    evs = sched.events(14)  # first post-warmup boundary (warmup ends at 10)
    assert [e.kind for e in evs] == ["dispatch", "apply"]
    assert all(e.op == "outer" for e in evs)
    assert all(e.sync_step == 14 and e.apply_step == 14 for e in evs)
    # the warmup accumulate boundary is a fused pair too (DESIGN.md §9)
    evs = sched.events(4)
    assert [(e.kind, e.op) for e in evs] == [("dispatch", "accumulate"),
                                             ("apply", "accumulate")]


def test_events_warmup_inner_transition():
    """Accumulate pairs strictly anchored inside warmup; outer pairs
    strictly after — both flowing through the same dispatch/apply model
    with the per-event apply_step = sync_step + delay."""
    sched = PierSchedule(_tc(sync_delay=2))  # warmup = steps 0..9
    kinds = {}
    for step in range(40):
        for ev in sched.events(step):
            kinds.setdefault((ev.kind, ev.op), []).append(step)
            assert ev.apply_step == ev.sync_step + 2
    assert kinds[("dispatch", "accumulate")] == [4, 9]
    # the second accumulate's apply (step 11) lands PAST the warmup→inner
    # boundary — the window legally crosses phases (d < sync_interval)
    assert kinds[("apply", "accumulate")] == [6, 11]
    assert kinds[("dispatch", "outer")] == [14, 19, 24, 29, 34, 39]
    assert kinds[("apply", "outer")] == [16, 21, 26, 31, 36]
    # the final dispatch (39) is in flight at the horizon — the host loop
    # drains it via flush(); the schedule itself never emits its apply here.


@pytest.mark.parametrize("delay", [1, 2, 4])
def test_events_dispatch_apply_interleaving(delay):
    """At most one dispatch in flight; applies always precede the next
    dispatch — uniformly over accumulate and outer events."""
    sched = PierSchedule(_tc(sync_delay=delay, total_steps=200))
    outstanding = 0
    for step in range(200):
        for ev in sched.events(step):
            if ev.kind == "dispatch":
                outstanding += 1
            elif ev.kind == "apply":
                assert ev.sync_step == step - delay
                outstanding -= 1
            assert 0 <= outstanding <= 1, (step, ev)


@given(delay=st.integers(0, 4), seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_global_comm_fraction_invariant_under_delay(delay, seed):
    """The delay hides the collective; it never changes how often it runs."""
    tc0 = _tc(sync_delay=0)
    tcd = _tc(sync_delay=delay)
    assert (PierSchedule(tcd).global_comm_fraction()
            == PierSchedule(tc0).global_comm_fraction())
    # and the dispatch *count* over a horizon is identical too
    n0 = sum(1 for s in range(40) if PierSchedule(tc0).is_dispatch_step(s))
    nd = sum(1 for s in range(40) if PierSchedule(tcd).is_dispatch_step(s))
    assert n0 == nd


# ---------------------------------------------------------------------------
# outer_apply algebra
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_outer_apply_zero_drift_is_bitwise_identity(seed):
    """apply(target, p, p) == target exactly — the d=0 fusion argument."""
    rng = np.random.default_rng(seed)
    target = {"a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=7).astype(np.float32))}
    p = {"a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=7).astype(np.float32))}
    out = outer_apply(target, p, p)
    for k in target:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(target[k]))


def test_outer_apply_preserves_inflight_progress():
    target = {"w": jnp.zeros(4)}
    dispatch = {"w": jnp.asarray([1.0, 1.0, 1.0, 1.0])}
    current = {"w": jnp.asarray([1.5, 2.0, 0.5, 1.0])}
    out = outer_apply(target, dispatch, current)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.5, 1.0, -0.5, 0.0])


# ---------------------------------------------------------------------------
# sync_delay=0 is bit-identical to the pre-delay eager loop
# ---------------------------------------------------------------------------


def _run_legacy_eager(tc, num_groups, seed, num_steps):
    """The pre-delay simulator loop, verbatim: one fused outer event that
    means+updates+broadcasts at every sync boundary. Reuses the jitted inner
    machinery of SimulatedRun so only the outer event model differs."""
    r = SimulatedRun(MC, tc, num_groups=num_groups, seed=seed)
    st_, sched = r.state, r.sched

    def do_outer(group_params, outer, mu, lr):
        mean_params = jax.tree.map(
            lambda p: jnp.mean(p.astype(jnp.float32), axis=0), group_params)
        delta = jax.tree.map(
            lambda m, a: m - a.astype(jnp.float32), mean_params, outer.anchor)
        new_params_f32, new_outer = outer_update(outer, delta, tc, mu=mu,
                                                 lr=lr)
        new_group = jax.tree.map(
            lambda f, g: jnp.broadcast_to(f.astype(g.dtype), g.shape),
            new_params_f32, group_params)
        return new_group, new_outer

    legacy_outer = jax.jit(do_outer)
    for _ in range(num_steps):
        step = st_.step
        if sched.phase(step) == "warmup":
            batch = r._global_batch(step)
            st_.params, st_.opt, _ = r._warmup_step(
                st_.params, st_.opt, batch, jnp.asarray(step))
            if sched.is_sync_step(step):
                st_.outer = r._accumulate(
                    st_.outer, st_.params, jnp.float32(sched.mu_at(step)))
            elif (step + 1) % tc.sync_interval == 0:
                st_.outer = OuterState(
                    momentum=st_.outer.momentum,
                    anchor=jax.tree.map(lambda p, a: p.astype(a.dtype),
                                        st_.params, st_.outer.anchor),
                    num_syncs=st_.outer.num_syncs)
        else:
            if st_.group_params is None:
                r._switch_to_groups()
            batches = r._group_batches(step)
            st_.group_params, st_.opt, _ = r._inner_step(
                st_.group_params, st_.opt, batches, jnp.asarray(step))
            if sched.is_sync_step(step):
                st_.group_params, st_.outer = legacy_outer(
                    st_.group_params, st_.outer,
                    jnp.float32(sched.mu_at(step)),
                    jnp.float32(sched.outer_lr_at(step)))
                st_.params = jax.tree.map(lambda g: g[0], st_.group_params)
        st_.step += 1
    return r


def test_delay_zero_bit_identical_to_eager():
    tc = _tc(sync_delay=0)
    new = SimulatedRun(MC, tc, num_groups=2, seed=0)
    new.run(30)  # warmup, accumulates, switch, 4 outer syncs
    ref = _run_legacy_eager(tc, 2, 0, 30)
    for a, b in zip(jax.tree.leaves(new.state.group_params),
                    jax.tree.leaves(ref.state.group_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(new.state.outer.momentum),
                    jax.tree.leaves(ref.state.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(new.state.outer.num_syncs) == int(ref.state.outer.num_syncs)


# ---------------------------------------------------------------------------
# delayed semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delay", [1, 2])
def test_delay_with_zero_inner_lr_matches_eager_exactly(delay):
    """No inner progress -> no in-flight drift -> any delay == eager."""
    tcz = _tc(inner_lr=0.0, inner_min_lr=0.0)
    eager = SimulatedRun(MC, tcz, num_groups=2, seed=0)
    eager.run(30)
    delayed = SimulatedRun(MC, tcz.replace(sync_delay=delay), num_groups=2,
                           seed=0)
    delayed.run(30)
    delayed.flush()
    # compare at a point where neither has a sync in flight
    for a, b in zip(jax.tree.leaves(eager.state.group_params),
                    jax.tree.leaves(delayed.state.group_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_delayed_groups_stay_diverged_during_flight():
    """Between dispatch and apply the groups keep training (no barrier)."""
    tc = _tc(sync_delay=2)
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    r.run(15)  # dispatch fires at step 14; in-flight until 16
    assert r._inflight is not None
    leaf = jax.tree.leaves(r.state.group_params)[0]
    assert float(jnp.abs(leaf[0] - leaf[1]).max()) > 0
    r.run(2)  # apply lands at 16
    assert r._inflight is None


def test_flush_mid_flight_then_continue():
    """Draining early (checkpoint / segmented run) must not crash or
    double-apply when the schedule's step-based apply event later fires."""
    tc = _tc(sync_delay=2)
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    r.run(15)  # dispatch at 14 in flight
    assert r._inflight is not None
    r.flush()  # early drain
    assert r._inflight is None
    r.flush()  # idempotent
    r.run(5)  # crosses step 16, where the apply event fires as a no-op
    assert r._inflight is None or r._inflight[0] > 16


@pytest.mark.parametrize("delay", [1, 2])
def test_delayed_convergence_within_5pct(delay):
    """MarkovLM validation loss with overlap within 5% of eager (paper-style
    acceptance: relaxing the sync point must not degrade convergence)."""
    tc = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5)
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    he = eager.run(60, eval_every=60)
    delayed = SimulatedRun(MC, tc.replace(sync_delay=delay), num_groups=2,
                           seed=0)
    hd = delayed.run(60, eval_every=60)
    ve, vd = he["val_loss"][-1], hd["val_loss"][-1]
    assert vd <= ve * 1.05, (ve, vd)
