"""Unit tests for the dry-run's HLO accounting (no devices needed)."""

import pytest

pytest.importorskip("jax")

# NOTE: importing repro.launch.dryrun would force 512 host devices into this
# process; the parsers live at module level so we import the module source
# WITHOUT executing the jax-touching parts by vendoring the regexes through
# a controlled import of the functions only.
import importlib.util
import os

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro", "launch",
                   "dryrun.py")


def _load_parsers():
    """Execute dryrun.py with XLA_FLAGS already set to 1 device so the
    module import doesn't change this process's device count."""
    os.environ.setdefault("XLA_FLAGS", "")
    spec = importlib.util.spec_from_file_location("_dryrun_parsers", SRC)
    mod = importlib.util.module_from_spec(spec)
    saved = os.environ.get("XLA_FLAGS")
    spec.loader.exec_module(mod)
    if saved is not None:
        os.environ["XLA_FLAGS"] = saved
    return mod


DR = _load_parsers()


HLO = """
  %ar = f32[1024,512]{1,0} all-reduce(%x), channel_id=1, replica_groups={{0,1}}
  %ag.1 = bf16[64]{0} all-gather(%y), dimensions={0}
  %rs = (f32[128]{0}, f32[32]{0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%z), dimensions={0}
  %cp-start = f32[8]{0} collective-permute-start(%w)
  %cp-done = f32[8]{0} collective-permute-done(%cp-start)
  %notacoll = f32[999]{0} add(%p, %q)
"""


def test_collective_bytes_parser():
    out = DR.collective_bytes(HLO)
    assert out["all-reduce"] == 1024 * 512 * 4
    assert out["all-gather"] == 64 * 2
    assert out["reduce-scatter"] == 128 * 4 + 32 * 4
    assert out["all-to-all"] == 256 * 4
    assert out["collective-permute"] == 8 * 4  # start counted, done skipped


def test_convert_artifact_parser():
    txt = """
%wrapped_convert_computation.17 (param_0.552: bf16[59,10,1280,1536]) -> f32[59,10,1280,1536] {
%wrapped_convert_computation.18 (param_0.553: bf16[4,4]) -> f32[4,4] {
"""
    n = DR.cpu_convert_artifact_bytes(txt)
    assert n == (59 * 10 * 1280 * 1536 + 16) * 4


def test_extrapolate_cost_linear():
    r1 = {"flops": 100.0, "bytes_accessed": 10.0,
          "collective_bytes": {"all-reduce": 4.0}}
    r2 = {"flops": 180.0, "bytes_accessed": 18.0,
          "collective_bytes": {"all-reduce": 6.0, "all-gather": 2.0}}
    out = DR.extrapolate_cost(r1, r2, 2, 4, 10)
    assert out["flops"] == pytest.approx(100 + 40 * 8)
    assert out["bytes_accessed"] == pytest.approx(10 + 4 * 8)
    assert out["collective_bytes"]["all-reduce"] == pytest.approx(4 + 8)
    # a kind absent at L1 extrapolates from zero
    assert out["collective_bytes"]["all-gather"] == pytest.approx(0 + 8)


def test_long_skip_set():
    assert "deepseek-v2-236b" in DR.LONG_SKIP
    assert "kimi-k2-1t-a32b" in DR.LONG_SKIP
    assert "whisper-large-v3" in DR.LONG_SKIP
    shape = DR.INPUT_SHAPES["long_500k"]
    assert DR.resolve_model("deepseek-v2-236b", shape) is None
    swa = DR.resolve_model("granite-8b", shape)
    assert swa is not None and swa.sliding_window == DR.SWA_WINDOW


def test_cost_depths():
    from repro.configs import get_config

    assert DR.cost_depths(get_config("granite-8b"))[:2] == (1, 2)
    assert DR.cost_depths(get_config("deepseek-v2-236b"))[:2] == (2, 3)
    l1, l2, c = DR.cost_depths(get_config("recurrentgemma-9b"))
    assert (l1, l2, c) == (3, 6, 3)
    l1, l2, c = DR.cost_depths(get_config("xlstm-1.3b"))
    assert (l1, l2, c) == (8, 16, 8)
