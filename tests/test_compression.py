"""Compressed hierarchical outer collective (DESIGN.md §6).

The contract under test:

- Blockwise quantization round-trips within ``scale/2`` per element, the
  Pallas kernels match the jnp oracle bit for bit, and error feedback
  telescopes: the sum of dequantized payloads plus the final residual
  equals the sum of the true deltas.
- ``outer_compression="none"`` + ``comm_chunks=1`` + no hierarchy is the
  seed path, bit for bit — on the simulator (vs the legacy eager loop, on
  both the XLA and Pallas outer update) and on the distributed path
  (chunked / hierarchical-without-pods runs reproduce the default Trainer
  bitwise).
- int8 + error feedback converges within 5% of the fp32 eager baseline
  (mirrors tests/test_delayed_sync.py's acceptance).
- ``sync_delay="auto"`` resolves d* from the overlap step-time model and
  falls back to 0 without an estimate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import ParallelConfig, TrainConfig
from repro.core.outer import compress_delta, outer_init
from repro.core.simulate import SimulatedRun
from repro.kernels import ops as kops
from repro.kernels.ref import (dequantize_blockwise_ref,
                               quantize_blockwise_ref)
from test_delayed_sync import MC, _run_legacy_eager

BLOCK = 64


def _tc(**kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25)
    base.update(kw)
    return TrainConfig(**base)


def _ctc(**kw):
    kw.setdefault("outer_compression", "quantize")
    kw.setdefault("outer_comm_bits", 8)
    kw.setdefault("outer_comm_block", BLOCK)
    return _tc(**kw)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_compression_config_validation():
    with pytest.raises(ValueError):
        _tc(outer_compression="int8")  # not a mode name
    with pytest.raises(ValueError):
        _ctc(outer_comm_bits=5)
    with pytest.raises(ValueError):
        _tc(comm_chunks=0)
    with pytest.raises(ValueError):
        _tc(outer_comm_block=0)
    with pytest.raises(ValueError):
        _tc(sync_delay="later")
    _tc(sync_delay="auto")  # the auto sentinel is legal, resolved at launch
    _ctc(outer_comm_bits=4, comm_chunks=3, hierarchical_reduce=True)


# ---------------------------------------------------------------------------
# quantize / dequantize kernels
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    """Per element: |x − DQ(Q(x))| <= scale/2 (round-to-nearest, no clip
    error beyond 1 ulp of the scale)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=1000).astype(np.float32) * 10.0)
    for bits in (8, 4):
        q, s = quantize_blockwise_ref(x, bits=bits, block=BLOCK)
        dq = dequantize_blockwise_ref(q, s, block=BLOCK)[:1000]
        srep = np.repeat(np.asarray(s), BLOCK)[:1000]
        err = np.abs(np.asarray(x) - np.asarray(dq))
        assert (err <= srep / 2 + 1e-5).all(), (bits, err.max())


def test_quantize_pallas_matches_ref_bitwise():
    rng = np.random.default_rng(0)
    for n in (7, 300, 1000, 4096):
        x = jnp.asarray(rng.normal(size=n).astype(np.float32))
        for bits in (8, 4):
            q, s = kops.quantize_blockwise(x, bits=bits, block=BLOCK)
            qr, sr = quantize_blockwise_ref(x, bits=bits, block=BLOCK)
            np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
            np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
            dq = kops.dequantize_blockwise(q, s, block=BLOCK)
            dqr = dequantize_blockwise_ref(qr, sr, block=BLOCK)
            np.testing.assert_array_equal(np.asarray(dq), np.asarray(dqr))


def test_quantize_zero_block_is_exact():
    q, s = quantize_blockwise_ref(jnp.zeros(2 * BLOCK), block=BLOCK)
    assert (np.asarray(s) == 0).all()
    dq = dequantize_blockwise_ref(q, s, block=BLOCK)
    assert (np.asarray(dq) == 0).all()


def test_pier_update_interpret_default_resolves():
    """interpret=None resolves backend-aware (interpreter off-TPU) and the
    kernel still matches the oracle — the perf-bug fix for direct callers."""
    from repro.kernels.pier_update import pier_update
    from repro.kernels.ref import pier_update_ref

    rng = np.random.default_rng(0)
    a, m, d = (jnp.asarray(rng.normal(size=300).astype(np.float32))
               for _ in range(3))
    p1, m1 = pier_update(a, m, d, jnp.float32(0.9), jnp.float32(0.7))
    pr, mr = pier_update_ref(a, m, d, mu=0.9, lr=0.7)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(pr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(mr), atol=1e-6)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_error_feedback_telescopes(bits):
    """sum(payload_t) + residual_T == sum(delta_t): the quantization error
    is carried, never dropped — so it cannot bias the outer momentum."""
    tc = _ctc(outer_comm_bits=bits)
    rng = np.random.default_rng(1)
    tree = lambda: {
        "w": jnp.asarray(rng.normal(size=(13, 11)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=7).astype(np.float32))}
    residual = jax.tree.map(jnp.zeros_like, tree())
    deltas, payloads = [], []
    for _ in range(6):
        d = tree()
        deltas.append(d)
        payload, residual = compress_delta(d, residual, tc)
        payloads.append(payload)
    for k in ("w", "b"):
        true_sum = sum(np.asarray(d[k], np.float64) for d in deltas)
        sent_sum = sum(np.asarray(p[k], np.float64) for p in payloads)
        np.testing.assert_allclose(
            sent_sum + np.asarray(residual[k], np.float64), true_sum,
            rtol=1e-4, atol=1e-4)


def test_compress_delta_single_round_identity():
    """payload + residual == delta + residual_in exactly per round."""
    tc = _ctc()
    rng = np.random.default_rng(2)
    d = {"w": jnp.asarray(rng.normal(size=130).astype(np.float32))}
    r0 = {"w": jnp.asarray(rng.normal(size=130).astype(np.float32) * 1e-3)}
    payload, r1 = compress_delta(d, r0, tc)
    c = np.asarray(d["w"]) + np.asarray(r0["w"])
    np.testing.assert_allclose(
        np.asarray(payload["w"]) + np.asarray(r1["w"]), c, atol=1e-6)


def test_outer_init_residual_shapes():
    params = {"w": jnp.ones((3, 4)), "b": jnp.ones(5)}
    st_none = outer_init(params, _tc())
    assert st_none.residual is None
    st_q = outer_init(params, _ctc(), num_groups=2)
    assert st_q.residual["w"].shape == (2, 3, 4)
    assert st_q.residual["b"].shape == (2, 5)
    assert st_q.residual["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# knobs-off bit-identity (simulator)
# ---------------------------------------------------------------------------


def test_compression_none_bit_identical_to_legacy_eager():
    """Explicit knobs-off config reproduces the pre-compression eager loop
    bit for bit (and carries no residual)."""
    tc = _tc(outer_compression="none", comm_chunks=1,
             hierarchical_reduce=False, sync_delay=0)
    new = SimulatedRun(MC, tc, num_groups=2, seed=0)
    new.run(30)
    ref = _run_legacy_eager(tc, 2, 0, 30)
    for a, b in zip(jax.tree.leaves(new.state.group_params),
                    jax.tree.leaves(ref.state.group_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(new.state.outer.momentum),
                    jax.tree.leaves(ref.state.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert new.state.outer.residual is None


def test_compression_none_outer_update_xla_vs_pallas():
    """Knobs-off outer update agrees across the XLA and Pallas backends and
    neither grows a residual — the 'both backends' half of the knobs-off
    acceptance (the collective itself is backend-independent)."""
    from repro.core.outer import outer_update

    tc = _tc(outer_compression="none")
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.normal(size=(9, 5)).astype(np.float32))}
    state = outer_init(params, tc)
    delta = {"w": jnp.asarray(rng.normal(size=(9, 5)).astype(np.float32))}
    px, sx = outer_update(state, delta, tc, mu=0.9, lr=0.7,
                          use_pallas=False)
    pp, sp = outer_update(state, delta, tc, mu=jnp.float32(0.9),
                          lr=jnp.float32(0.7), use_pallas=True)
    np.testing.assert_allclose(np.asarray(px["w"]), np.asarray(pp["w"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(sx.momentum["w"]),
                               np.asarray(sp.momentum["w"]), atol=1e-6)
    assert sx.residual is None and sp.residual is None


# ---------------------------------------------------------------------------
# distributed path (single host device: 1x1x1 mesh, group semantics intact)
# ---------------------------------------------------------------------------


def _trainer_run(tc, steps=20):
    from repro.data.pipeline import synthetic_pipeline
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh)
    pipe = synthetic_pipeline(mesh, M.data_axes(mesh), MC, tc)
    try:
        tr.run(steps, pipe, log_every=0)
    finally:
        pipe.close()
    return tr


def test_distributed_chunked_and_hier_bit_identical_to_default():
    """comm_chunks>1 (leaf-span repartitioning) and hierarchical_reduce on
    a pod-less mesh both reproduce the default Trainer bitwise — the
    distributed knobs-off bit-identity acceptance."""
    base = dict(optimizer="pier", total_steps=20, global_batch_size=4,
                seq_len=16, sync_interval=4, warmup_frac=0.25, seed=0)
    ref = _trainer_run(TrainConfig(**base))
    ref_d2 = _trainer_run(TrainConfig(**base, sync_delay=2))
    for reference, kw in ((ref, dict(comm_chunks=3)),
                          (ref, dict(hierarchical_reduce=True)),
                          (ref_d2, dict(comm_chunks=2, sync_delay=2))):
        got = _trainer_run(TrainConfig(**base, **kw))
        for a, b in zip(jax.tree.leaves(reference.state.params),
                        jax.tree.leaves(got.state.params)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=str(kw))


@pytest.mark.parametrize("hier", [False, True])
def test_distributed_int8_matches_simulator(hier):
    """The compressed distributed dispatch (residual wiring, quantize,
    reduce) tracks the simulator's compressed path step for step (G=1) —
    including hierarchical_reduce on a pod-less mesh, where both sides
    must quantize the *global* mean once."""
    tc = TrainConfig(optimizer="pier", total_steps=20, global_batch_size=4,
                     seq_len=16, sync_interval=4, warmup_frac=0.25, seed=0,
                     outer_compression="quantize", outer_comm_bits=8,
                     outer_comm_block=BLOCK, hierarchical_reduce=hier)
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    sim = SimulatedRun(MC, tc, num_groups=1, seed=0)
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh)
    for step in range(16):
        batch = sim._global_batch(step)
        dist_batch = jax.device_put(batch, tr.bundle.batch_sharding(batch))
        tr.train_step(dist_batch)
        sim.run(1)
    worst = 0.0
    sim_params = (sim.state.group_params if sim.state.group_params
                  is not None else sim.state.params)
    sim_leaves = jax.tree.leaves(jax.tree.map(
        lambda g: g[0] if g.ndim else g, sim_params))
    for a, b in zip(sim_leaves,
                    jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                                 tr.state.params))):
        worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                         - jnp.asarray(b, jnp.float32)
                                         ).max()))
    assert worst < 5e-4, worst
    # residuals agree too (both non-trivial after a sync)
    r_sim = jax.tree.leaves(sim.state.outer.residual)
    r_dist = jax.tree.leaves(tr.outer.residual)
    assert any(float(jnp.abs(r).max()) > 0 for r in r_sim)
    for a, b in zip(r_sim, r_dist):
        d = float(jnp.abs(a - b).max())
        assert d < 5e-4, d


# ---------------------------------------------------------------------------
# convergence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delay", [0, 2])
def test_int8_convergence_within_5pct_of_fp32(delay):
    """int8 + error feedback within 5% of the fp32 eager baseline — the
    paper-style acceptance for relaxing the payload precision."""
    tc = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5)
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    he = eager.run(60, eval_every=60)
    tq = _ctc(total_steps=60, warmup_frac=0.2, sync_interval=5,
              sync_delay=delay)
    quant = SimulatedRun(MC, tq, num_groups=2, seed=0)
    hq = quant.run(60, eval_every=60)
    ve, vq = he["val_loss"][-1], hq["val_loss"][-1]
    assert vq <= ve * 1.05, (ve, vq)


def test_hierarchical_sim_close_to_flat():
    """Two-stage reduce (2 pods x 2 groups) only reorders the fp32 mean;
    convergence must match the flat reduce."""
    tc = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5)
    flat = SimulatedRun(MC, tc, num_groups=4, seed=0)
    hf = flat.run(60, eval_every=60)
    hier = SimulatedRun(MC, tc.replace(hierarchical_reduce=True),
                        num_groups=4, seed=0, num_pods=2)
    hh = hier.run(60, eval_every=60)
    vf, vh = hf["val_loss"][-1], hh["val_loss"][-1]
    assert vh <= vf * 1.05, (vf, vh)


# ---------------------------------------------------------------------------
# sync_delay="auto"
# ---------------------------------------------------------------------------


def test_resolve_sync_delay_from_model():
    from benchmarks.overlap import resolve_sync_delay

    d32 = resolve_sync_delay(n_params=1.5e9, n_devices=256, group_size=4,
                             sync_interval=50, chip="a100-perlmutter")
    d8 = resolve_sync_delay(n_params=1.5e9, n_devices=256, group_size=4,
                            sync_interval=50, chip="a100-perlmutter",
                            bits=8, hierarchical=True, pods=4)
    assert d32 is not None and d32 > 0
    assert d8 is not None and 0 < d8 <= d32  # smaller payload, smaller d*
    assert resolve_sync_delay(n_params=1e9, n_devices=256, group_size=4,
                              sync_interval=50, chip="warp-drive") is None
    assert resolve_sync_delay(n_params=1e9, n_devices=256, group_size=4,
                              sync_interval=50, chip=None) is None


def test_auto_sync_delay_launcher_fallback():
    """The launcher resolves 'auto' (chip hint -> d*, no hint -> 0)."""
    from repro.launch.train import resolve_auto_sync_delay

    tc = _tc(sync_delay="auto")
    pc = ParallelConfig(data_axis_size=16, model_axis_size=16, data_outer=4)
    d = resolve_auto_sync_delay(tc, MC, pc, chip="")
    assert d == 0  # no chip hint -> no estimate -> eager fallback
    d2 = resolve_auto_sync_delay(tc, MC, pc, chip="a100-perlmutter")
    assert isinstance(d2, int) and 0 <= d2 < tc.sync_interval
    # already-resolved configs pass through untouched
    assert resolve_auto_sync_delay(_tc(sync_delay=3), MC, pc) == 3


def test_trainer_resolves_auto_sync_delay():
    tc = TrainConfig(optimizer="pier", total_steps=20, global_batch_size=4,
                     seq_len=16, sync_interval=4, warmup_frac=0.25,
                     sync_delay="auto")
    tr = _trainer_run(tc, steps=6)
    assert isinstance(tr.tc.sync_delay, int)


# ---------------------------------------------------------------------------
# bytes-on-wire acceptance
# ---------------------------------------------------------------------------


def test_modeled_bytes_drop_at_int8_hierarchical():
    """Acceptance: cross-pod bytes per sync drop >= 3.5x at int8 with the
    hierarchical reduce (and already >= 3.5x from quantization alone)."""
    from benchmarks.overlap import cross_domain_bytes, period_times
    from benchmarks.hardware import CHIPS

    n = 1.5e9
    flat32 = cross_domain_bytes(n, n_groups=16)
    flat8 = cross_domain_bytes(n, n_groups=16, bits=8)
    hier8 = cross_domain_bytes(n, n_groups=16, bits=8, pods=2,
                               hierarchical=True)
    assert flat32 / flat8 >= 3.5
    assert flat32 / hier8 >= 3.5
    assert hier8 < flat8  # hierarchy shrinks it further
    # and the smaller payload shrinks d* in the step-time model
    chip = CHIPS["a100-perlmutter"]
    kw = dict(sync_interval=50, sync_delay=0, group_size=4)
    d32 = period_times(n, 256, chip, **kw)["d_star"]
    d8 = period_times(n, 256, chip, bits=8, hierarchical=True, pods=4,
                      **kw)["d_star"]
    assert d8 < d32
