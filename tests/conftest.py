# NOTE: deliberately NO XLA_FLAGS / device-count forcing here — unit tests
# and smoke tests run on the single real CPU device. Multi-device semantics
# are tested via subprocesses in test_multidevice.py (which set
# XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax imports).

import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(SRC) not in [os.path.abspath(p) for p in sys.path]:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
