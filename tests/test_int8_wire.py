"""True int8 wire format: ring exchange of quantized Δθ (DESIGN.md §8).

The contract under test:

- The ring all-reduce of actual ``(q, scales)`` pairs matches the
  ``Quantized`` dequantized-payload reference **bit for bit** in
  interpret mode — on 2, 4 and odd ring sizes, for int8 and (nibble-
  packed) int4, on every endpoint, across all transports (ppermute ring,
  one-hot psum) and against the ``ring_allreduce_qs_ref`` oracle.
- ``Int8Wire.sim_reduce`` implements the same per-source-scale sum
  semantics bit for bit (flat and pod-grouped), and the distributed
  Trainer tracks the simulator step for step.
- Error feedback telescopes under per-source scales: the accumulated
  wire means plus the final mean residual reconstruct the accumulated
  true delta means exactly (up to fp32 addition noise).
- The measured bytes-on-wire (real buffers off the quantizer + packer)
  sit within 5% of the ``bits/8 + 4/block`` model.
- Regressions for the PR-4 satellite bugfixes: a measured ``t_comm`` of
  0.0 resolves d*=0 instead of deferring to the fallback; an indivisible
  ``num_pods`` raises a clear ``ValueError``; ``Chunked.plan`` clamps to
  the leaf count; ragged payloads raise ``ValueError`` (not a bare
  assert) in ``dequantize_blockwise``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OuterCommConfig, ParallelConfig, TrainConfig
from repro.core.simulate import SimulatedRun
from repro.kernels import ops as kops
from repro.kernels.ref import (dequantize_blockwise_ref, pack_wire,
                               quantize_blockwise_ref, ring_allreduce_qs_ref,
                               unpack_wire)
from repro.kernels.ring_allreduce import (measure_wire_bytes,
                                          measured_cross_domain_bytes,
                                          ring_allreduce_quantized)
from repro.sync import (Chunked, FlatFP32, Hierarchical, Int8Wire,
                        MeasuredDelayController, Quantized, resolve_strategy,
                        validate_pod_grouping)
from test_delayed_sync import MC

BLOCK = 64


def _tc(**kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25)
    comm = kw.pop("comm", None)
    if comm is not None:
        base["outer_comm"] = comm
    base.update(kw)
    return TrainConfig(**base)


def _quantize_stack(x, bits):
    """(E, n) fp32 -> stacked (q, scales) rows via the ref quantizer."""
    qs = [quantize_blockwise_ref(x[i], bits=bits, block=BLOCK)
          for i in range(x.shape[0])]
    return (jnp.stack([q for q, _ in qs]), jnp.stack([s for _, s in qs]))


# ---------------------------------------------------------------------------
# wire packing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 7, 128, 255])
def test_int4_pack_roundtrip_exact(n):
    q = jax.random.randint(jax.random.PRNGKey(n), (n,), -7, 8, jnp.int8)
    w = pack_wire(q, 4)
    assert w.dtype == jnp.uint8 and w.shape[0] == (n + 1) // 2
    np.testing.assert_array_equal(np.asarray(unpack_wire(w, 4, n)),
                                  np.asarray(q))


def test_int8_pack_is_identity():
    q = jnp.array([-127, 0, 5, 127], jnp.int8)
    assert pack_wire(q, 8) is q
    assert unpack_wire(q, 8, 4) is q


# ---------------------------------------------------------------------------
# the ring all-reduce vs the Quantized dequantized-payload reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("E", [2, 3, 4])  # even, odd, pow2 ring sizes
@pytest.mark.parametrize("transport", ["ring", "psum"])
def test_ring_matches_quantized_payload_reference_bitwise(bits, E, transport):
    """The acceptance bit: the wire ring == canonical-order mean of the
    dequantized payloads (what ``Quantized``'s fp32 exchange delivers),
    identical on every endpoint, in interpret mode."""
    x = jax.random.normal(jax.random.PRNGKey(E + bits), (E, 200),
                          jnp.float32)
    q, s = _quantize_stack(x, bits)

    # the Quantized dequantized-payload reference, canonical source order
    payloads = jnp.stack([dequantize_blockwise_ref(q[j], s[j], block=BLOCK)
                          for j in range(E)])
    oracle = np.asarray(jax.jit(
        lambda q, s: ring_allreduce_qs_ref(q, s, block=BLOCK, bits=bits)
    )(q, s))
    np.testing.assert_allclose(oracle, np.asarray(payloads).mean(0),
                               atol=1e-6)

    def ring(qi, si):
        return ring_allreduce_quantized(
            qi, si, axis_names=("x",), axis_sizes={"x": E}, bits=bits,
            block=BLOCK, transport=transport)

    got = jax.jit(jax.vmap(ring, axis_name="x"))(q, s)
    for e in range(E):  # identical bits on every endpoint
        np.testing.assert_array_equal(np.asarray(got[e]), oracle)


def test_ring_multi_axis_linearizes_row_major():
    """Two exchange axes compose as nested rings; the canonical source
    order is row-major over the axis names — the simulator's group
    linearization."""
    E1, E2 = 2, 3
    x = jax.random.normal(jax.random.PRNGKey(0), (E1 * E2, 128), jnp.float32)
    q, s = _quantize_stack(x, 8)
    oracle = np.asarray(jax.jit(
        lambda q, s: ring_allreduce_qs_ref(q, s, block=BLOCK))(q, s))

    def ring(qi, si):
        return ring_allreduce_quantized(
            qi, si, axis_names=("a", "b"),
            axis_sizes={"a": E1, "b": E2}, bits=8, block=BLOCK,
            transport="ring")

    f = jax.vmap(jax.vmap(ring, axis_name="b"), axis_name="a")
    got = jax.jit(f)(q.reshape(E1, E2, -1), s.reshape(E1, E2, -1))
    got = np.asarray(got).reshape(E1 * E2, -1)
    for e in range(E1 * E2):
        np.testing.assert_array_equal(got[e], oracle)


def test_ring_transports_agree_bitwise():
    """ppermute ring and one-hot psum produce identical stacks, so the
    shared reduction must give identical bits (per-transport paths are
    interchangeable on any backend)."""
    E = 3
    x = jax.random.normal(jax.random.PRNGKey(7), (E, 300), jnp.float32)
    q, s = _quantize_stack(x, 4)
    outs = {}
    for transport in ("ring", "psum"):
        def ring(qi, si, t=transport):
            return ring_allreduce_quantized(
                qi, si, axis_names=("x",), axis_sizes={"x": E}, bits=4,
                block=BLOCK, transport=t)
        outs[transport] = np.asarray(
            jax.jit(jax.vmap(ring, axis_name="x"))(q, s))
    np.testing.assert_array_equal(outs["ring"], outs["psum"])


def test_ring_unknown_transport_and_missing_axis_size():
    q, s = _quantize_stack(jnp.ones((1, 128), jnp.float32), 8)
    with pytest.raises(ValueError, match="transport"):
        jax.vmap(lambda qi, si: ring_allreduce_quantized(
            qi, si, axis_names=("x",), axis_sizes={"x": 1}, bits=8,
            block=BLOCK, transport="carrier-pigeon"),
            axis_name="x")(q, s)
    with pytest.raises(ValueError, match="axis_sizes"):
        jax.vmap(lambda qi, si: ring_allreduce_quantized(
            qi, si, axis_names=("x",), axis_sizes={}, bits=8,
            block=BLOCK, transport="ring"), axis_name="x")(q, s)


# ---------------------------------------------------------------------------
# strategy resolution + sim semantics
# ---------------------------------------------------------------------------


def test_int8_wire_resolution_and_names():
    tc = _tc(comm=OuterCommConfig(compression="int8-wire", bits=8,
                                  block=BLOCK))
    st = resolve_strategy(tc)
    assert isinstance(st, Int8Wire)
    assert st.name == f"int8-wire(block={BLOCK})"
    assert st.wire_format == "int8+scales"
    assert st.needs_residual
    plan = st.plan({"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}, tc)
    assert plan.wire_format == "int8+scales"

    tc2 = _tc(comm=OuterCommConfig(compression="int8-wire", bits=4,
                                   block=BLOCK, hierarchical=True, chunks=2))
    st2 = resolve_strategy(tc2)
    assert isinstance(st2, Chunked) and isinstance(st2.inner, Hierarchical)
    assert isinstance(st2.inner.inner, Int8Wire)
    assert st2.wire_format == "int4+scales"
    assert st2.needs_residual
    # fp32 strategies keep the fp32 wire format label
    assert FlatFP32().wire_format == "fp32"
    assert Quantized().wire_format == "fp32"

    with pytest.raises(ValueError):
        OuterCommConfig(compression="int8-wire", bits=3)
    with pytest.raises(ValueError):
        OuterCommConfig(compression="zip")


@pytest.mark.parametrize("bits", [8, 4])
def test_sim_reduce_matches_oracle_bitwise(bits):
    tc = _tc(comm=OuterCommConfig(compression="int8-wire", bits=bits,
                                  block=BLOCK))
    st = resolve_strategy(tc)
    G = 4
    delta = {"w": jax.random.normal(jax.random.PRNGKey(1), (G, 10, 13))}
    res = {"w": 0.01 * jax.random.normal(jax.random.PRNGKey(2), (G, 10, 13))}
    avg, new_r = jax.jit(
        lambda d, r: st.sim_reduce(d, r, tc, num_pods=1))(delta, res)
    c = (delta["w"] + res["w"]).reshape(G, -1)
    q, s = _quantize_stack(c, bits)
    oracle = np.asarray(jax.jit(
        lambda q, s: ring_allreduce_qs_ref(q, s, block=BLOCK, bits=bits)
    )(q, s))[:130].reshape(10, 13)
    np.testing.assert_array_equal(np.asarray(avg["w"]), oracle)
    # residual telescopes against the locally dequantized payload
    payload = jnp.stack([
        dequantize_blockwise_ref(q[g], s[g], block=BLOCK)[:130]
        for g in range(G)]).reshape(G, 10, 13)
    np.testing.assert_allclose(np.asarray(new_r["w"]),
                               np.asarray(c.reshape(G, 10, 13) - payload),
                               atol=1e-6)


def test_sim_reduce_pod_grouped_uses_pod_representatives():
    """Under Hierarchical the stacked entries are pod-duplicated; the ring
    endpoints are the pods — one representative each, in pod order."""
    tc = _tc(comm=OuterCommConfig(compression="int8-wire", bits=8,
                                  block=BLOCK, hierarchical=True))
    st = Int8Wire(bits=8, block=BLOCK)
    P, per = 2, 2
    G = P * per
    pod_vals = jax.random.normal(jax.random.PRNGKey(3), (P, 128))
    delta = {"w": jnp.repeat(pod_vals, per, axis=0)}  # pod-duplicated
    res = {"w": jnp.zeros((G, 128))}
    avg, _ = jax.jit(lambda d, r: st.sim_reduce(
        d, r, tc, num_pods=P, pod_grouped=True))(delta, res)
    q, s = _quantize_stack(pod_vals, 8)
    oracle = np.asarray(jax.jit(
        lambda q, s: ring_allreduce_qs_ref(q, s, block=BLOCK))(q, s))
    np.testing.assert_array_equal(np.asarray(avg["w"]), oracle)


def test_error_feedback_telescopes_under_per_source_scales():
    """Σ_rounds wire-mean + mean(residual_final) == Σ_rounds mean(Δθ):
    per group, payload + residual' == Δθ + residual exactly per round, so
    the mean error telescopes instead of accumulating."""
    tc = _tc(comm=OuterCommConfig(compression="int8-wire", bits=8,
                                  block=BLOCK))
    st = resolve_strategy(tc)
    G, n = 3, 256
    key = jax.random.PRNGKey(5)
    res = {"w": jnp.zeros((G, n))}
    total_wire = jnp.zeros((n,))
    total_true = jnp.zeros((n,))
    for r in range(6):
        key, k = jax.random.split(key)
        delta = {"w": jax.random.normal(k, (G, n))}
        avg, res = st.sim_reduce(delta, res, tc, num_pods=1)
        total_wire = total_wire + avg["w"]
        total_true = total_true + jnp.mean(delta["w"], axis=0)
    recon = total_wire + jnp.mean(res["w"], axis=0)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(total_true),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# distributed Trainer vs simulator (single-device mesh; the multi-group
# shard_map rings run in tests/multidevice/md_equivalence.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hier", [False, True])
def test_trainer_int8_wire_matches_simulator(hier):
    tc = TrainConfig(optimizer="pier", total_steps=20, global_batch_size=4,
                     seq_len=16, sync_interval=4, warmup_frac=0.25, seed=0,
                     outer_comm=OuterCommConfig(
                         compression="int8-wire", bits=8, block=BLOCK,
                         hierarchical=hier))
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    sim = SimulatedRun(MC, tc, num_groups=1, seed=0)
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh)
    for step in range(16):
        batch = sim._global_batch(step)
        tr.train_step(jax.device_put(batch, tr.bundle.batch_sharding(batch)))
        sim.run(1)
    worst = 0.0
    simp = (sim.state.group_params if sim.state.group_params is not None
            else sim.state.params)
    for a, b in zip(
            jax.tree.leaves(jax.tree.map(lambda g: g[0], simp)),
            jax.tree.leaves(jax.tree.map(lambda x: x[0], tr.state.params))):
        worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                         - jnp.asarray(b, jnp.float32)
                                         ).max()))
    assert worst < 5e-4, worst
    # the error-feedback residual survived the wire round trip
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree.leaves(tr.outer.residual))


def test_int8_wire_convergence_within_5pct_of_fp32():
    tc = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5)
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    he = eager.run(60, eval_every=60)
    tw = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5,
             comm=OuterCommConfig(compression="int8-wire", bits=8,
                                  block=BLOCK))
    wire = SimulatedRun(MC, tw, num_groups=2, seed=0)
    hw = wire.run(60, eval_every=60)
    ve, vw = he["val_loss"][-1], hw["val_loss"][-1]
    assert vw <= ve * 1.05, (ve, vw)


# ---------------------------------------------------------------------------
# measured bytes-on-wire
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_measured_wire_bytes_within_5pct_of_model(bits):
    n = 1_000_000
    m = measure_wire_bytes(n, bits=bits, block=256)
    model = bits / 8.0 + 4.0 / 256
    assert abs(m["measured_payload_bytes_per_param"] / model - 1) < 0.05, m
    # cross-domain totals follow the same ring convention as the model
    got = measured_cross_domain_bytes(n, endpoints=4, bits=bits, block=256)
    assert abs(got / (2 * model * n * 3) - 1) < 0.05


def test_measured_wire_bytes_fp32_is_exact():
    m = measure_wire_bytes(1000, bits=32)
    assert m["measured_payload_bytes_per_param"] == 4.0
    assert m["measured_scale_bytes"] == 0


# ---------------------------------------------------------------------------
# satellite bugfix regressions
# ---------------------------------------------------------------------------


def test_measured_delay_accepts_zero_t_comm():
    """A legitimately measured 0.0 t_comm (coarse timer, sub-ms
    collective) must resolve d*=0, not defer to the fallback forever."""
    from repro.sync import FixedDelayController

    tc = _tc(sync_delay=0)
    c = MeasuredDelayController(tc, fallback=FixedDelayController(3),
                                min_windows=2, skip_windows=1)
    c.observe_step(0.1)
    for _ in range(3):  # 1 skip + 2 measured windows, all t_comm == 0.0
        c.observe_window(t_comm=0.0)
    assert c.current_delay() == 0  # not the fallback's 3


def test_measured_delay_zero_t_inner_still_falls_back():
    from repro.sync import FixedDelayController

    tc = _tc(sync_delay=0)
    c = MeasuredDelayController(tc, fallback=FixedDelayController(2),
                                min_windows=2, skip_windows=0)
    for _ in range(2):
        c.observe_window(t_comm=0.5, t_inner=0.0)
    assert c.current_delay() == 2  # t_inner == 0: division guarded


def test_hierarchical_indivisible_pods_raise_clear_error():
    with pytest.raises(ValueError, match="num_pods"):
        validate_pod_grouping(3, 2)
    validate_pod_grouping(4, 2)  # divisible: fine
    validate_pod_grouping(3, 0)  # pod-less: clamps to 1
    h = Hierarchical()
    delta = jnp.zeros((3, 8))
    with pytest.raises(ValueError, match="num_pods"):
        h.sim_reduce({"w": delta}, None, _tc(), num_pods=2)
    with pytest.raises(ValueError, match="num_pods"):
        SimulatedRun(MC, _tc(), num_groups=3, num_pods=2)


def test_chunked_plan_clamps_to_leaf_count():
    tc = _tc()
    shapes = {f"l{i}": jax.ShapeDtypeStruct((4,), jnp.float32)
              for i in range(5)}
    plan = Chunked(num_chunks=99).plan(shapes, tc)
    assert plan.num_chunks <= 5
    assert plan.spans[0][0] == 0 and plan.spans[-1][1] == 5
    covered = []
    for lo, hi in plan.spans:
        assert hi > lo  # every span non-empty
        covered.extend(range(lo, hi))
    assert covered == list(range(5))
    # empty tree: a single empty span, still a valid plan
    empty = Chunked(num_chunks=4).plan({}, tc)
    assert empty.spans == ((0, 0),) and empty.num_leaves == 0


def test_dequantize_ragged_payload_raises_value_error():
    q = jnp.zeros((100,), jnp.int8)  # 100 % 64 != 0
    s = jnp.zeros((2,), jnp.float32)
    with pytest.raises(ValueError, match="ragged"):
        dequantize_blockwise_ref(q, s, block=BLOCK)
    with pytest.raises(ValueError, match="ragged"):
        kops.dequantize_blockwise(q, s, block=BLOCK)
