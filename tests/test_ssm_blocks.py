"""xLSTM / RG-LRU block math: parallel == chunkwise == recurrent."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import rglru as RG
from repro.models import ssm as SSM


def _qkv(key, B=2, S=32, H=2, dh=16):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H)) - 2.0
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    return q, k, v, ig, fg


def test_mlstm_chunkwise_equals_parallel(rng):
    q, k, v, ig, fg = _qkv(rng)
    h_par = SSM.mlstm_parallel(q, k, v, ig, fg)
    for chunk in (4, 8, 16):
        h_chk = SSM.mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_par),
                                   rtol=2e-4, atol=2e-5)


def test_mlstm_recurrent_equals_parallel(rng):
    q, k, v, ig, fg = _qkv(rng, B=1, S=16)
    h_par = SSM.mlstm_parallel(q, k, v, ig, fg)
    B, S, H, dh = q.shape
    state = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
             jnp.full((B, H), -jnp.inf))
    outs = []
    for t in range(S):
        state, h = SSM.mlstm_recurrent_step(
            state, q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t])
        outs.append(h)
    h_rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_rec), np.asarray(h_par),
                               rtol=2e-4, atol=2e-5)


def test_mlstm_final_state_equals_recurrent(rng):
    q, k, v, ig, fg = _qkv(rng, B=1, S=12)
    C, n, m = SSM.mlstm_final_state(q, k, v, ig, fg)
    B, S, H, dh = q.shape
    state = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
             jnp.full((B, H), -jnp.inf))
    for t in range(S):
        state, _ = SSM.mlstm_recurrent_step(
            state, q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t])
    np.testing.assert_allclose(np.asarray(C), np.asarray(state[0]),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(n), np.asarray(state[1]),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(state[2]),
                               rtol=2e-4, atol=1e-5)


def test_rglru_scan_equals_sequential(rng):
    """associative_scan recurrence == step-by-step loop."""
    cfg = ModelConfig(d_model=32, lru_width=32, num_heads=2, dtype="float32")
    p = RG.init_rglru(rng, cfg)
    B, S = 2, 10
    x = jax.random.normal(rng, (B, S, 32))
    out_par, _ = RG.apply_rglru(p, x, cfg)
    state = RG.init_rglru_state(cfg, B)
    outs = []
    for t in range(S):
        o, state = RG.apply_rglru(p, x[:, t:t + 1], cfg, state=state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(out_par),
                               rtol=2e-4, atol=2e-5)


def test_rglru_state_bounded(rng):
    """|a_t| < 1 keeps the hidden state bounded over long rollouts."""
    cfg = ModelConfig(d_model=16, lru_width=16, num_heads=2, dtype="float32")
    p = RG.init_rglru(rng, cfg)
    state = RG.init_rglru_state(cfg, 1)
    x = jax.random.normal(rng, (1, 1, 16))
    for _ in range(200):
        _, state = RG.apply_rglru(p, x, cfg, state=state)
    assert bool(jnp.isfinite(state["hidden"]).all())
    assert float(jnp.abs(state["hidden"]).max()) < 100.0


def test_slstm_decode_continues_scan(rng):
    cfg = ModelConfig(d_model=32, num_heads=2, dtype="float32")
    p = SSM.init_slstm(rng, cfg)
    x = jax.random.normal(rng, (1, 8, 32))
    full, _ = SSM.apply_slstm(p, x, cfg)
    half, st = SSM.apply_slstm(p, x[:, :4], cfg, return_state=True)
    outs = [half]
    for t in range(4, 8):
        o, st = SSM.apply_slstm(p, x[:, t:t + 1], cfg, state=st)
        outs.append(o)
    stitched = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stitched), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_causal_conv1d_streaming(rng):
    kernel = jax.random.normal(rng, (4, 8))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 8))
    full, _ = SSM._causal_conv1d(x, kernel)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(12):
        y, state = SSM._causal_conv1d(x[:, t:t + 1], kernel, state)
        outs.append(y)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-5, atol=1e-6)
