"""Quantized reduce-scatter + all-gather wire path (DESIGN.md §14).

The contract under test:

- ``reduce_scatter_qs`` delivers endpoint e the canonical-order mean of
  slot e of every source's quantized payload, bit-identical to
  ``reduce_scatter_qs_ref`` rows, across the ppermute-ring and one-hot
  psum transports, for int8 and nibble-packed int4, even/odd/pow2 E.
- The full rs → requantize(+residual2) → ag round trip reconstructs the
  identical payload on every endpoint, bit-for-bit against
  ``rs_ag_qs_ref``, and the second error-feedback residual telescopes
  exactly per slot: reduced + r2_in == dequant(q2, s2) + r2_out.
- Wire-shard edge cases (the satellite property tests): E not dividing
  the quant-block count (ragged last shard, zero-padded tail blocks are
  bit-transparent), int4 nibble packing at odd per-slot lengths, E=1.
- Measured per-device rs/ag bytes (real slot buffers) sit within 5% of
  the 2·(E−1)/E·payload model and ≤ 0.6× the all-reduce wire path's
  per-device sent bytes at E=4.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import (dequant_concat_sources,
                               dequantize_blockwise_ref, pack_wire,
                               quantize_blockwise_ref, reduce_scatter_qs_ref,
                               rs_ag_qs_ref, shard_slot_wire,
                               wire_shard_blocks)
from repro.kernels.ring_allreduce import (allgather_qs, measure_wire_bytes,
                                          measured_rs_ag_bytes,
                                          reduce_scatter_qs)

BLOCK = 64


def _quantize_stack(x, bits, block=BLOCK):
    qs = [quantize_blockwise_ref(x[i], bits=bits, block=block)
          for i in range(x.shape[0])]
    return (jnp.stack([q for q, _ in qs]), jnp.stack([s for _, s in qs]))


# ---------------------------------------------------------------------------
# slot layout (shard_slot_wire)
# ---------------------------------------------------------------------------


def test_wire_shard_blocks_ceil_division():
    assert wire_shard_blocks(8, 4) == 2
    assert wire_shard_blocks(7, 3) == 3  # E does not divide nb
    assert wire_shard_blocks(1, 4) == 1
    assert wire_shard_blocks(5, 1) == 5
    with pytest.raises(ValueError):
        wire_shard_blocks(4, 0)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("nb,E", [(8, 4), (7, 3), (5, 4), (1, 2)])
def test_slot_padding_is_bit_transparent(bits, nb, E):
    """Zero-padded tail blocks carry zero scales and dequantize to exact
    zeros: concatenating all per-slot dequants reproduces the original
    dequantized payload followed by exact zeros."""
    n = nb * BLOCK - 3  # ragged tail inside the last real block too
    x = jax.random.normal(jax.random.PRNGKey(nb * E + bits), (n,),
                          jnp.float32)
    q, s = quantize_blockwise_ref(x, bits=bits, block=BLOCK)
    assert s.shape[0] == nb
    w_slots, s_slots = shard_slot_wire(q, s, bits=bits, block=BLOCK,
                                       endpoints=E)
    sb = wire_shard_blocks(nb, E)
    assert w_slots.shape[0] == E and s_slots.shape == (E, sb)
    full = np.asarray(dequant_concat_sources(w_slots, s_slots, bits=bits,
                                             block=BLOCK))
    ref = np.asarray(dequantize_blockwise_ref(q, s, block=BLOCK))
    np.testing.assert_array_equal(full[:ref.shape[0]], ref)
    np.testing.assert_array_equal(full[ref.shape[0]:],
                                  np.zeros(E * sb * BLOCK - ref.shape[0]))


def test_int4_nibbles_never_straddle_slots():
    """Per-slot packing at odd per-slot element counts: each slot packs
    independently (odd tail padded inside its own slot), so slot e of the
    wire buffer decodes without knowing its neighbors."""
    block, nb, E = 5, 7, 3  # sb=3 -> 15 elems/slot: odd, exercises the tail
    x = jax.random.normal(jax.random.PRNGKey(0), (nb * block,), jnp.float32)
    q, s = quantize_blockwise_ref(x, bits=4, block=block)
    w_slots, s_slots = shard_slot_wire(q, s, bits=4, block=block,
                                       endpoints=E)
    sb = wire_shard_blocks(nb, E)
    assert w_slots.shape == (E, (sb * block + 1) // 2)
    # independent decode of each slot == the padded payload's slots
    qp = jnp.pad(q, (0, (E * sb - nb) * block)).reshape(E, sb * block)
    for e in range(E):
        np.testing.assert_array_equal(
            np.asarray(w_slots[e]), np.asarray(pack_wire(qp[e], 4)))


# ---------------------------------------------------------------------------
# reduce_scatter_qs vs the reference oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("E", [2, 3, 4])  # even, odd, pow2
@pytest.mark.parametrize("transport", ["ring", "psum"])
def test_reduce_scatter_matches_ref_bitwise(bits, E, transport):
    n = 300  # 5 blocks of 64: E=3,4 do not divide nb — ragged last shard
    x = jax.random.normal(jax.random.PRNGKey(E + bits), (E, n), jnp.float32)
    q, s = _quantize_stack(x, bits)
    oracle = np.asarray(jax.jit(
        lambda q, s: reduce_scatter_qs_ref(q, s, block=BLOCK, bits=bits)
    )(q, s))

    def rs(qi, si):
        return reduce_scatter_qs(
            qi, si, axis_names=("x",), axis_sizes={"x": E}, bits=bits,
            block=BLOCK, transport=transport)

    got = jax.jit(jax.vmap(rs, axis_name="x"))(q, s)
    for e in range(E):  # endpoint e holds exactly oracle row e
        np.testing.assert_array_equal(np.asarray(got[e]), oracle[e])


def test_reduce_scatter_weighted_membership():
    """Elastic weights flow through the same dequant_sum_sources path."""
    E = 3
    x = jax.random.normal(jax.random.PRNGKey(9), (E, 256), jnp.float32)
    q, s = _quantize_stack(x, 8)
    w = jnp.array([1.0, 0.0, 1.0], jnp.float32)
    oracle = np.asarray(jax.jit(lambda q, s: reduce_scatter_qs_ref(
        q, s, block=BLOCK, bits=8, weights=w))(q, s))

    def rs(qi, si):
        return reduce_scatter_qs(
            qi, si, axis_names=("x",), axis_sizes={"x": E}, bits=8,
            block=BLOCK, transport="ring", weights=w)

    got = np.asarray(jax.jit(jax.vmap(rs, axis_name="x"))(q, s))
    for e in range(E):
        np.testing.assert_array_equal(got[e], oracle[e])


def test_reduce_scatter_multi_axis_linearizes_row_major():
    E1, E2 = 2, 3
    x = jax.random.normal(jax.random.PRNGKey(4), (E1 * E2, 256), jnp.float32)
    q, s = _quantize_stack(x, 8)
    oracle = np.asarray(jax.jit(
        lambda q, s: reduce_scatter_qs_ref(q, s, block=BLOCK))(q, s))

    for transport in ("ring", "psum"):
        def rs(qi, si, t=transport):
            return reduce_scatter_qs(
                qi, si, axis_names=("a", "b"),
                axis_sizes={"a": E1, "b": E2}, bits=8, block=BLOCK,
                transport=t)

        f = jax.vmap(jax.vmap(rs, axis_name="b"), axis_name="a")
        got = np.asarray(jax.jit(f)(q.reshape(E1, E2, -1),
                                    s.reshape(E1, E2, -1)))
        got = got.reshape(E1 * E2, -1)
        for e in range(E1 * E2):
            np.testing.assert_array_equal(got[e], oracle[e])


def test_rs_transports_agree_bitwise():
    E = 4
    x = jax.random.normal(jax.random.PRNGKey(2), (E, 320), jnp.float32)
    q, s = _quantize_stack(x, 4)
    outs = {}
    for transport in ("ring", "psum"):
        def rs(qi, si, t=transport):
            return reduce_scatter_qs(
                qi, si, axis_names=("x",), axis_sizes={"x": E}, bits=4,
                block=BLOCK, transport=t)
        outs[transport] = np.asarray(
            jax.jit(jax.vmap(rs, axis_name="x"))(q, s))
    np.testing.assert_array_equal(outs["ring"], outs["psum"])


# ---------------------------------------------------------------------------
# the full rs -> requantize -> ag round trip vs rs_ag_qs_ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("E", [2, 3, 4])
@pytest.mark.parametrize("transport", ["ring", "psum"])
def test_rs_ag_roundtrip_matches_ref_bitwise(bits, E, transport):
    nb = 5  # E=3,4 don't divide it; E=2 does with a ragged split at 3/2
    n = nb * BLOCK
    x = jax.random.normal(jax.random.PRNGKey(E * 7 + bits), (E, n),
                          jnp.float32)
    q, s = _quantize_stack(x, bits)
    sb = wire_shard_blocks(nb, E)
    r2 = 0.01 * jax.random.normal(jax.random.PRNGKey(1), (E, sb * BLOCK))
    payload_ref, r2_ref = jax.jit(lambda q, s, r: rs_ag_qs_ref(
        q, s, block=BLOCK, bits=bits, residual2=r))(q, s, r2)

    def rs_ag(qi, si, r2i):
        shard = reduce_scatter_qs(
            qi, si, axis_names=("x",), axis_sizes={"x": E}, bits=bits,
            block=BLOCK, transport=transport)
        c2 = shard + r2i
        q2, s2 = quantize_blockwise_ref(c2, bits=bits, block=BLOCK)
        new_r2 = c2 - dequantize_blockwise_ref(q2, s2, block=BLOCK)
        payload = allgather_qs(
            q2, s2, axis_names=("x",), axis_sizes={"x": E}, bits=bits,
            block=BLOCK, transport=transport)
        return payload[:n], new_r2

    payload, new_r2 = jax.jit(jax.vmap(rs_ag, axis_name="x"))(q, s, r2)
    for e in range(E):  # identical payload bits on every endpoint
        np.testing.assert_array_equal(np.asarray(payload[e]),
                                      np.asarray(payload_ref))
        np.testing.assert_array_equal(np.asarray(new_r2[e]),
                                      np.asarray(r2_ref[e]))


def test_residual2_telescopes_exactly_per_slot():
    """reduced + r2_in == dequant(q2, s2) + r2_out, exactly: the gather
    leg's quantization error is carried, not lost."""
    E, nb = 3, 4
    n = nb * BLOCK
    x = jax.random.normal(jax.random.PRNGKey(11), (E, n))
    q, s = _quantize_stack(x, 8)
    sb = wire_shard_blocks(nb, E)
    # r2 zero in the slot-padding region (positions ≥ n): padded blocks
    # reduce to exact zeros, so a zero residual there stays zero — the
    # invariant the strategy's padded full-size residual2 buffer relies on.
    r2 = 0.05 * jax.random.normal(jax.random.PRNGKey(12), (E * sb * BLOCK,))
    r2 = r2.at[n:].set(0.0).reshape(E, sb * BLOCK)
    reduced = reduce_scatter_qs_ref(q, s, block=BLOCK, bits=8)
    payload, new_r2 = rs_ag_qs_ref(q, s, block=BLOCK, bits=8, residual2=r2)
    delivered = jnp.pad(payload, (0, E * sb * BLOCK - n)).reshape(
        E, sb * BLOCK)  # slot e as every endpoint sees it (pad dequants to 0)
    lhs = np.asarray(reduced + r2)
    rhs = np.asarray(delivered + new_r2)
    np.testing.assert_array_equal(lhs, rhs)


def test_rs_ag_single_endpoint_is_local_dequant():
    """E=1: the exchange degenerates to dequantize(quantize(shard))."""
    n = 2 * BLOCK
    x = jax.random.normal(jax.random.PRNGKey(3), (1, n))
    q, s = _quantize_stack(x, 8)
    payload, r2 = rs_ag_qs_ref(q, s, block=BLOCK, bits=8)
    # one slot == whole payload; the second quantization of an
    # already-on-grid payload is exact, so r2 stays zero
    local = dequantize_blockwise_ref(q[0], s[0], block=BLOCK)
    np.testing.assert_allclose(np.asarray(payload), np.asarray(local),
                               atol=1e-6)

    def rs(qi, si):
        return reduce_scatter_qs(qi, si, axis_names=("x",),
                                 axis_sizes={"x": 1}, bits=8, block=BLOCK,
                                 transport="ring")

    got = np.asarray(jax.jit(jax.vmap(rs, axis_name="x"))(q, s))
    np.testing.assert_array_equal(got[0], np.asarray(
        reduce_scatter_qs_ref(q, s, block=BLOCK, bits=8)[0]))


# ---------------------------------------------------------------------------
# measured bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_measured_rs_ag_bytes_within_5pct_of_model(bits):
    n, E = 1_000_000, 4
    m = measured_rs_ag_bytes(n, endpoints=E, bits=bits, block=256)
    per_elem = bits / 8.0 + 4.0 / 256
    model_per_device = 2.0 * (E - 1) / E * n * per_elem
    assert abs(m["measured_rs_ag_bytes_per_device"] / model_per_device
               - 1) < 0.05, m
    assert m["measured_rs_bytes_per_device"] == m["measured_ag_bytes_per_device"]
    assert m["measured_rs_ag_bytes_total"] == pytest.approx(
        E * m["measured_rs_ag_bytes_per_device"])


def test_rs_ag_beats_allreduce_wire_path_at_e4():
    """The acceptance bit: per-device sent bytes ≤ 0.6× the gather-based
    all-reduce wire path at E=4 (the true ratio is 2/E = 0.5)."""
    n, E = 1_000_000, 4
    rs_ag = measured_rs_ag_bytes(n, endpoints=E, bits=8, block=256)
    allreduce_sent = (E - 1) * measure_wire_bytes(
        n, bits=8, block=256)["measured_payload_bytes"]
    ratio = rs_ag["measured_rs_ag_bytes_per_device"] / allreduce_sent
    assert ratio <= 0.6, ratio
    assert ratio == pytest.approx(2.0 / E, rel=0.05)


# ---------------------------------------------------------------------------
# strategy resolution + composition rules
# ---------------------------------------------------------------------------

from repro.config import OuterCommConfig, ParallelConfig, TrainConfig  # noqa: E402
from repro.core.simulate import SimulatedRun  # noqa: E402
from repro.sync import (Chunked, FlatFP32, Hierarchical, Int8Wire,  # noqa: E402
                        MeasuredDelayController, Quantized, Sharded,
                        default_ladder, resolve_strategy)
from test_delayed_sync import MC  # noqa: E402


def _tc(**kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4,
                warmup_frac=0.25)
    comm = kw.pop("comm", None)
    base.update(kw)
    tc = TrainConfig(**base)
    return tc.replace(outer_comm=comm) if comm is not None else tc


def test_rs_ag_resolution_and_names():
    tc = _tc(comm=OuterCommConfig(compression="rs-ag", bits=8, block=BLOCK))
    st = resolve_strategy(tc)
    assert isinstance(st, Int8Wire) and st.reduce_scatter
    assert st.name == f"rs-ag(int8,block={BLOCK})"
    assert st.wire_format == "int8+scales/rs-ag"
    assert st.needs_residual and st.needs_residual2
    plan = st.plan({"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}, tc)
    assert plan.needs_residual2
    # the plain gather wire path keeps its names (and no second residual)
    plain = Int8Wire(bits=8, block=BLOCK)
    assert plain.name == f"int8-wire(block={BLOCK})"
    assert not plain.needs_residual2
    assert not plain.plan(
        {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}, tc).needs_residual2
    # sharded + rs-ag resolves to Sharded(Int8Wire(reduce_scatter=True))
    sh = resolve_strategy(
        OuterCommConfig(compression="rs-ag", bits=4, block=BLOCK,
                        sharded=True))
    assert isinstance(sh, Sharded) and sh.inner.reduce_scatter
    assert sh.needs_residual2 and sh.wire_format == "int4+scales/rs-ag"


def test_rs_ag_combinator_exclusions():
    rs = Int8Wire(bits=8, block=BLOCK, reduce_scatter=True)
    with pytest.raises(ValueError, match="[Hh]ierarchical"):
        Hierarchical(inner=rs)
    with pytest.raises(ValueError, match="[Cc]hunked"):
        Chunked(inner=rs, num_chunks=2)
    with pytest.raises(ValueError, match="hierarchical"):
        OuterCommConfig(compression="rs-ag", hierarchical=True)
    with pytest.raises(ValueError, match="chunks"):
        OuterCommConfig(compression="rs-ag", chunks=2)
    # the plain wire path still composes with both combinators
    Hierarchical(inner=Int8Wire(bits=8, block=BLOCK))
    Chunked(inner=Int8Wire(bits=8, block=BLOCK), num_chunks=2)


def test_core_ladder_preserves_reduce_scatter():
    rs = Int8Wire(bits=8, block=BLOCK, reduce_scatter=True)
    ladder = default_ladder(rs)
    assert ladder[0] is rs
    assert ladder[1].bits == 4 and ladder[1].reduce_scatter
    assert ladder[1].block == BLOCK


# ---------------------------------------------------------------------------
# sim_reduce vs the shared reference oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_rs_ag_sim_reduce_matches_ref_bitwise(bits):
    tc = _tc(comm=OuterCommConfig(compression="rs-ag", bits=bits,
                                  block=BLOCK))
    st = resolve_strategy(tc)
    G, shape = 3, (10, 13)
    n = 130
    delta = {"w": jax.random.normal(jax.random.PRNGKey(1), (G, *shape))}
    r1 = {"w": 0.01 * jax.random.normal(jax.random.PRNGKey(2), (G, *shape))}
    r2 = {"w": jnp.zeros((G, *shape))}
    avg, (new_r1, new_r2) = jax.jit(
        lambda d, r: st.sim_reduce(d, r, tc, num_pods=1))(delta, (r1, r2))
    c = (delta["w"] + r1["w"]).reshape(G, -1)
    q, s = _quantize_stack(c, bits)
    sb = wire_shard_blocks(int(s.shape[1]), G)
    slot = sb * BLOCK
    payload, new_r2_shards = jax.jit(
        lambda q, s: rs_ag_qs_ref(q, s, block=BLOCK, bits=bits,
                                  residual2=jnp.zeros((G, slot))))(q, s)
    np.testing.assert_array_equal(
        np.asarray(avg["w"]), np.asarray(payload[:n].reshape(shape)))
    # first residual telescopes against the locally dequantized payload.
    # The wire payload above is bitwise; the residual subtraction c - q*s
    # may fuse differently under jit (FMA), so allow ~1 ulp here.
    local = jnp.stack([
        dequantize_blockwise_ref(q[g], s[g], block=BLOCK)[:n]
        for g in range(G)])
    np.testing.assert_allclose(
        np.asarray(new_r1["w"]), np.asarray((c - local).reshape(G, *shape)),
        atol=1e-6, rtol=0)
    # second residual: each group's row holds exactly its own slot
    got_r2 = np.asarray(new_r2["w"]).reshape(G, -1)
    for g in range(G):
        want = np.zeros(n, np.float32)
        lo, hi = g * slot, min((g + 1) * slot, n)
        want[lo:hi] = np.asarray(new_r2_shards)[g][:hi - lo]
        np.testing.assert_allclose(got_r2[g], want, atol=1e-6, rtol=0)


def test_rs_ag_sim_two_residuals_telescope_across_rounds():
    """Σ_rounds payload + mean(r1_T) + Σ_g r2_T[g] recovers Σ mean(Δθ):
    both error-feedback stages telescope instead of accumulating."""
    tc = _tc(comm=OuterCommConfig(compression="rs-ag", bits=8,
                                  block=BLOCK))
    st = resolve_strategy(tc)
    G, n = 3, 256
    key = jax.random.PRNGKey(5)
    res = ({"w": jnp.zeros((G, n))}, {"w": jnp.zeros((G, n))})
    total_wire = jnp.zeros((n,))
    total_true = jnp.zeros((n,))
    for _ in range(6):
        key, k = jax.random.split(key)
        delta = {"w": jax.random.normal(k, (G, n))}
        avg, res = st.sim_reduce(delta, res, tc, num_pods=1)
        total_wire = total_wire + avg["w"]
        total_true = total_true + jnp.mean(delta["w"], axis=0)
    r1, r2 = res
    recon = (total_wire + jnp.mean(r1["w"], axis=0)
             + jnp.sum(r2["w"], axis=0))
    np.testing.assert_allclose(np.asarray(recon), np.asarray(total_true),
                               atol=1e-5)


def test_rs_ag_sim_rejects_pod_grouping():
    st = Int8Wire(bits=8, block=BLOCK, reduce_scatter=True)
    with pytest.raises(ValueError, match="hierarchical"):
        st.sim_reduce({"w": jnp.zeros((4, 128))},
                      ({"w": jnp.zeros((4, 128))},
                       {"w": jnp.zeros((4, 128))}),
                      _tc(), num_pods=2, pod_grouped=True)


# ---------------------------------------------------------------------------
# Trainer vs simulator lockstep + convergence
# ---------------------------------------------------------------------------


def test_trainer_rs_ag_matches_simulator():
    tc = TrainConfig(optimizer="pier", total_steps=20, global_batch_size=4,
                     seq_len=16, sync_interval=4, warmup_frac=0.25, seed=0,
                     outer_comm=OuterCommConfig(
                         compression="rs-ag", bits=8, block=BLOCK))
    from repro.launch import mesh as M
    from repro.launch.train import Trainer

    sim = SimulatedRun(MC, tc, num_groups=1, seed=0)
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = M.small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))
    tr = Trainer(MC, tc, pc, mesh)
    assert tr.bundle.plan.needs_residual2
    assert tr.outer.residual2 is not None
    for step in range(16):
        batch = sim._global_batch(step)
        tr.train_step(jax.device_put(batch, tr.bundle.batch_sharding(batch)))
        sim.run(1)
    worst = 0.0
    simp = (sim.state.group_params if sim.state.group_params is not None
            else sim.state.params)
    for a, b in zip(
            jax.tree.leaves(jax.tree.map(lambda g: g[0], simp)),
            jax.tree.leaves(jax.tree.map(lambda x: x[0], tr.state.params))):
        worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                         - jnp.asarray(b, jnp.float32)
                                         ).max()))
    assert worst < 5e-4, worst


def test_rs_ag_convergence_within_5pct_of_fp32():
    tc = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5)
    eager = SimulatedRun(MC, tc, num_groups=2, seed=0)
    he = eager.run(60, eval_every=60)
    tw = _tc(total_steps=60, warmup_frac=0.2, sync_interval=5,
             comm=OuterCommConfig(compression="rs-ag", bits=8,
                                  block=BLOCK))
    wire = SimulatedRun(MC, tw, num_groups=2, seed=0)
    hw = wire.run(60, eval_every=60)
    ve, vw = he["val_loss"][-1], hw["val_loss"][-1]
    assert vw <= ve * 1.05, (ve, vw)


# ---------------------------------------------------------------------------
# warmup-sample width scaling (satellite: MeasuredDelayController)
# ---------------------------------------------------------------------------


def test_wire_bytes_per_param_model():
    tc = _tc()
    assert FlatFP32().wire_bytes_per_param(tc) == 4.0
    # Quantized's actual collective is the fp32 pmean of the dequantized
    # payload — full width on the wire
    assert Quantized(8, BLOCK).wire_bytes_per_param(tc) == 4.0
    w = Int8Wire(bits=8, block=BLOCK)
    assert w.wire_bytes_per_param(tc) == 8 / 8 + 4 / BLOCK
    assert Int8Wire(bits=4, block=BLOCK).wire_bytes_per_param(tc) == \
        4 / 8 + 4 / BLOCK
    # combinators delegate to the wire core
    assert Sharded(inner=w).wire_bytes_per_param(tc) == \
        w.wire_bytes_per_param(tc)
    assert Hierarchical(inner=w).wire_bytes_per_param(tc) == \
        w.wire_bytes_per_param(tc)
    assert Chunked(inner=w, num_chunks=2).wire_bytes_per_param(tc) == \
        w.wire_bytes_per_param(tc)


def test_warmup_samples_rescaled_by_payload_width():
    """Warmup accumulate windows exchange fp32 whatever the strategy;
    with warmup_scale the rescaled samples resolve the compressed wire's
    d* before the first post-warmup sync."""
    from repro.sync import FixedDelayController

    tc = _tc(sync_delay=0, sync_interval=10)
    scale = Int8Wire(bits=8, block=BLOCK).wire_bytes_per_param(tc) / 4.0
    c = MeasuredDelayController(tc, fallback=FixedDelayController(9),
                                min_windows=2, skip_windows=1,
                                warmup_scale=scale)
    c.observe_step(0.1)
    for _ in range(3):  # 1 skip + 2 measured warmup windows
        c.observe_window(t_comm=0.8, warmup=True)
    # fp32 sample 0.8s -> int8 wire estimate 0.8*scale ~ 0.2125s -> d*=3
    assert c.current_delay() == int(np.ceil(0.8 * scale / 0.1))
    # without the warmup flag the sample enters the EMA unscaled
    c2 = MeasuredDelayController(tc, fallback=FixedDelayController(9),
                                 min_windows=2, skip_windows=1,
                                 warmup_scale=scale)
    c2.observe_step(0.1)
    for _ in range(3):
        c2.observe_window(t_comm=0.8)
    assert c2.current_delay() == 8  # ceil(0.8/0.1)


def test_strategy_warmup_scale_reaches_controller():
    tc = _tc(sync_delay=0)
    w = Int8Wire(bits=8, block=BLOCK)
    ctrl = w.make_delay_controller(tc, None, None)
    assert isinstance(ctrl, MeasuredDelayController)
    assert ctrl.warmup_scale == pytest.approx(
        w.wire_bytes_per_param(tc) / 4.0)
    # fp32 strategies keep warmup samples exact
    assert FlatFP32().make_delay_controller(
        tc, None, None).warmup_scale == 1.0


# ---------------------------------------------------------------------------
# jaxlib version gate for ragged sharded leaves (satellite)
# ---------------------------------------------------------------------------


def test_can_pad_in_manual_gate_both_ways(monkeypatch):
    """Sharded(Quantized) ragged leaves: shard-local pad path when the
    gate is open (modern jax), replicated compress_delta fallback when
    closed (jaxlib 0.4.x partitioner CHECK). Both keep the exact
    error-feedback identity c == payload + residual'."""
    from repro import compat
    from repro.sync import ReduceCtx
    from repro.sync import strategies as S

    assert S._can_pad_in_manual() == compat.HAS_NEW_SHARD_MAP

    ctx = ReduceCtx(manual=(), fast_axes=(), slow_axes=(),
                    exchange_axes=(), axis_sizes={})
    st = Sharded(inner=Quantized(8, BLOCK))
    n = BLOCK * 2 + 7  # ragged: does not divide block * auto_size
    d = jax.random.normal(jax.random.PRNGKey(11), (n,))
    r = 0.01 * jax.random.normal(jax.random.PRNGKey(12), (n,))
    tc = _tc()

    outs = {}
    for gate in (False, True):
        monkeypatch.setattr(S, "_can_pad_in_manual", lambda: gate)
        payload, new_r = st.reduce_leaf(d, r, tc, ctx)
        assert payload.shape == (n,) and new_r.shape == (n,)
        np.testing.assert_allclose(
            np.asarray(payload + new_r), np.asarray(d + r), atol=1e-6)
        outs[gate] = (np.asarray(payload), np.asarray(new_r))
    # same numeric model either way: both paths quantize the same blocks
    np.testing.assert_allclose(outs[False][0], outs[True][0], atol=1e-6)
    np.testing.assert_allclose(outs[False][1], outs[True][1], atol=1e-6)
