"""Data pipeline determinism + checkpoint roundtrip/resume."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.synthetic import MarkovLM, make_train_batch


def test_markov_determinism():
    lm1 = MarkovLM(128, seed=7)
    lm2 = MarkovLM(128, seed=7)
    k = jax.random.PRNGKey(3)
    a = lm1.sample(k, 4, 16)
    b = lm2.sample(k, 4, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = lm1.sample(jax.random.PRNGKey(4), 4, 16)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_markov_structure_learnable():
    """Each token's successor must come from its fixed successor set."""
    lm = MarkovLM(64, seed=1, branching=4)
    toks = np.asarray(lm.sample(jax.random.PRNGKey(0), 8, 64))
    succ = np.asarray(lm._succ)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            assert b in succ[a]
    assert 0 < lm.entropy < np.log(64)


def test_batch_format():
    lm = MarkovLM(128, seed=0)
    b = make_train_batch(lm, jax.random.PRNGKey(0), 4, 32)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "nested": [jnp.ones((4,)), {"x": jnp.zeros((2, 2))}]}
    mgr.save(10, {"state": tree}, metadata={"step": 10})
    mgr.save(20, {"state": tree}, metadata={"step": 20})
    mgr.save(30, {"state": tree}, metadata={"step": 30})
    assert mgr.all_steps() == [20, 30]  # keep=2 garbage-collects step 10
    out, meta = mgr.restore(30, {"state": tree})
    assert meta["step"] == 30
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out["state"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"state": {"w": jnp.ones((4,))}})
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, {"state": {"w": jnp.ones((8,))}})


def test_checkpoint_dtype_cast_to_template(tmp_path):
    """An array saved under one opt_state_dtype restores into a template of
    another by validate-and-cast — the template dtype is authoritative, so
    resume numerics never silently change."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"state": {"m": jnp.arange(6.0, dtype=jnp.float32)}})
    out, _ = mgr.restore(
        1, {"state": {"m": jnp.zeros((6,), jnp.bfloat16)}})
    restored = out["state"]["m"]
    assert restored.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(restored, np.float32),
        np.arange(6.0, dtype=np.float32).astype(jnp.bfloat16).astype(
            np.float32))


class _AnonKey:
    """A path entry carrying none of key/name/idx — stringifies to ""."""


class _AnonPair:
    """A pytree node whose children flatten with anonymous path entries:
    both leaves' checkpoint keys stringify to the same empty string."""

    def __init__(self, a, b):
        self.a, self.b = a, b


jax.tree_util.register_pytree_with_keys(
    _AnonPair,
    lambda n: (((_AnonKey(), n.a), (_AnonKey(), n.b)), None),
    lambda _, ch: _AnonPair(*ch))


def test_checkpoint_duplicate_key_rejected_at_save(tmp_path):
    """Regression: two leaves whose paths stringify identically used to
    silently overwrite each other in the npz dict; save must raise."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _AnonPair(jnp.ones((2,)), jnp.zeros((3,)))
    with pytest.raises(ValueError, match="duplicate checkpoint key"):
        mgr.save(1, {"state": tree})
    # distinct keys keep working
    mgr.save(2, {"state": {"a": jnp.ones((2,)), "b": jnp.zeros((3,))}})


# ---------------------------------------------------------------------------
# crash safety: atomic save + corrupt-checkpoint quarantine
# ---------------------------------------------------------------------------

_TREE = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((4,))}


def test_checkpoint_save_leaves_no_partial_state(tmp_path):
    import os
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"state": _TREE})
    mgr.save(2, {"state": _TREE})
    # every temp artifact was renamed into place
    assert not [n for n in os.listdir(tmp_path) if ".tmp" in n]
    for d in os.listdir(tmp_path):
        assert not [n for n in os.listdir(tmp_path / d) if ".tmp" in n]
    # stale debris from a crashed save is swept on the next one
    os.makedirs(tmp_path / "step_00000003.tmp")
    mgr.save(3, {"state": _TREE})
    assert mgr.all_steps() == [1, 2, 3]


def test_checkpoint_truncated_npz_skipped_with_warning(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"state": _TREE})
    mgr.save(2, {"state": _TREE})
    victim = tmp_path / "step_00000002" / "state.npz"
    victim.write_bytes(victim.read_bytes()[:-20])  # torn write
    fresh = CheckpointManager(str(tmp_path))  # no memoized verification
    with pytest.warns(UserWarning, match="skipping corrupt checkpoint"):
        assert fresh.all_steps() == [1]
    assert fresh.latest_step() == 1  # auto-resume lands on the survivor
    with pytest.raises(ValueError, match="incomplete/corrupt"):
        fresh.restore(2, {"state": _TREE})
    out, _ = fresh.restore(1, {"state": _TREE})
    np.testing.assert_allclose(np.asarray(out["state"]["w"]),
                               np.asarray(_TREE["w"]))


@pytest.mark.parametrize("breakage", ["no_manifest", "garbage_manifest",
                                      "missing_archive", "missing_array"])
def test_checkpoint_incomplete_step_skipped(tmp_path, breakage):
    import json
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"state": _TREE})
    mgr.save(2, {"state": _TREE})
    step2 = tmp_path / "step_00000002"
    if breakage == "no_manifest":
        (step2 / "manifest.json").unlink()
    elif breakage == "garbage_manifest":
        (step2 / "manifest.json").write_text("{not json")
    elif breakage == "missing_archive":
        (step2 / "state.npz").unlink()
    else:  # an archive that lost one of its manifest-listed arrays
        np.savez(step2 / "state.npz", w=np.zeros((2, 3), np.float32))
    fresh = CheckpointManager(str(tmp_path))
    with pytest.warns(UserWarning, match="skipping corrupt checkpoint"):
        assert fresh.all_steps() == [1]
    with pytest.raises(ValueError, match="incomplete/corrupt"):
        fresh.restore(2, {"state": _TREE})


def test_checkpoint_corruption_warns_once_and_gc_survives(tmp_path):
    import warnings as _warnings
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"state": _TREE})
    (tmp_path / "step_00000001" / "manifest.json").unlink()
    fresh = CheckpointManager(str(tmp_path), keep=2)
    with pytest.warns(UserWarning):
        fresh.all_steps()
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")  # second sweep stays quiet
        assert fresh.all_steps() == []
        # saves (and their GC pass over the corrupt dir) keep working
        fresh.save(2, {"state": _TREE})
        fresh.save(3, {"state": _TREE})
        fresh.save(4, {"state": _TREE})
        assert fresh.all_steps() == [3, 4]


def test_trainer_resume_determinism(tmp_path):
    """train 10 == train 5 + save + restore + train 5 (single device)."""
    from repro.config import ModelConfig, ParallelConfig, TrainConfig
    from repro.launch.mesh import small_mesh
    from repro.launch.train import Trainer
    from repro.data.pipeline import synthetic_pipeline
    from repro.launch import mesh as M

    mc = ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                     d_ff=128, vocab_size=128, dtype="float32")
    tc = TrainConfig(optimizer="pier", total_steps=10, global_batch_size=4,
                     seq_len=16, sync_interval=2, warmup_frac=0.2)
    pc = ParallelConfig(data_axis_size=1, model_axis_size=1, data_outer=1)
    mesh = small_mesh((1, 1, 1), ("data_outer", "data_inner", "model"))

    def run(n, ckpt_dir, restore_at=None):
        t = Trainer(mc, tc, pc, mesh, checkpoint_dir=ckpt_dir)
        pipe = synthetic_pipeline(mesh, M.data_axes(mesh), mc, tc)
        if restore_at is not None:
            t.restore(restore_at)
            # skip already-consumed batches for determinism
            for _ in range(restore_at):
                next(pipe)
        t.run(n, pipe, log_every=0)
        pipe.close()
        return t

    d1 = str(tmp_path / "a")
    t_full = run(10, d1)
    d2 = str(tmp_path / "b")
    t_half = Trainer(mc, tc, pc, mesh, checkpoint_dir=d2)
    pipe = synthetic_pipeline(mesh, M.data_axes(mesh), mc, tc)
    t_half.run(5, pipe, log_every=0)
    t_half.save()
    pipe.close()
    t_resumed = run(5, d2, restore_at=5)
    a = jax.tree.leaves(t_full.state.params)
    b = jax.tree.leaves(t_resumed.state.params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)
