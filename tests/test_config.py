"""Config correctness: assigned architectures match the assignment table,
schedules implement Algorithm 2 / §V exactly."""

import pytest

from repro.config import INPUT_SHAPES, TrainConfig
from repro.configs import (assigned_architectures, get_config,
                           get_reduced_config, list_architectures)

# (name, layers, d_model, heads, kv, d_ff_or_moe_ff, vocab)
ASSIGNMENT = {
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102_400),
    "granite-8b": (36, 4096, 32, 8, 14336, 49_152),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122_753),
    "qwen3-14b": (40, 5120, 40, 8, 17408, 151_936),
    "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151_936),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50_304),
    "chameleon-34b": (48, 8192, 64, 8, 22016, 65_536),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256_000),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
}


@pytest.mark.parametrize("arch", sorted(ASSIGNMENT))
def test_assigned_config_matches_table(arch):
    L, d, h, kv, ff, v = ASSIGNMENT[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.vocab_size == v
    if cfg.is_moe:
        assert cfg.moe_d_ff == ff
    elif ff:
        assert cfg.d_ff == ff


def test_assignment_pool_complete():
    assert sorted(assigned_architectures()) == sorted(ASSIGNMENT)
    assert len(list_architectures()) == 14  # + GPT-2 family


def test_moe_details():
    ds = get_config("deepseek-v2-236b")
    assert (ds.num_experts, ds.num_experts_per_tok, ds.num_shared_experts) \
        == (160, 6, 2)
    assert ds.attention_kind == "mla" and ds.kv_lora_rank == 512
    k2 = get_config("kimi-k2-1t-a32b")
    assert (k2.num_experts, k2.num_experts_per_tok) == (384, 8)


def test_reduced_configs_are_small():
    for arch in list_architectures():
        cfg = get_reduced_config(arch)
        assert cfg.num_layers <= 3
        assert cfg.d_model <= 512
        assert cfg.num_experts <= 4


def test_input_shapes():
    s = INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768 and s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524_288 and s["long_500k"].global_batch == 1


def test_sub_quadratic_flags():
    assert get_config("xlstm-1.3b").sub_quadratic
    assert get_config("recurrentgemma-9b").sub_quadratic
    assert not get_config("granite-8b").sub_quadratic
    assert get_config("granite-8b").replace(sliding_window=4096).sub_quadratic
    assert not get_config("deepseek-v2-236b").sub_quadratic


# ---------------------------------------------------------------------------
# schedules (Algorithm 2 lines 12-18, §V outer LR)
# ---------------------------------------------------------------------------


def test_momentum_decay_schedule():
    tc = TrainConfig(total_steps=1000)
    assert tc.mu_at(100) == 0.99  # 10% boundary
    assert tc.mu_at(149) == 0.99
    assert tc.mu_at(150) == 0.95
    assert tc.mu_at(199) == 0.95
    assert tc.mu_at(200) == 0.90
    assert tc.mu_at(999) == 0.90


def test_outer_lr_schedule():
    tc = TrainConfig(total_steps=1000)
    assert tc.outer_lr_at(0) == 0.0  # lazy start: outer not applied
    assert tc.outer_lr_at(99) == 0.0
    mid = tc.outer_lr_at(150)
    assert 0.0 < mid < 1.0  # linear warmup 0 -> 1 over [10%, 20%]
    assert abs(tc.outer_lr_at(150) - 0.5) < 0.02
    assert tc.outer_lr_at(500) == 1.1  # 20%-80%
    assert tc.outer_lr_at(900) == 0.9  # final 20%


def test_pier_schedule_phases():
    from repro.core.pier import PierSchedule

    tc = TrainConfig(total_steps=1000, sync_interval=50, optimizer="pier")
    s = PierSchedule(tc)
    assert s.phase(0) == "warmup"
    assert s.phase(99) == "warmup"
    assert s.phase(100) == "inner"
    # sync events fire at interval boundaries
    assert s.is_sync_step(49) and s.sync_kind(49) == "accumulate"
    assert not s.is_sync_step(50)
    assert s.is_sync_step(149) and s.sync_kind(149) == "outer"
    # comm fraction: warmup (10%) + 1/50 of the rest
    assert abs(s.global_comm_fraction() - (0.1 + 0.9 / 50)) < 1e-9


def test_diloco_schedule():
    from repro.core.pier import PierSchedule

    tc = TrainConfig(total_steps=1000, sync_interval=50, optimizer="diloco",
                     lazy_start=False)
    s = PierSchedule(tc)
    assert s.phase(0) == "inner"  # no lazy start
    assert s.mu_at(120) == 0.9  # fixed mu (no decay schedule)
    assert s.outer_lr_at(500) == tc.fixed_outer_lr


def test_adamw_schedule():
    from repro.core.pier import PierSchedule

    tc = TrainConfig(total_steps=1000, optimizer="adamw")
    s = PierSchedule(tc)
    assert s.phase(999) == "warmup"
    assert not s.is_sync_step(49)
    assert s.global_comm_fraction() == 1.0
