"""Pier outer optimizer: Algorithm 1 & 2 algebra, incl. the PyTorch-Nesterov
formulation equivalence the paper discusses in §V."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import TrainConfig
from repro.core.outer import OuterState, outer_init, outer_update, warmup_accumulate
from repro.kernels.ref import pier_update_ref


def _mk_state(p0, tc):
    params = {"w": jnp.asarray(p0)}
    return params, outer_init(params, tc)


def test_warmup_accumulate_algebra():
    """Alg. 1 lines 5-6: M <- mu*M + (theta_t - theta_{t-r}); anchor moves."""
    tc = TrainConfig()
    params, st0 = _mk_state(np.zeros(4, np.float32), tc)
    p1 = {"w": jnp.asarray(np.array([1.0, 2.0, 3.0, 4.0], np.float32))}
    st1 = warmup_accumulate(st0, p1, 0.9)
    np.testing.assert_allclose(np.asarray(st1.momentum["w"]), [1, 2, 3, 4])
    np.testing.assert_allclose(np.asarray(st1.anchor["w"]), [1, 2, 3, 4])
    p2 = {"w": p1["w"] + 1.0}
    st2 = warmup_accumulate(st1, p2, 0.9)
    # M = 0.9*[1,2,3,4] + [1,1,1,1]
    np.testing.assert_allclose(np.asarray(st2.momentum["w"]),
                               [1.9, 2.8, 3.7, 4.6], rtol=1e-6)
    assert int(st2.num_syncs) == 2


def _torch_nesterov_sgd(grad, buf, mu, lr, theta):
    """Reference: PyTorch SGD (nesterov=True, dampening=0) semantics.

    buf <- mu*buf + g;  update = g + mu*buf;  theta <- theta - lr*update.
    Pier feeds g = -delta (delta is the improvement direction), hence signs.
    """
    buf = mu * buf + grad
    update = grad + mu * buf
    return theta - lr * update, buf


def test_torch_nesterov_equivalence():
    """Alg. 2 l.20-21 == PyTorch nesterov SGD on the outer 'gradient' -delta."""
    tc = TrainConfig(outer_optimizer="nesterov_torch")
    rng = np.random.default_rng(1)
    anchor = rng.normal(size=6).astype(np.float32)
    params, st = _mk_state(anchor, tc)
    buf = np.zeros(6, np.float32)
    theta = anchor.copy()
    for it in range(4):
        delta = rng.normal(size=6).astype(np.float32) * 0.1
        new_p, st = outer_update(st, {"w": jnp.asarray(delta)}, tc,
                                 mu=0.9, lr=0.7)
        theta, buf = _torch_nesterov_sgd(-delta, buf, 0.9, 0.7, theta)
        np.testing.assert_allclose(np.asarray(new_p["w"]), theta, rtol=1e-5,
                                   atol=1e-6)
        # anchor follows the synced model
        np.testing.assert_allclose(np.asarray(st.anchor["w"]), theta,
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("form", ["nesterov_torch", "nesterov_classic", "sgd"])
def test_outer_matches_kernel_ref(form):
    tc = TrainConfig(outer_optimizer=form)
    rng = np.random.default_rng(2)
    anchor = rng.normal(size=(3, 5)).astype(np.float32)
    params, st = _mk_state(anchor, tc)
    m0 = rng.normal(size=(3, 5)).astype(np.float32)
    st = OuterState(momentum={"w": jnp.asarray(m0)}, anchor=st.anchor,
                    num_syncs=st.num_syncs)
    delta = rng.normal(size=(3, 5)).astype(np.float32)
    new_p, st2 = outer_update(st, {"w": jnp.asarray(delta)}, tc, mu=0.95,
                              lr=1.1)
    ref_p, ref_m = pier_update_ref(anchor, m0, delta, mu=0.95, lr=1.1,
                                   formulation=form)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(ref_p),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st2.momentum["w"]),
                               np.asarray(ref_m), rtol=1e-6)


@given(mu=st.floats(0.0, 0.999), lr=st.floats(0.0, 2.0),
       seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_outer_update_properties(mu, lr, seed):
    """Zero delta with zero momentum is a fixed point; lr=0 freezes theta."""
    tc = TrainConfig(outer_optimizer="nesterov_torch")
    rng = np.random.default_rng(seed)
    anchor = rng.normal(size=8).astype(np.float32)
    params, st = _mk_state(anchor, tc)
    zero = {"w": jnp.zeros(8)}
    new_p, st2 = outer_update(st, zero, tc, mu=mu, lr=lr)
    np.testing.assert_allclose(np.asarray(new_p["w"]), anchor, atol=1e-6)
    delta = {"w": jnp.asarray(rng.normal(size=8).astype(np.float32))}
    frozen, _ = outer_update(st, delta, tc, mu=mu, lr=0.0)
    np.testing.assert_allclose(np.asarray(frozen["w"]), anchor, atol=1e-6)


def test_opt_state_dtype_bf16():
    tc = TrainConfig(opt_state_dtype="bfloat16")
    params, st = _mk_state(np.ones(4, np.float32), tc)
    assert st.momentum["w"].dtype == jnp.bfloat16
    assert st.anchor["w"].dtype == jnp.bfloat16
