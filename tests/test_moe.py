"""MoE routing/dispatch semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import moe as MOE


def _cfg(**kw):
    base = dict(d_model=32, moe_d_ff=16, num_experts=4, num_experts_per_tok=2,
                num_shared_experts=0, num_layers=2, dtype="float32",
                expert_capacity_factor=8.0)
    base.update(kw)
    return ModelConfig(**base)


def _naive_moe(p, x, cfg):
    """Loop-over-experts oracle (no capacity limit)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D).astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    topk_probs = topk_probs / jnp.sum(topk_probs, axis=-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(cfg.num_experts):
        gate = xf @ p["w_gate"][e].astype(jnp.float32)
        up = xf @ p["w_up"][e].astype(jnp.float32)
        h = jax.nn.silu(gate) * up
        eo = h @ p["w_down"][e].astype(jnp.float32)
        for k in range(cfg.num_experts_per_tok):
            w = jnp.where(topk_idx[:, k] == e, topk_probs[:, k], 0.0)
            out = out + w[:, None] * eo
    return out.reshape(B, S, D)


def test_moe_matches_naive_loop(rng):
    cfg = _cfg()
    p = MOE.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 8, 32))
    out, stats = MOE.apply_moe(p, x, cfg)
    ref = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_moe_shared_expert_added(rng):
    cfg = _cfg(num_shared_experts=1)
    p = MOE.init_moe(rng, cfg)
    x = jax.random.normal(rng, (1, 4, 32))
    out, _ = MOE.apply_moe(p, x, cfg)
    routed = _naive_moe(p, x, cfg)
    from repro.models import layers as L
    shared = L.apply_mlp(p["shared"], x, cfg)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(routed + shared),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drops_tokens(rng):
    """With capacity_factor -> tiny, overflowing tokens contribute zeros."""
    cfg = _cfg(expert_capacity_factor=1e-6)  # capacity floor = 8 slots
    p = MOE.init_moe(rng, cfg)
    x = jax.random.normal(rng, (4, 32, 32))  # 128 tokens, 256 assignments
    out, stats = MOE.apply_moe(p, x, cfg)
    ref = _naive_moe(p, x, cfg)
    # some tokens must differ from the capacity-free oracle (drops)
    assert float(jnp.abs(out - ref).max()) > 1e-4
    assert bool(jnp.isfinite(out).all())


def test_load_stats_and_aux_loss(rng):
    cfg = _cfg()
    p = MOE.init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 16, 32))
    _, stats = MOE.apply_moe(p, x, cfg)
    load = np.asarray(stats["load"])
    assert abs(load.sum() - 1.0) < 1e-5  # assignment fractions
    # Switch aux loss is >= 1 (equality iff perfectly uniform)
    assert float(stats["aux_loss"]) >= 0.99
    assert float(stats["z_loss"]) >= 0.0


def test_expert_capacity_helper():
    cfg = _cfg(expert_capacity_factor=1.25)
    c = MOE.expert_capacity(1024, cfg)
    assert c % 8 == 0
    assert c >= 1024 * 2 / 4  # >= tokens*k/E


def test_moe_grads_flow_to_router(rng):
    cfg = _cfg()
    p = MOE.init_moe(rng, cfg)
    x = jax.random.normal(rng, (1, 8, 32))

    def loss(p):
        out, stats = MOE.apply_moe(p, x, cfg)
        return jnp.sum(out ** 2) + stats["aux_loss"]

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
