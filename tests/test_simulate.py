"""Semantics of the simulated multi-group runner (the convergence harness)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.core.simulate import SimulatedRun

MC = ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                 d_ff=128, vocab_size=128, dtype="float32",
                 norm="layernorm", activation="gelu", positional="learned",
                 max_position_embeddings=64)


def _tc(**kw):
    base = dict(total_steps=40, global_batch_size=8, seq_len=16,
                sync_interval=5, inner_lr=1e-3, inner_min_lr=1e-4)
    base.update(kw)
    return TrainConfig(**base)


def test_pier_equals_adamw_during_warmup():
    """First 10% (warmup) of Pier is exactly global AdamW."""
    tc_p = _tc(optimizer="pier", warmup_frac=0.5)
    tc_a = _tc(optimizer="adamw")
    rp = SimulatedRun(MC, tc_p, num_groups=4, seed=3)
    ra = SimulatedRun(MC, tc_a, num_groups=1, seed=3)
    hp = rp.run(19)
    ha = ra.run(19)
    np.testing.assert_allclose(hp["train_loss"], ha["train_loss"],
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(rp.state.params),
                    jax.tree.leaves(ra.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_groups_diverge_then_resync():
    tc = _tc(optimizer="pier", warmup_frac=0.25)  # warmup ends at step 10
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    r.run(12)  # steps 10, 11 are inner steps (no sync yet)
    gp = r.state.group_params
    assert gp is not None
    leaf = jax.tree.leaves(gp)[0]
    assert float(jnp.abs(leaf[0] - leaf[1]).max()) > 0  # diverged
    r.run(3)  # hits the sync at step 14 (15 % 5 == 0)
    leaf = jax.tree.leaves(r.state.group_params)[0]
    assert float(jnp.abs(leaf[0] - leaf[1]).max()) == 0  # resynced


def test_momentum_warmup_accumulates():
    tc = _tc(optimizer="pier", warmup_frac=0.5)
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    m0 = jax.tree.leaves(r.state.outer.momentum)[0]
    assert float(jnp.abs(m0).max()) == 0
    r.run(10)  # two accumulation events (steps 4, 9)
    m1 = jax.tree.leaves(r.state.outer.momentum)[0]
    assert float(jnp.abs(m1).max()) > 0
    assert int(r.state.outer.num_syncs) == 2


def test_diloco_has_no_momentum_warmup():
    tc = _tc(optimizer="diloco", lazy_start=False, momentum_warmup=False)
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    r.run(4)  # inner from step 0
    assert r.state.group_params is not None  # groups exist immediately


def test_loss_decreases():
    tc = _tc(optimizer="pier", total_steps=60, warmup_frac=0.2)
    r = SimulatedRun(MC, tc, num_groups=2, seed=0)
    h = r.run(60)
    first = np.mean(h["train_loss"][:5])
    last = np.mean(h["train_loss"][-5:])
    assert last < first - 0.5
