"""Outer-update formulations vs a numpy closed-form oracle, on both the XLA
path and the fused Pallas kernel path (interpret mode), over random pytrees.

The oracle is written against the paper's Algorithm 2 directly (not against
kernels/ref.py, which the kernel tests already use) so the XLA, Pallas, and
reference implementations are pinned to one independent formula.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or example-based shim

from repro.config import TrainConfig
from repro.core.outer import OuterState, outer_reduce, outer_update

FORMS = ["nesterov_torch", "nesterov_classic", "sgd"]


def _np_outer(form, anchor, momentum, delta, mu, lr):
    """Algorithm 2 lines 19-21, closed form in numpy fp32."""
    m_new = mu * momentum + delta
    if form == "nesterov_torch":
        step = mu * m_new + delta
    elif form == "nesterov_classic":
        step = mu * momentum + delta
    else:  # sgd
        step = m_new
    return anchor + lr * step, m_new


def _random_pytree(rng, shapes=((4, 3), (8,), (2, 3, 5))):
    return {
        "layer0": {"w": rng.normal(size=shapes[0]).astype(np.float32),
                   "b": rng.normal(size=shapes[1]).astype(np.float32)},
        "layer1": rng.normal(size=shapes[2]).astype(np.float32),
    }


def _state_from(m_tree, a_tree):
    return OuterState(
        momentum=jax.tree.map(jnp.asarray, m_tree),
        anchor=jax.tree.map(jnp.asarray, a_tree),
        num_syncs=jnp.zeros((), jnp.int32))


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas-interpret"])
@pytest.mark.parametrize("form", FORMS)
def test_outer_matches_numpy_oracle(form, use_pallas):
    tc = TrainConfig(outer_optimizer=form)
    rng = np.random.default_rng(7)
    anchor, momentum, delta = (_random_pytree(rng) for _ in range(3))
    state = _state_from(momentum, anchor)
    mu, lr = 0.93, 1.1
    new_p, new_state = outer_update(
        state, jax.tree.map(jnp.asarray, delta), tc, mu=jnp.float32(mu),
        lr=jnp.float32(lr), use_pallas=use_pallas)
    flat_p, _ = jax.tree_util.tree_flatten(new_p)
    flat_m, _ = jax.tree_util.tree_flatten(new_state.momentum)
    ref = [_np_outer(form, a, m, d, np.float32(mu), np.float32(lr))
           for a, m, d in zip(jax.tree_util.tree_leaves(anchor),
                              jax.tree_util.tree_leaves(momentum),
                              jax.tree_util.tree_leaves(delta))]
    for (rp, rm), p, m in zip(ref, flat_p, flat_m):
        np.testing.assert_allclose(np.asarray(p), rp, rtol=2e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(m), rm, rtol=2e-6, atol=1e-6)
    # anchor follows the new params on every formulation and both paths
    for a, p in zip(jax.tree_util.tree_leaves(new_state.anchor), flat_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(p), rtol=1e-6)
    assert int(new_state.num_syncs) == 1


@given(mu=st.floats(0.0, 0.999), lr=st.floats(0.0, 2.0),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_xla_and_pallas_paths_agree(mu, lr, seed):
    """The fused kernel is a drop-in for the XLA tree-map on every form."""
    rng = np.random.default_rng(seed)
    anchor, momentum, delta = (_random_pytree(rng) for _ in range(3))
    d = jax.tree.map(jnp.asarray, delta)
    for form in FORMS:
        tc = TrainConfig(outer_optimizer=form)
        p_x, s_x = outer_reduce(_state_from(momentum, anchor), d, tc,
                                mu=jnp.float32(mu), lr=jnp.float32(lr))
        p_k, s_k = outer_reduce(_state_from(momentum, anchor), d, tc,
                                mu=jnp.float32(mu), lr=jnp.float32(lr),
                                use_pallas=True)
        # the fused kernel reassociates the multiply-adds: 1-2 ULP slack
        for a, b in zip(jax.tree_util.tree_leaves(p_x),
                        jax.tree_util.tree_leaves(p_k)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(s_x.momentum),
                        jax.tree_util.tree_leaves(s_k.momentum)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


def test_unknown_formulation_raises():
    tc = TrainConfig(outer_optimizer="adagrad")
    rng = np.random.default_rng(0)
    t = _random_pytree(rng)
    with pytest.raises(ValueError):
        outer_update(_state_from(t, t), jax.tree.map(jnp.asarray, t), tc,
                     mu=0.9, lr=1.0)
