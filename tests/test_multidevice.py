"""Multi-device semantics via subprocesses (8 forced host devices).

Kept out-of-process so the main pytest run sees the single real CPU device
(per the assignment: no global XLA_FLAGS)."""

import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))

from repro import compat  # noqa: E402  (conftest puts src on sys.path)

SCRIPTS = [
    "md_steps.py",
    "md_equivalence.py",
    "md_membership.py",
    "md_7b_dryrun.py",
    pytest.param(
        "md_dryrun_mini.py",
        marks=pytest.mark.skipif(
            not compat.HAS_NEW_SHARD_MAP,
            reason="jaxlib 0.4.x partial-manual SPMD hits an XLA CHECK "
                   "(hlo_sharding_util IsManualSubgroup) compiling the MoE "
                   "dry-run; needs jax>=0.5 shard_map")),
]


def _run(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "multidevice", script)],
        capture_output=True, text=True, timeout=900, env=env)
    if r.returncode != 0:
        raise AssertionError(
            f"{script} failed\n--- stdout ---\n{r.stdout[-3000:]}"
            f"\n--- stderr ---\n{r.stderr[-3000:]}")
    return r.stdout


@pytest.mark.slow
@pytest.mark.parametrize("script", SCRIPTS)
def test_multidevice(script):
    out = _run(script)
    assert "OK" in out
