"""gpt2_7b DP×TP dry-run smoke: the paper's evaluation model traces end to
end on a data_outer×data_inner×model mesh with the sharded quantized outer
exchange (DESIGN.md §10), and the declared outer-state layout scales
~1/(TP×FSDP) per device.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8. jax must
initialize BEFORE importing repro.launch.dryrun (whose import-time XLA
override to 512 host devices is inert once the backend is up).
"""

import jax

assert jax.device_count() == 8, jax.device_count()

import numpy as np

from repro.launch.dryrun import (collective_bytes, make_train_batch_specs,
                                 _specs_of)
from repro.config import (InputShape, OuterCommConfig, ParallelConfig,
                          TrainConfig)
from repro.configs import get_config
from repro.launch.mesh import small_mesh
from repro.models import registry as R
from repro.parallel.steps import build_train_steps

mc = get_config("gpt2_7b")
assert R.count_params(mc) > 6e9  # the real 7B, not a reduced stand-in

shape = InputShape("7b_smoke_train", 8, 128, "train")
pc = ParallelConfig(data_axis_size=4, model_axis_size=2, data_outer=2,
                    scan_layers=True, remat="full", num_microbatches=1)
tc = TrainConfig(global_batch_size=8, seq_len=128,
                 outer_comm=OuterCommConfig(compression="quantize",
                                            sharded=True))
mesh = small_mesh((2, 2, 2), ("data_outer", "data_inner", "model"))

bundle = build_train_steps(mc, tc, pc, mesh)
assert bundle.plan.name.startswith("sharded[quantized"), bundle.plan.name

state_shapes = jax.eval_shape(bundle.init_state, jax.random.PRNGKey(0))
state_specs = _specs_of(state_shapes, bundle.state_shardings)
batch_specs = make_train_batch_specs(mc, shape, bundle)
step_spec = jax.ShapeDtypeStruct((), jax.numpy.int32)

# inner + warmup trace (lower only: compiling the full 32-layer step on the
# host backend is the production dryrun's job, not this smoke's)
assert bundle.inner_step.lower(state_specs, batch_specs, step_spec)
assert bundle.warmup_step.lower(state_specs, batch_specs, step_spec)
print("gpt2-7b inner/warmup lowered")

# outer sync compiles; the sharded quantized exchange still crosses
# data_outer (a real all-reduce survives SPMD partitioning). Raw
# collective_bytes, not _compile_record: jaxlib 0.4.x cost_analysis()
# returns a list, which _compile_record only handles on jax>=0.5.
outer_shapes = jax.eval_shape(bundle.init_outer, state_shapes)
outer_specs = _specs_of(outer_shapes, bundle.outer_shardings)
mu = jax.ShapeDtypeStruct((), jax.numpy.float32)
compiled = bundle.outer_step.lower(
    state_specs, outer_specs, mu, mu).compile()
coll = collective_bytes(compiled.as_text())
assert coll.get("all-reduce", 0) > 0, coll
print("gpt2-7b outer compiled:", coll)

# declared outer-state layout: per-device bytes ~1/(TP×FSDP) of replicated
# (the 7B weight matrices dominate and shard 4-way over data_inner×model)
def _nbytes(shape, dtype):
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize

leaves = jax.tree.leaves(outer_shapes)
shards = jax.tree.leaves(
    bundle.outer_shardings,
    is_leaf=lambda s: isinstance(s, jax.sharding.NamedSharding))
assert len(leaves) == len(shards)
total = sum(_nbytes(l.shape, l.dtype) for l in leaves)
per_dev = sum(_nbytes(s.shard_shape(l.shape), l.dtype)
              for l, s in zip(leaves, shards))
print(f"gpt2-7b outer state per-device {per_dev/2**30:.2f}GiB "
      f"of {total/2**30:.2f}GiB replicated")
assert per_dev < 0.5 * total, (per_dev, total)

print("MD_7B_DRYRUN_OK")
