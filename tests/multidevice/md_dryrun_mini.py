"""Mini dry-run: the dryrun machinery end-to-end with a reduced arch on a
(2,2,2) pier mesh (pod-less) and a multi-pod analogue.

NOTE: importing repro.launch.dryrun sets XLA_FLAGS to 512 host devices
before jax initializes (by design — its first two lines); the small meshes
here use the first 8 of them.
"""

from repro.launch.dryrun import (  # noqa: E402  (must be first: sets XLA_FLAGS)
    _compile_record, collective_bytes, lower_serve, lower_train,
    make_train_batch_specs)

import jax

from repro.config import ParallelConfig, TrainConfig, InputShape
from repro.configs import get_reduced_config
from repro.launch.mesh import small_mesh

assert jax.device_count() == 512, jax.device_count()

# meshes must span ALL devices: XLA's SPMD partitioner CHECK-fails on
# gather/scatter ops when the mesh covers a strict subset of the world
# (same limitation documented in parallel/sharding.py).
shape = InputShape("mini_train", 64, 64, "train")
mc = get_reduced_config("deepseek-v2-236b")
pc = ParallelConfig(data_axis_size=64, model_axis_size=8, data_outer=2,
                    scan_layers=True, remat="full", num_microbatches=2)
tc = TrainConfig(global_batch_size=64, seq_len=64)
mesh = small_mesh((2, 32, 8), ("data_outer", "data_inner", "model"))

out = lower_train(mc, tc, pc, mesh, shape, steps=("inner", "warmup", "outer"))
rec = {k: _compile_record(v) for k, v in out.items()}
for k, r in rec.items():
    assert r["flops"] > 0 or k == "outer", (k, r["flops"])

# inner has no big cross-group collective, warmup/outer do (checked by bytes:
# warmup adds a gradient-sized all-reduce; inner only scalar metrics)
inner_ar = rec["inner"]["collective_bytes"].get("all-reduce", 0)
warm_ar = rec["warmup"]["collective_bytes"].get("all-reduce", 0)
outer_ar = rec["outer"]["collective_bytes"].get("all-reduce", 0)
assert warm_ar > inner_ar, (warm_ar, inner_ar)
assert outer_ar > 0

# multi-pod analogue mesh: (pod=2, data_outer=1, data_inner=32, model=8)
mesh_mp = small_mesh((2, 1, 32, 8),
                     ("pod", "data_outer", "data_inner", "model"))
pc_mp = ParallelConfig(data_axis_size=32, model_axis_size=8, num_pods=2,
                       data_outer=1, scan_layers=True, remat="full",
                       num_microbatches=2)
out_mp = lower_train(mc, tc, pc_mp, mesh_mp, shape, steps=("inner",))
assert out_mp["inner"] is not None

# serve paths
dshape = InputShape("mini_decode", 64, 64, "decode")
sv = lower_serve(mc, pc, mesh, dshape, prefill=False)
assert _compile_record(sv["decode"])["flops"] >= 0
pshape = InputShape("mini_prefill", 64, 64, "prefill")
pv = lower_serve(mc, pc, mesh, pshape, prefill=True)
assert pv["prefill"] is not None

print("MD_DRYRUN_MINI_OK")
