"""Elastic membership on a real mesh (DESIGN.md §11).

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8.

Three claims:

1. **All-ones bit-identity**: a Trainer driven through the elastic
   (weighted) step graphs at full membership is *bitwise* equal to the
   fixed-membership Trainer — for the flat fp32 collective, the
   quantized+delayed path (residual state included), and the
   hierarchical int8 wire ring on a pod mesh. This is what makes it safe
   to keep the elastic graphs always-on whenever a controller is
   attached.
2. **Churn agreement**: under scripted drop + rejoin + straggler churn,
   the distributed Trainer and the vmap simulator — consuming identical
   membership records and batch streams — agree on every group's params
   at every outer boundary (inner-step noise tolerance, as
   md_equivalence.py).
3. The launcher wires ``--churn-script`` end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (MembershipConfig, ModelConfig, OuterCommConfig,
                          ParallelConfig, TrainConfig)
from repro.core.simulate import SimulatedRun
from repro.launch.mesh import small_mesh
from repro.launch.train import Trainer
from repro.sync import ChurnSchedule, MembershipController

assert jax.device_count() == 8

mc = ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                 d_ff=128, vocab_size=128, dtype="float32",
                 norm="layernorm", activation="gelu", positional="learned",
                 max_position_embeddings=64)
tc = TrainConfig(optimizer="pier", total_steps=20, global_batch_size=8,
                 seq_len=16, sync_interval=4, warmup_frac=0.4,
                 inner_lr=1e-3, inner_min_lr=1e-4, seed=0)

# 4 groups x 1 data_inner x 2 model
pc = ParallelConfig(data_axis_size=4, model_axis_size=2, data_outer=4)
mesh = small_mesh((4, 1, 2), ("data_outer", "data_inner", "model"))


def _drive(trainer, sim, steps):
    """Identical batch streams: sim._global_batch is pure in (seed, step)."""
    for step in range(steps):
        batch = sim._global_batch(step)
        dist = jax.device_put(batch, trainer.bundle.batch_sharding(batch))
        trainer.train_step(dist)
    return trainer


def _assert_bitwise(ta, tb, what):
    for a, b in zip(jax.tree.leaves(ta.state.params),
                    jax.tree.leaves(tb.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ta.outer.momentum),
                    jax.tree.leaves(tb.outer.momentum)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"all-ones elastic bitwise == fixed ({what})")


# ---- 1. all-ones elastic graphs bitwise == fixed graphs ----
sim = SimulatedRun(mc, tc, num_groups=4, seed=0)  # batch-stream source

variants = [
    ("fp32 d=0", tc),
    ("quantize d=2 (residual)", tc.replace(
        sync_delay=2,
        outer_comm=OuterCommConfig(compression="quantize", bits=8,
                                   block=64))),
]
for what, tcv in variants:
    fixed = _drive(Trainer(mc, tcv, pc, mesh), sim, 16)
    elastic = _drive(
        Trainer(mc, tcv, pc, mesh, membership=MembershipController(4)),
        sim, 16)
    _assert_bitwise(fixed, elastic, what)

# hierarchical int8 wire ring on a pod mesh (2 pods x 2 groups)
tc_h = tc.replace(outer_comm=OuterCommConfig(
    compression="int8-wire", bits=8, block=64, hierarchical=True))
pc_h = ParallelConfig(data_axis_size=2, model_axis_size=2, num_pods=2,
                      data_outer=2)
mesh_h = small_mesh((2, 2, 1, 2), ("pod", "data_outer", "data_inner",
                                   "model"))
sim_h = SimulatedRun(mc, tc_h, num_groups=4, seed=0, num_pods=2)
fixed_h = _drive(Trainer(mc, tc_h, pc_h, mesh_h), sim_h, 16)
elastic_h = _drive(
    Trainer(mc, tc_h, pc_h, mesh_h, membership=MembershipController(4)),
    sim_h, 16)
_assert_bitwise(fixed_h, elastic_h, "int8 wire hier pod ring")

# ---- 2. sim == Trainer at every outer boundary under scripted churn ----
SCRIPT = "drop:1@1,rejoin:1@3,straggle:0@2+1"
mcfg = MembershipConfig(max_staleness=1)


def _worst_all_groups(sim, trainer):
    w = 0.0
    for a, b in zip(jax.tree.leaves(sim.state.group_params),
                    jax.tree.leaves(trainer.state.params)):
        w = max(w, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32)).max()))
    return w


def _churn_pair(tcv):
    tcv = tcv.replace(membership=mcfg)
    mk = lambda: MembershipController(4, cfg=mcfg,
                                      schedule=ChurnSchedule.parse(SCRIPT))
    s = SimulatedRun(mc, tcv, num_groups=4, seed=0, membership=mk())
    t = Trainer(mc, tcv, pc, mesh, membership=mk())
    return s, t


# fp32 eager: outer events at steps 7/11/15/19/23 (ordinals 0..4); the
# script's rejoin bootstrap lands after event 2's apply and group 1
# re-enters the mask at event 3
tc_c = tc.replace(total_steps=24, warmup_frac=0.25)
sim_c, trainer_c = _churn_pair(tc_c)
boundaries = [s for s in range(24)
              if sim_c.sched.is_sync_step(s) and sim_c.sched.op_at(s) == "outer"]
assert len(boundaries) == 5, boundaries
for step in range(24):
    batch = sim_c._global_batch(step)
    dist = jax.device_put(batch, trainer_c.bundle.batch_sharding(batch))
    trainer_c.train_step(dist)
    sim_c.run(1)
    if step in boundaries:
        w = _worst_all_groups(sim_c, trainer_c)
        assert w < 5e-4, (step, w)
        print(f"churn boundary step {step}: worst divergence {w:.2e}")

# int8 wire + delayed dispatch under the same script: the weighted ring
# reduce, masked apply and bootstrap all agree end to end
tc_w = tc_c.replace(sync_delay=1, outer_comm=OuterCommConfig(
    compression="int8-wire", bits=8, block=64))
sim_w, trainer_w = _churn_pair(tc_w)
for step in range(24):
    batch = sim_w._global_batch(step)
    dist = jax.device_put(batch, trainer_w.bundle.batch_sharding(batch))
    trainer_w.train_step(dist)
    sim_w.run(1)
w = _worst_all_groups(sim_w, trainer_w)
print(f"churn int8-wire d=1 final: worst divergence {w:.2e}")
assert w < 5e-4, w

# ---- 3. launcher --churn-script smoke ----
from repro.launch import train as train_launcher

train_launcher.main([
    "--reduced", "--steps", "20", "--global-batch", "8",
    "--seq-len", "16", "--sync-interval", "4", "--groups", "4",
    "--mesh", "4,2,1", "--log-every", "10",
    "--churn-script", "drop:1@0,rejoin:1@2", "--max-staleness", "1",
])
print("launcher --churn-script smoke ok")

print("MD_MEMBERSHIP_OK")
