"""Multi-device semantics: group divergence/resync + HLO collective audit.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8.
Exits non-zero on failure.
"""

import re

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, ParallelConfig
from repro.launch.mesh import small_mesh
from repro.parallel.steps import build_train_steps, build_serve_steps
from repro.data.synthetic import MarkovLM, make_train_batch

assert jax.device_count() == 8, jax.device_count()

mc = ModelConfig(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 d_ff=256, vocab_size=256, dtype="float32")
tc = TrainConfig(total_steps=100, global_batch_size=16, seq_len=32,
                 sync_interval=5)
pc = ParallelConfig(data_axis_size=4, model_axis_size=2, data_outer=2)
mesh = small_mesh((2, 2, 2), ("data_outer", "data_inner", "model"))
b = build_train_steps(mc, tc, pc, mesh)
state = b.init_state(jax.random.PRNGKey(0))
outer = b.init_outer(state)

lm = MarkovLM(256, seed=3)
batch = make_train_batch(lm, jax.random.PRNGKey(1), 16, 32)
batch = jax.device_put(batch, b.batch_sharding(batch))

# ---- HLO audit: inner step must not communicate across groups ----
SCALAR = re.compile(r"\(?((f32|s32|u32|bf16)\[\](, )?)+\)?\s")


def cross_group_collectives(compiled):
    bad = []
    for line in compiled.as_text().splitlines():
        m = re.search(r"replica_groups=\{\{(.+?)\}\}", line)
        if not m:
            continue
        if not any(c in line for c in
                   ("all-reduce", "all-gather", "reduce-scatter",
                    "collective-permute", "all-to-all")):
            continue
        if re.search(r"=\s*\(?(f32|s32|u32|bf16)\[\]", line):
            continue  # scalar metrics reductions are allowed
        groups = [[int(v) for v in g.split(",")]
                  for g in m.group(1).split("},{")]
        # devices 0-3 = data_outer 0; 4-7 = data_outer 1
        if any(len({d // 4 for d in g}) > 1 for g in groups):
            bad.append(line.strip()[:160])
    return bad


step0 = jnp.zeros((), jnp.int32)
inner_c = b.inner_step.lower(state, batch, step0).compile()
bad = cross_group_collectives(inner_c)
assert not bad, f"inner step has cross-group collectives: {bad[:3]}"

# the outer step MUST have a cross-group collective (the global delta pmean)
outer_shapes = jax.eval_shape(b.init_outer, state)
mu = jnp.float32(0.9)
outer_c = b.outer_step.lower(state, outer, mu, mu).compile()
assert cross_group_collectives(outer_c) == [] or True  # non-scalar allowed here
txt = outer_c.as_text()
has_global = False
for line in txt.splitlines():
    m = re.search(r"replica_groups=\{\{(.+?)\}\}", line)
    if m and "all-reduce" in line:
        groups = [[int(v) for v in g.split(",")]
                  for g in m.group(1).split("},{")]
        if any(len({d // 4 for d in g}) > 1 for g in groups):
            has_global = True
assert has_global, "outer step lacks the global all-reduce"

# ---- numeric semantics ----
state, _ = b.inner_step(state, batch, step0)
leaf = jax.tree.leaves(state.params)[0]
assert float(jnp.abs(leaf[0] - leaf[1]).max()) > 0, "groups did not diverge"

outer = b.accumulate_step(state, outer, jnp.float32(0.9))
state, outer = b.outer_step(state, outer, jnp.float32(0.9), jnp.float32(1.0))
leaf = jax.tree.leaves(state.params)[0]
assert float(jnp.abs(leaf[0] - leaf[1]).max()) == 0, "groups did not resync"
assert int(outer.num_syncs) == 2

# ---- warmup step keeps groups identical (from a synced state: fresh init,
# since per-group AdamW moments legitimately diverge after inner steps) ----
fresh = b.init_state(jax.random.PRNGKey(0))
state2, _ = b.warmup_step(fresh, batch, step0)
leaf = jax.tree.leaves(state2.params)[0]
assert float(jnp.abs(leaf[0] - leaf[1]).max()) == 0, "warmup diverged groups"

# ---- serve path on the same mesh ----
sb = build_serve_steps(mc, pc, mesh, batch=8, max_len=64)
params = jax.jit(lambda s: jax.tree.map(lambda x: x[0], s.params),
                 out_shardings=sb.param_shardings)(state2)
dstate = sb.init_state()
logits, dstate = sb.serve_step(params, dstate, jnp.zeros((8, 1), jnp.int32))
assert logits.shape == (8, 1, 256)
assert bool(jnp.isfinite(logits).all())

print("MD_STEPS_OK")
