"""Distributed (shard_map) Pier == simulated (vmap) Pier, step for step.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig, ParallelConfig
from repro.core.simulate import SimulatedRun
from repro.launch.mesh import small_mesh
from repro.launch.train import Trainer

assert jax.device_count() == 8

mc = ModelConfig(num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                 d_ff=128, vocab_size=128, dtype="float32",
                 norm="layernorm", activation="gelu", positional="learned",
                 max_position_embeddings=64)
tc = TrainConfig(optimizer="pier", total_steps=20, global_batch_size=8,
                 seq_len=16, sync_interval=4, warmup_frac=0.4,
                 inner_lr=1e-3, inner_min_lr=1e-4, seed=0)

# simulated: 2 groups
sim = SimulatedRun(mc, tc, num_groups=2, seed=0)

# distributed: 2 groups x 2 data_inner x 2 model
pc = ParallelConfig(data_axis_size=4, model_axis_size=2, data_outer=2)
mesh = small_mesh((2, 2, 2), ("data_outer", "data_inner", "model"))
trainer = Trainer(mc, tc, pc, mesh)

# identical initial params (same PRNG key, same init path)
sim_leaves = jax.tree.leaves(sim.state.params)
dist_leaves = jax.tree.leaves(
    jax.tree.map(lambda x: x[0], trainer.state.params))
for a, b in zip(sim_leaves, dist_leaves):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)

# drive both with identical batches (simulate's stream)
for step in range(16):  # covers warmup, accumulation, switch, 2 outer syncs
    batch = sim._global_batch(step)
    dist_batch = jax.device_put(batch, trainer.bundle.batch_sharding(batch))
    trainer.train_step(dist_batch)
    sim.run(1)

sim_final = jax.tree.leaves(sim.eval_params())
dist_final = jax.tree.leaves(
    jax.tree.map(lambda x: x[0], trainer.state.params))
worst = 0.0
for a, b in zip(sim_final, dist_final):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist):", worst)
assert worst < 5e-4, worst

# outer states agree too
for a, b in zip(jax.tree.leaves(sim.state.outer.momentum),
                jax.tree.leaves(trainer.outer.momentum)):
    d = float(jnp.abs(a - b).max())
    assert d < 5e-4, d

# ---- delayed sync: dispatch/apply distributed path == simulator ----
tc_d = tc.replace(sync_delay=2)
sim_d = SimulatedRun(mc, tc_d, num_groups=2, seed=0)
trainer_d = Trainer(mc, tc_d, pc, mesh)
for step in range(16):  # covers an in-flight window crossing inner steps
    batch = sim_d._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_d.bundle.batch_sharding(batch))
    trainer_d.train_step(dist_batch)
    sim_d.run(1)
# an in-flight dispatch leaves the groups diverged -> compare group 0 to
# group 0 (mesh group 0 = data_outer index 0 = sim group 0)
worst = 0.0
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda g: g[0],
                                             sim_d.state.group_params)),
                jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                             trainer_d.state.params))):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist, sync_delay=2):", worst)
assert worst < 5e-4, worst

# ---- compressed hierarchical collective: int8 + two-stage reduce on a real
# pod mesh (2 pods x 2 groups) tracks the simulator's compressed path ----
tc_q = tc.replace(outer_compression="quantize", outer_comm_bits=8,
                  outer_comm_block=64, hierarchical_reduce=True)
sim_q = SimulatedRun(mc, tc_q, num_groups=4, seed=0, num_pods=2)
pc_q = ParallelConfig(data_axis_size=2, model_axis_size=2, num_pods=2,
                      data_outer=2)  # per-pod data axis: 2 outer x 1 inner
mesh_q = small_mesh((2, 2, 1, 2), ("pod", "data_outer", "data_inner",
                                   "model"))
trainer_q = Trainer(mc, tc_q, pc_q, mesh_q)
for step in range(16):
    batch = sim_q._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_q.bundle.batch_sharding(batch))
    trainer_q.train_step(dist_batch)
    sim_q.run(1)
worst = 0.0
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda g: g[0],
                                             sim_q.state.group_params)),
                jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                             trainer_q.state.params))):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist, int8 hierarchical):", worst)
assert worst < 5e-4, worst
# group-local residuals survived the round trip on both sides
assert any(float(jnp.abs(r).max()) > 0
           for r in jax.tree.leaves(trainer_q.outer.residual))

# ---- true int8 wire format (DESIGN.md §8): the packed (q, scales) pairs
# cross the slow axes through the one-hot/psum gather with per-source-scale
# sum semantics; the simulator shares the reduction subgraph bit for bit,
# so sim and distributed stay within inner-step noise. Flat: E=2 ring over
# data_outer; hierarchical: fp32 intra-pod mean, then the E=2 pod ring ----
from repro.config import OuterCommConfig

tc_w = tc.replace(outer_comm=OuterCommConfig(
    compression="int8-wire", bits=8, block=64))
sim_w = SimulatedRun(mc, tc_w, num_groups=2, seed=0)
trainer_w = Trainer(mc, tc_w, pc, mesh)
for step in range(16):
    batch = sim_w._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_w.bundle.batch_sharding(batch))
    trainer_w.train_step(dist_batch)
    sim_w.run(1)
worst = 0.0
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda g: g[0],
                                             sim_w.state.group_params)),
                jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                             trainer_w.state.params))):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist, int8 wire ring):", worst)
assert worst < 5e-4, worst
assert any(float(jnp.abs(r).max()) > 0
           for r in jax.tree.leaves(trainer_w.outer.residual))

tc_wh = tc.replace(outer_comm=OuterCommConfig(
    compression="int8-wire", bits=8, block=64, hierarchical=True))
sim_wh = SimulatedRun(mc, tc_wh, num_groups=4, seed=0, num_pods=2)
trainer_wh = Trainer(mc, tc_wh, pc_q, mesh_q)
for step in range(16):
    batch = sim_wh._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_wh.bundle.batch_sharding(batch))
    trainer_wh.train_step(dist_batch)
    sim_wh.run(1)
worst = 0.0
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda g: g[0],
                                             sim_wh.state.group_params)),
                jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                             trainer_wh.state.params))):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist, int8 wire hier pod ring):", worst)
assert worst < 5e-4, worst

# ---- controller-driven mid-run strategy switch (DESIGN.md §9): scripted
# controllers drive BOTH engines through the same decision at the same
# window (Quantized int8 -> int4 after window 2, flushing the in-flight
# dispatch); sim and distributed stay within inner-step noise across the
# switch, and the error-feedback residual survives the re-jit boundary.
# The delayed section above already exercises warmup overlap on both
# sides: with sync_delay=2 the accumulates at steps 3 and 7 dispatch into
# the same in-flight window and apply at 5 and 9. ----
from repro.sync import Quantized, ScriptedSyncController

tc_s = tc.replace(sync_delay=2, outer_comm=OuterCommConfig(
    compression="quantize", bits=8, block=64))
q4 = Quantized(4, 64)
sim_s = SimulatedRun(mc, tc_s, num_groups=2, seed=0,
                     sync_controller=ScriptedSyncController(2, {2: q4}))
trainer_s = Trainer(mc, tc_s, pc, mesh,
                    sync_controller=ScriptedSyncController(2, {2: q4}))
for step in range(16):
    batch = sim_s._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_s.bundle.batch_sharding(batch))
    trainer_s.train_step(dist_batch)
    sim_s.run(1)
assert sim_s.strategy == trainer_s.strategy == q4
worst = 0.0
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda g: g[0],
                                             sim_s.state.group_params)),
                jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                             trainer_s.state.params))):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist, int8->int4 switch):", worst)
assert worst < 5e-4, worst
for a, b in zip(jax.tree.leaves(sim_s.state.outer.momentum),
                jax.tree.leaves(trainer_s.outer.momentum)):
    d = float(jnp.abs(a - b).max())
    assert d < 5e-4, d
assert any(float(jnp.abs(r).max()) > 0
           for r in jax.tree.leaves(trainer_s.outer.residual))

# ---- chunked dispatch + per-chunk apply: bitwise == the unchunked
# delayed Trainer on the same mesh (spans only repartition host dispatch;
# each chunk installs through its own apply with a per-span correction) ----
tc_c = tc_d.replace(comm_chunks=3)
trainer_c = Trainer(mc, tc_c, pc, mesh)
assert trainer_c.bundle.plan.num_chunks == 3
for step in range(16):  # sim_d's batch stream is pure in (seed, step)
    batch = sim_d._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_c.bundle.batch_sharding(batch))
    trainer_c.train_step(dist_batch)
for a, b in zip(jax.tree.leaves(trainer_d.state.params),
                jax.tree.leaves(trainer_c.state.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("chunked(3) delayed Trainer bitwise == unchunked")

# ---- sharded outer exchange over DP×TP (DESIGN.md §10): each device
# compresses/exchanges only its Δθ shard along the auto (data_inner,
# model) axes, with momentum/anchor/residual sharded alongside. fp32 is a
# pure layout change -> bitwise == the replicated path; quantized keeps
# the inner strategy's numeric model -> same simulator tolerance. ----

# (a) sharded flat-fp32 bitwise == the replicated trainer above (same
# batch stream: sim._global_batch is pure in (seed, step))
tc_sf = tc.replace(outer_comm=OuterCommConfig(sharded=True))
trainer_sf = Trainer(mc, tc_sf, pc, mesh)
for step in range(16):
    batch = sim._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_sf.bundle.batch_sharding(batch))
    trainer_sf.train_step(dist_batch)
for a, b in zip(jax.tree.leaves(trainer.state.params),
                jax.tree.leaves(trainer_sf.state.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(trainer.outer.momentum),
                jax.tree.leaves(trainer_sf.outer.momentum)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("sharded flat-fp32 bitwise == replicated")

# (b) sharded quantized tracks its simulator model within the same
# tolerance as the replicated Quantized path
tc_sq = tc.replace(outer_comm=OuterCommConfig(
    compression="quantize", bits=8, block=64, sharded=True))
sim_sq = SimulatedRun(mc, tc_sq, num_groups=2, seed=0)
trainer_sq = Trainer(mc, tc_sq, pc, mesh)
for step in range(16):
    batch = sim_sq._global_batch(step)
    dist_batch = jax.device_put(
        batch, trainer_sq.bundle.batch_sharding(batch))
    trainer_sq.train_step(dist_batch)
    sim_sq.run(1)
worst = 0.0
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda g: g[0],
                                             sim_sq.state.group_params)),
                jax.tree.leaves(jax.tree.map(lambda x: x[0],
                                             trainer_sq.state.params))):
    worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32)).max()))
print("max param divergence (sim vs dist, sharded int8):", worst)
assert worst < 5e-4, worst
assert any(float(jnp.abs(r).max()) > 0
           for r in jax.tree.leaves(trainer_sq.outer.residual))

# (c) per-device outer-state + dispatch buffer bytes scale ~1/(TP×FSDP):
# the big weight matrices shard 4-way over data_inner(2)×model(2) (small
# vectors replicate), so at least one leaf is exactly 1/4 per device and
# the tree-wide per-device total drops well below the replicated total.
def _per_device_bytes(tree):
    total = per_dev = 0
    min_ratio = 1.0
    for leaf in jax.tree.leaves(tree):
        shard = leaf.addressable_shards[0].data.nbytes
        total += leaf.nbytes
        per_dev += shard
        min_ratio = min(min_ratio, shard / leaf.nbytes)
    return total, per_dev, min_ratio

for name, tree in [("momentum", trainer_sq.outer.momentum),
                   ("anchor", trainer_sq.outer.anchor)]:
    total, per_dev, min_ratio = _per_device_bytes(tree)
    assert min_ratio == 0.25, (name, min_ratio)
    assert per_dev < 0.6 * total, (name, per_dev, total)
# residual is (G,)-stacked over data_outer AND auto-sharded per group
_, res_per_dev, res_min = _per_device_bytes(trainer_sq.outer.residual)
assert res_min == 0.25 / 2, res_min  # 1/2 groups × 1/4 auto shards
# Non-sharded strategies declare no layout for outer state — XLA
# propagation is free to shard it opportunistically (and does here), so
# the guarantee under test is the *declared* layout above, not a
# contrast against a replicated reference.
print("sharded outer state per-device bytes:",
      f"momentum {per_dev}/{total}")

# dispatch buffers: the in-flight target/snapshot shard the same way
mu = jnp.float32(0.9)
olr = jnp.float32(0.7)
dispatch, trainer_sq.outer = trainer_sq.bundle.dispatch_step(
    trainer_sq.state, trainer_sq.outer, mu, olr)
t_total, t_per_dev, t_min = _per_device_bytes(dispatch.target)
s_total, s_per_dev, s_min = _per_device_bytes(dispatch.snapshot)
assert t_min == 0.25, t_min
assert t_per_dev < 0.6 * t_total
assert s_min == 0.25 / 2, s_min  # (G,)-stacked snapshots
print("sharded dispatch buffers per-device bytes:",
      f"target {t_per_dev}/{t_total} snapshot {s_per_dev}/{s_total}")

print("MD_EQUIVALENCE_OK")


# ---- quantized reduce-scatter + all-gather wire path (DESIGN.md §14):
# each group ships only its 1/E shard of the quantized payload across
# data_outer, re-quantizes the reduced shard (second error-feedback
# residual), and all-gathers the re-quantized slots. The simulator runs
# the identical rs_ag_qs_ref subgraph, so while the two engines feed the
# exchange bitwise-identical inputs (through the first two syncs, before
# shard_map-vs-vmap inner-step fusion noise creeps in) the params AND
# both residuals stay exactly equal. After that, ~1e-6 of inner noise can
# land on a quantization rounding boundary and flip one int8 level —
# one quant step at the leaf's scale — so the long-run bound is
# quant-step-scaled rather than the fp32 5e-4. ----
def _worst_rs(sim_x, trainer_x, *trees):
    worst = 0.0
    pairs = [(jax.tree.map(lambda g: g[0], sim_x.state.group_params),
              jax.tree.map(lambda x: x[0], trainer_x.state.params)),
             (sim_x.state.outer.residual, trainer_x.outer.residual),
             (sim_x.state.outer.residual2, trainer_x.outer.residual2)]
    for sa, sb in pairs:
        for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            worst = max(worst, float(jnp.abs(jnp.asarray(a, jnp.float32)
                                             - jnp.asarray(b,
                                                           jnp.float32)).max()))
    return worst


def _drive_rs(tc_x, label):
    sim_x = SimulatedRun(mc, tc_x, num_groups=2, seed=0)
    trainer_x = Trainer(mc, tc_x, pc, mesh)
    assert trainer_x.bundle.plan.needs_residual2
    for step in range(8):  # two syncs (3, 7) on bitwise-identical inputs
        batch = sim_x._global_batch(step)
        dist_batch = jax.device_put(
            batch, trainer_x.bundle.batch_sharding(batch))
        trainer_x.train_step(dist_batch)
        sim_x.run(1)
    exact = _worst_rs(sim_x, trainer_x)
    print(f"divergence through sync 2 ({label}):", exact)
    assert exact == 0.0, exact
    for step in range(8, 16):  # two more syncs on noise-perturbed inputs
        batch = sim_x._global_batch(step)
        dist_batch = jax.device_put(
            batch, trainer_x.bundle.batch_sharding(batch))
        trainer_x.train_step(dist_batch)
        sim_x.run(1)
    worst = _worst_rs(sim_x, trainer_x)
    print(f"max divergence (sim vs dist, {label}):", worst)
    assert worst < 5e-2, worst  # <= a few int8 steps at leaf scale
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree.leaves(trainer_x.outer.residual))
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree.leaves(trainer_x.outer.residual2))
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree.leaves(sim_x.state.outer.residual2))
    return trainer_x


tc_rs = tc.replace(outer_comm=OuterCommConfig(
    compression="rs-ag", bits=8, block=64))
trainer_rs = _drive_rs(tc_rs, "rs-ag int8")

# ---- Sharded(Int8Wire): the wire core composes with the sharded outer
# exchange — Sharded force-normalizes the inner onto the rs-ag path so
# each lane ships only slot-sized buffers. Same bitwise-then-bounded
# contract; outer state keeps the §10 sharded layout alongside both
# residuals. ----
tc_sw = tc.replace(outer_comm=OuterCommConfig(
    compression="int8-wire", bits=8, block=64, sharded=True))
trainer_sw = _drive_rs(tc_sw, "sharded int8-wire rs-ag")
assert trainer_sw.strategy.inner.reduce_scatter
for name, tree in [("momentum", trainer_sw.outer.momentum),
                   ("anchor", trainer_sw.outer.anchor)]:
    total, per_dev, min_ratio = _per_device_bytes(tree)
    assert min_ratio == 0.25, (name, min_ratio)
    assert per_dev < 0.6 * total, (name, per_dev, total)
print("MD_RS_AG_OK")
