"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward + one train step on CPU, asserting output
shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.config import TrainConfig
from repro.configs import list_architectures, get_reduced_config
from repro.models import registry as R
from repro.optim.adamw import adamw_init, adamw_update


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", list_architectures())
def test_forward_and_train_step(arch, rng):
    cfg = get_reduced_config(arch)
    params = R.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    logits, aux = R.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    tc = TrainConfig(total_steps=10, inner_lr=1e-3)
    state = adamw_init(params, tc)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: R.loss_fn(p, cfg, batch), has_aux=True)(params)
        new_params, new_state = adamw_update(grads, state, params, tc,
                                             jnp.float32(1e-3))
        return new_params, new_state, loss

    p1, s1, loss1 = step(params, state, batch)
    p2, s2, loss2 = step(p1, s1, batch)
    assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss1) + 0.5  # not diverging
    # parameters actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "xlstm-1.3b",
                                  "recurrentgemma-9b", "whisper-large-v3",
                                  "deepseek-v2-236b", "gpt2-small"])
def test_prefill_decode_matches_full_forward(arch, rng):
    cfg = get_reduced_config(arch).replace(dtype="float32")
    if cfg.is_moe:
        # avoid capacity-drop mismatch between full-seq and incremental runs
        cfg = cfg.replace(expert_capacity_factor=8.0)
    params = R.init_params(rng, cfg)
    B, S = 2, 20
    batch = _batch(cfg, rng, B, S)
    full_logits, _ = R.forward(params, cfg, batch)
    P = S - 4
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :P]
    logits, state = R.prefill(params, cfg, pre, max_len=S)
    outs = [logits[:, -1]]
    for t in range(P, S):
        lg, state = R.decode_step(params, cfg, state,
                                  batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs[:-1], axis=1)
    ref = full_logits[:, P - 1:S - 1]
    assert float(jnp.max(jnp.abs(dec - ref))) < 2e-3


@pytest.mark.parametrize("scan", [False, True])
def test_scan_layers_equivalence(scan, rng):
    """Scanned and unrolled layouts compute identical logits (fp32)."""
    from repro.models import transformer as T

    cfg = get_reduced_config("qwen3-1.7b").replace(
        num_layers=4, dtype="float32")
    p_scan = R.init_params(rng, cfg, scan_layers=True)
    prefix, C, n, suffix = T.layer_segments(cfg)
    layers = []
    for j in range(n):
        for c in range(C):
            layers.append(jax.tree.map(lambda x: x[j],
                                       p_scan["layers"]["scan"][c]))
    p_flat = {k: v for k, v in p_scan.items() if k != "layers"}
    p_flat["layers"] = layers
    batch = _batch(cfg, rng)
    lg_s, _ = R.forward(p_scan if scan else p_flat, cfg, batch)
    lg_f, _ = R.forward(p_flat, cfg, batch)
    assert float(jnp.abs(lg_s - lg_f).max()) < 1e-4


def test_sliding_window_attention_masks_past(rng):
    """SWA: tokens beyond the window cannot influence the output."""
    cfg = get_reduced_config("granite-8b").replace(
        num_layers=2, dtype="float32", sliding_window=4)
    params = R.init_params(rng, cfg)
    B, S = 1, 12
    t1 = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % cfg.vocab_size)
    l1, _ = R.forward(params, cfg, {"tokens": t1})
    l2, _ = R.forward(params, cfg, {"tokens": t2})
    # position 11 sees only positions 8..11 -> unaffected by edits at 0..3
    assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) < 1e-5
    # but an early position IS affected
    assert float(jnp.abs(l1[:, 3] - l2[:, 3]).max()) > 1e-4


def test_causality(rng):
    """Changing future tokens never changes past logits (all families)."""
    for arch in ["gpt2-small", "xlstm-1.3b", "recurrentgemma-9b",
                 "deepseek-v2-236b"]:
        cfg = get_reduced_config(arch).replace(dtype="float32")
        params = R.init_params(rng, cfg)
        B, S = 1, 12
        batch = _batch(cfg, rng, B, S)
        t1 = batch["tokens"]
        t2 = t1.at[:, -1].set((t1[:, -1] + 3) % cfg.vocab_size)
        b1 = dict(batch); b1["tokens"] = t1
        b2 = dict(batch); b2["tokens"] = t2
        l1, _ = R.forward(params, cfg, b1)
        l2, _ = R.forward(params, cfg, b2)
        assert float(jnp.abs(l1[:, :-1] - l2[:, :-1]).max()) < 1e-5, arch
